//! Batched policy-serving router — the deploy-scenario runtime.
//!
//! Clients submit observation requests; the router coalesces them into
//! batches (up to `max_batch` or `max_wait`) and dispatches to worker
//! threads running policy inference. This mirrors the dynamic-batching
//! router architecture of LLM serving systems (vllm-project/router),
//! specialized for action-policy serving where each request is a single
//! policy step with tight latency budgets.
//!
//! Workers execute whatever representation the model's store holds: a
//! PTQ-committed model serves on [`crate::model::params::WeightRepr::Packed`]
//! 1-bit kernels directly — no dequantization on the request path.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::metrics::LatencyStats;
use crate::model::MiniVla;
use crate::sim::observe::Observation;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub workers: usize,
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { workers: 2, max_batch: 8, max_wait: Duration::from_micros(500) }
    }
}

struct Request {
    obs: Observation,
    submitted: Instant,
    reply: Sender<(Vec<Vec<f32>>, Duration)>,
}

/// The serving router. `submit` is thread-safe and blocking (returns the
/// decoded action chunk); latency statistics accumulate internally.
pub struct PolicyServer {
    tx: Sender<Request>,
    stats: Arc<Mutex<LatencyStats>>,
    batch_sizes: Arc<Mutex<Vec<usize>>>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl PolicyServer {
    pub fn start(model: Arc<MiniVla>, cfg: ServeConfig) -> Self {
        let (tx, rx) = channel::<Request>();
        let rx = Arc::new(Mutex::new(rx));
        let stats = Arc::new(Mutex::new(LatencyStats::new()));
        let batch_sizes = Arc::new(Mutex::new(Vec::new()));
        let mut handles = Vec::new();
        for w in 0..cfg.workers.max(1) {
            let rx = Arc::clone(&rx);
            let stats = Arc::clone(&stats);
            let batch_sizes = Arc::clone(&batch_sizes);
            let model = Arc::clone(&model);
            let cfg = cfg.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::with_stream(0x5E4E, w as u64);
                loop {
                    // Collect a batch: block for the first request, then
                    // drain up to max_batch within max_wait.
                    let mut batch: Vec<Request> = Vec::new();
                    {
                        let guard = rx.lock().unwrap();
                        match guard.recv() {
                            Ok(r) => batch.push(r),
                            Err(_) => break,
                        }
                        let deadline = Instant::now() + cfg.max_wait;
                        while batch.len() < cfg.max_batch {
                            let left = deadline.saturating_duration_since(Instant::now());
                            if left.is_zero() {
                                break;
                            }
                            match guard.recv_timeout(left) {
                                Ok(r) => batch.push(r),
                                Err(_) => break,
                            }
                        }
                    }
                    batch_sizes.lock().unwrap().push(batch.len());
                    for req in batch {
                        let feat = model.features(
                            &req.obs.visual_raw,
                            req.obs.instr_id,
                            &req.obs.proprio,
                            &mut None,
                        );
                        let act = model.decode(&feat, &mut rng);
                        let latency = req.submitted.elapsed();
                        stats.lock().unwrap().record(latency);
                        let _ = req.reply.send((act, latency));
                    }
                }
            }));
        }
        PolicyServer { tx, stats, batch_sizes, handles }
    }

    /// Submit one observation; blocks until the action chunk is decoded.
    pub fn submit(&self, obs: Observation) -> (Vec<Vec<f32>>, Duration) {
        let (reply_tx, reply_rx): (Sender<(Vec<Vec<f32>>, Duration)>, Receiver<_>) = channel();
        self.tx
            .send(Request { obs, submitted: Instant::now(), reply: reply_tx })
            .expect("server stopped");
        reply_rx.recv().expect("worker dropped request")
    }

    pub fn latency_stats(&self) -> LatencyStats {
        self.stats.lock().unwrap().clone()
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batch_sizes.lock().unwrap();
        if b.is_empty() {
            0.0
        } else {
            b.iter().sum::<usize>() as f64 / b.len() as f64
        }
    }

    /// Shut down: close the queue and join workers.
    pub fn shutdown(mut self) {
        let (tx, _) = channel();
        drop(std::mem::replace(&mut self.tx, tx));
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{HeadKind, VlaConfig};
    use crate::sim::observe::{observe, ObsParams};
    use crate::sim::tasks::libero_suite;

    fn sample_obs(model: &MiniVla) -> Observation {
        let task = &libero_suite("object")[0];
        let mut rng = Rng::new(1);
        let scene = task.instantiate(&mut rng);
        observe(&scene, task.stages[0].instr(), 100, model, &ObsParams::clean(), &mut rng)
    }

    #[test]
    fn serves_requests_and_records_latency() {
        let model = Arc::new(MiniVla::new(VlaConfig::tiny(HeadKind::Chunk)));
        let server = PolicyServer::start(Arc::clone(&model), ServeConfig::default());
        let obs = sample_obs(&model);
        for _ in 0..12 {
            let (act, lat) = server.submit(obs.clone());
            assert_eq!(act.len(), model.chunk_len());
            assert!(lat.as_nanos() > 0);
        }
        let stats = server.latency_stats();
        assert_eq!(stats.count(), 12);
        server.shutdown();
    }

    #[test]
    fn serves_packed_weights_bit_true_to_dense_twin() {
        // The deploy property: a server running on packed 1-bit weights
        // must produce the same actions as one running the dense
        // dequantization of those same weights.
        let mut packed_model = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
        // Give the (zero-init) head real weights so the decode is
        // exercised, then pack every quantizable layer.
        let mut rng = Rng::new(17);
        let head_dims = packed_model.store.dims("head.main");
        packed_model.store.set(
            "head.main",
            crate::tensor::matrix::Matrix::gauss(head_dims.0, head_dims.1, 0.1, &mut rng),
        );
        let n_packed = packed_model.store.pack_quantizable(64);
        assert!(n_packed > 0);
        let mut dense_model = packed_model.clone();
        assert_eq!(dense_model.store.dequantize_all(), n_packed);

        let obs = sample_obs(&packed_model);
        let packed_model = Arc::new(packed_model);
        let dense_model = Arc::new(dense_model);
        let srv_p = PolicyServer::start(Arc::clone(&packed_model), ServeConfig::default());
        let srv_d = PolicyServer::start(Arc::clone(&dense_model), ServeConfig::default());
        for _ in 0..4 {
            let (ap, _) = srv_p.submit(obs.clone());
            let (ad, _) = srv_d.submit(obs.clone());
            assert_eq!(ap.len(), ad.len());
            for (ca, cb) in ap.iter().zip(&ad) {
                for (a, b) in ca.iter().zip(cb) {
                    assert!((a - b).abs() < 1e-3, "packed {a} vs dense-twin {b}");
                }
            }
        }
        srv_p.shutdown();
        srv_d.shutdown();
    }

    #[test]
    fn concurrent_clients_batch() {
        let model = Arc::new(MiniVla::new(VlaConfig::tiny(HeadKind::Chunk)));
        let server = Arc::new(PolicyServer::start(
            Arc::clone(&model),
            ServeConfig { workers: 1, max_batch: 4, max_wait: Duration::from_millis(2) },
        ));
        let obs = sample_obs(&model);
        std::thread::scope(|s| {
            for _ in 0..4 {
                let srv = Arc::clone(&server);
                let o = obs.clone();
                s.spawn(move || {
                    for _ in 0..8 {
                        let (act, _) = srv.submit(o.clone());
                        assert!(!act.is_empty());
                    }
                });
            }
        });
        assert_eq!(server.latency_stats().count(), 32);
        assert!(server.mean_batch_size() >= 1.0);
    }
}
