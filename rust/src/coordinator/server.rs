//! Batched multi-model policy-serving router — the deploy-scenario
//! runtime.
//!
//! Clients submit [`ServeRequest`]s naming (or defaulting) a model variant
//! from a [`ModelRegistry`]; the router coalesces requests into batches
//! (up to `max_batch` or `max_wait`), groups each batch by variant, and
//! executes every same-variant group through ONE batched forward
//! ([`crate::model::MiniVla::features_batch`] / `decode_batch`) — so
//! PTQ-committed variants run the row-parallel multi-token packed GEMM of
//! [`crate::quant::packed::PackedBits`] across the whole coalesced group,
//! not a per-request loop. Activation precision rides the variant: an
//! `-a8` twin ([`crate::coordinator::scheduler::register_a8_variant`])
//! carries [`crate::model::ActPrecision::Int8`] in its store, so its
//! group's batched forward runs the W1A8 integer kernels while `-packed`
//! requests in the same batch stay W1A32 — per-request choice, one
//! endpoint. This mirrors the dynamic-batching router of
//! LLM serving systems (vllm-project/router), specialized for
//! action-policy serving where each request is one policy step with a
//! tight latency budget.
//!
//! Dispatch is **variant-affine sharded** (see [`crate::coordinator::shard`]):
//! requests route by variant hash to one of `shards` queues, each with its
//! own lock, and workers hold their batch-collection windows open without
//! holding ANY lock — killing the convoy where every worker serialized on
//! one `Mutex<Receiver>` for the whole `max_wait` window. Idle workers
//! steal whole same-variant groups from the deepest foreign shard, and
//! admission is routed: per-shard depth priced by per-variant service
//! rates, so a slow variant's backlog no longer sheds a fast variant's
//! requests. Batched forwards co-plan with the kernel thread pool
//! ([`crate::util::threadpool::with_thread_cap`]): N concurrent
//! dispatchers each take ~1/N of the pool's row-parallel width instead of
//! all requesting full width and serializing on the idle-count heuristic.
//!
//! Bit-parity: stochastic decodes are keyed by each request's own
//! submission `seq` ([`crate::util::rng::Rng::with_stream`]) and every
//! kernel is bit-identical across thread counts, so WHICH shard, worker,
//! window, or steal served a request never changes its actions — sharded
//! serving is byte-identical to the sequential path, pinned by tests
//! across worker and shard counts.
//!
//! The contract is typed end-to-end: responses carry which variant served
//! the request and the queue/compute split; failures surface as
//! [`ServeError`] — submitting to a stopped server is an error, never a
//! panic. [`PolicyServer::submit_async`] returns a [`ResponseHandle`] for
//! clients that pipeline requests.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::metrics::{BatchStats, LatencyStats, ShardStats, VariantStats};
use crate::coordinator::registry::ModelRegistry;
use crate::coordinator::shard::{shard_for, ShardQueue, WorkSignal};
use crate::model::vla::ObsInput;
use crate::model::MiniVla;
use crate::sim::observe::Observation;
use crate::util::rng::Rng;
use crate::util::threadpool;

#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub workers: usize,
    /// Variant-affine dispatch shards. 0 = auto: one shard per worker.
    /// With more workers than shards, shards get multiple collectors;
    /// with more shards than workers, each worker adopts the orphaned
    /// shards congruent to its index (plus work stealing), so every
    /// shard always drains.
    pub shards: usize,
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Deadline-aware admission control (see [`AdmissionControl`]).
    pub admission: AdmissionControl,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 2,
            shards: 0,
            max_batch: 8,
            max_wait: Duration::from_micros(500),
            admission: AdmissionControl::Off,
        }
    }
}

/// Admission policy at `submit`: under overload, a deadline-bearing
/// request that is predicted to out-wait its deadline is shed immediately
/// with [`ServeError::Overloaded`] — bounding tail latency at the door
/// instead of only triaging stale requests at dispatch (which still
/// happens; admission is the earlier, cheaper gate).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum AdmissionControl {
    /// Never shed at submit (deadline triage at dispatch only).
    #[default]
    Off,
    /// Shed when the ROUTED estimate implies a miss: the depth of the
    /// request's own shard, priced per queued variant at that variant's
    /// observed per-request service rate (mean compute ÷ mean same-variant
    /// group size), divided by the shard's live collector count. Requests
    /// without deadlines are always admitted; so is everything until the
    /// REQUEST's variant has `min_samples` served requests (no shedding on
    /// cold stats — cold co-tenants in the mix are priced at the
    /// requester's rate).
    DeadlineAware { min_samples: u64 },
}

/// The legacy global admission estimate: expected queue wait (µs) for a
/// request arriving behind `depth` undispatched requests, given one
/// global mean compute / mean batch. Kept as the single-variant
/// degenerate form of [`estimated_shard_wait_us`] (identical when the
/// shard holds one variant) and for the bench's homogeneous reporting.
pub fn estimated_queue_wait_us(
    depth: usize,
    mean_compute_us: f64,
    workers: usize,
    mean_batch: f64,
) -> f64 {
    depth as f64 * mean_compute_us.max(1.0) / (workers.max(1) as f64 * mean_batch.max(1.0))
}

/// Per-request service cost (µs) of one variant: observed mean batched-
/// forward compute divided by the variant's OWN mean same-variant group
/// size — not the global mean batch, which let a fast variant's big
/// batches mask a slow variant's cost (and vice versa). Compute is
/// floored at 1 µs so sub-µs models can't disable admission.
pub fn per_request_service_us(mean_compute_us: f64, mean_group: f64) -> f64 {
    mean_compute_us.max(1.0) / mean_group.max(1.0)
}

/// The routed admission estimate: expected wait (µs) behind a shard whose
/// pending mix is `(count, per_request_service_us)` per variant, drained
/// by `workers` collectors. Pure, so the shed predicate is unit-testable
/// without racing a live server.
pub fn estimated_shard_wait_us(pending: &[(f64, f64)], workers: usize) -> f64 {
    pending.iter().map(|&(count, per_req_us)| count * per_req_us).sum::<f64>()
        / workers.max(1) as f64
}

/// Live collectors affine to `shard`, from the ACTUAL per-index liveness
/// flags. Workers are affine — worker `i` homes on shard `i % n_shards` —
/// but they retire (shrink, panic) at their own pace, so the surviving
/// index set is NOT a prefix `0..live`: counting `(0..live)` hallucinated
/// collectors on low shards and erased them on high shards whenever a
/// high-index worker outlived a low-index one. When fewer workers than
/// shards survive, each survivor adopts the orphaned shards congruent to
/// its index, so the count floors at 1 (stealing drains any shard
/// eventually regardless). Pure, for regression tests over arbitrary
/// liveness patterns.
pub fn affine_shard_workers(alive: &[bool], n_shards: usize, shard: usize) -> usize {
    let n_shards = n_shards.max(1);
    let live = alive.iter().filter(|&&a| a).count();
    if live >= n_shards {
        alive
            .iter()
            .enumerate()
            .filter(|&(i, &a)| a && i % n_shards == shard)
            .count()
            .max(1)
    } else {
        1
    }
}

/// Which registered variant a request asks for.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum VariantSelector {
    /// The registry's default variant.
    Default,
    /// A specific variant by name (e.g. `"hbvla-packed"`).
    Named(String),
}

impl VariantSelector {
    pub fn named(name: &str) -> Self {
        VariantSelector::Named(name.to_string())
    }
}

/// A typed serving request: observation, per-request variant choice, and
/// an optional queueing deadline (requests that wait longer are failed
/// with [`ServeError::DeadlineExceeded`] instead of served stale).
#[derive(Clone, Debug)]
pub struct ServeRequest {
    pub obs: Observation,
    pub variant: VariantSelector,
    pub deadline: Option<Duration>,
}

impl ServeRequest {
    pub fn new(obs: Observation) -> Self {
        ServeRequest { obs, variant: VariantSelector::Default, deadline: None }
    }

    pub fn with_variant(mut self, name: &str) -> Self {
        self.variant = VariantSelector::named(name);
        self
    }

    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }
}

/// A served action chunk plus the telemetry the caller needs to reason
/// about it: which variant actually ran, and where the time went.
#[derive(Clone, Debug)]
pub struct ServeResponse {
    pub actions: Vec<Vec<f32>>,
    pub variant_served: String,
    /// submit → this request's group dispatch (in a mixed batch this
    /// includes earlier variant groups' compute).
    pub queue_time: Duration,
    /// Wall time of the batched forward this request rode in.
    pub compute_time: Duration,
}

impl ServeResponse {
    /// End-to-end latency (queue + compute).
    pub fn latency(&self) -> Duration {
        self.queue_time + self.compute_time
    }
}

/// Every way serving can fail — the public API never panics on these.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// The requested variant is not in the registry.
    UnknownVariant(String),
    /// The registry holds no variants at all.
    NoVariants,
    /// The server has been shut down.
    Stopped,
    /// A worker dropped the request mid-flight (teardown or panic).
    WorkerDropped,
    /// The request out-waited its deadline in the queue.
    DeadlineExceeded { queued: Duration },
    /// Shed at submit by deadline-aware admission: the routed per-shard
    /// estimate predicted a deadline miss. `retry_after_us` is the
    /// predicted excess wait past the deadline — the shard drains roughly
    /// linearly, so a client that backs off this long before resubmitting
    /// should find an admittable queue instead of hot-looping on
    /// `Overloaded`.
    Overloaded { queue_depth: usize, estimated_wait: Duration, retry_after_us: u64 },
    /// The observation's shape doesn't match the serving interface.
    InvalidObservation { got: String },
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::UnknownVariant(name) => write!(f, "unknown model variant '{name}'"),
            ServeError::NoVariants => write!(f, "model registry holds no variants"),
            ServeError::Stopped => write!(f, "server is stopped"),
            ServeError::WorkerDropped => write!(f, "worker dropped the request"),
            ServeError::DeadlineExceeded { queued } => {
                write!(f, "deadline exceeded after {}us in queue", queued.as_micros())
            }
            ServeError::Overloaded { queue_depth, estimated_wait, retry_after_us } => {
                write!(
                    f,
                    "overloaded: {queue_depth} queued requests imply ~{}us wait past the \
                     deadline (retry after {retry_after_us}us)",
                    estimated_wait.as_micros()
                )
            }
            ServeError::InvalidObservation { got } => {
                write!(f, "observation does not match the serving interface ({got})")
            }
        }
    }
}

impl std::error::Error for ServeError {}

pub(crate) struct Request {
    pub(crate) obs: Observation,
    pub(crate) variant: String,
    pub(crate) deadline: Option<Duration>,
    pub(crate) submitted: Instant,
    /// Global submission sequence number: the request's own noise-stream
    /// id, so stochastic decodes (diffusion head) never depend on which
    /// requests happened to ride in the same batch — or which shard,
    /// window, or steal dispatched them.
    pub(crate) seq: u64,
    pub(crate) reply: Sender<Result<ServeResponse, ServeError>>,
}

/// Handle to an in-flight request from [`PolicyServer::submit_async`].
pub struct ResponseHandle {
    rx: Receiver<Result<ServeResponse, ServeError>>,
}

impl ResponseHandle {
    /// A handle over an externally-owned reply channel — the router front
    /// door completes routed requests through the same handle type local
    /// clients poll, so fleet code is agnostic to where a request ran.
    pub(crate) fn new(rx: Receiver<Result<ServeResponse, ServeError>>) -> Self {
        ResponseHandle { rx }
    }

    /// Block until the response (or error) arrives.
    pub fn wait(self) -> Result<ServeResponse, ServeError> {
        self.rx.recv().unwrap_or(Err(ServeError::WorkerDropped))
    }

    /// Non-blocking poll: `None` while still in flight. A dropped request
    /// (shutdown or worker death) surfaces as `WorkerDropped`, same as
    /// [`Self::wait`] — it never looks like an in-flight request.
    pub fn try_wait(&self) -> Option<Result<ServeResponse, ServeError>> {
        match self.rx.try_recv() {
            Ok(r) => Some(r),
            Err(std::sync::mpsc::TryRecvError::Empty) => None,
            Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                Some(Err(ServeError::WorkerDropped))
            }
        }
    }
}

/// The serving router. `submit`/`submit_async` are thread-safe; per-variant
/// latency and batch statistics accumulate internally (bounded memory).
/// Shutdown is explicit and idempotent; dropping the server shuts it down.
pub struct PolicyServer {
    registry: Arc<ModelRegistry>,
    cfg: ServeConfig,
    n_shards: usize,
    shards: Arc<Vec<ShardQueue>>,
    signal: Arc<WorkSignal>,
    next_seq: AtomicU64,
    /// Workers whose index is ≥ this value retire at their next idle tick
    /// or batch boundary (never mid-batch, so no reply is ever dropped).
    target_workers: Arc<AtomicUsize>,
    /// Per-index liveness flags (cleared by a drop guard, so a panicking
    /// worker is counted dead too). The service-rate term of
    /// deadline-aware admission reads WHICH indices are live, not just
    /// how many: workers retire at their own pace (idle tick / batch
    /// boundary), so the surviving index set is not a prefix during a
    /// shrink transition — a count-only view drifted per-shard affine
    /// divisors after a worker-loss drill.
    worker_alive: Arc<Vec<AtomicBool>>,
    variant_stats: Arc<Mutex<HashMap<String, VariantStats>>>,
    batch_stats: Arc<Mutex<BatchStats>>,
    shard_stats: Arc<Vec<Mutex<ShardStats>>>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

/// How long an idle worker parks on the work signal before re-checking
/// the shrink target and foreign-shard steal opportunities. Bounds
/// worker-loss reaction time; long enough that the re-scan cost is noise
/// next to any real batch.
const WORKER_IDLE_TICK: Duration = Duration::from_millis(2);

/// Batched forwards currently executing across every server in the
/// process — they all share ONE global kernel pool, so each dispatcher
/// caps its row-parallel fan-out at ~pool/active instead of requesting
/// full width and serializing on the pool's idle-count heuristic.
static ACTIVE_DISPATCHERS: AtomicUsize = AtomicUsize::new(0);

impl PolicyServer {
    pub fn start(registry: Arc<ModelRegistry>, cfg: ServeConfig) -> Self {
        let n_workers = cfg.workers.max(1);
        let n_shards = if cfg.shards == 0 { n_workers } else { cfg.shards };
        let shards: Arc<Vec<ShardQueue>> =
            Arc::new((0..n_shards).map(|_| ShardQueue::new()).collect());
        let shard_stats: Arc<Vec<Mutex<ShardStats>>> =
            Arc::new((0..n_shards).map(|_| Mutex::new(ShardStats::default())).collect());
        let signal = Arc::new(WorkSignal::new());
        let variant_stats = Arc::new(Mutex::new(HashMap::new()));
        let batch_stats = Arc::new(Mutex::new(BatchStats::new()));
        let target_workers = Arc::new(AtomicUsize::new(n_workers));
        let worker_alive: Arc<Vec<AtomicBool>> =
            Arc::new((0..n_workers).map(|_| AtomicBool::new(true)).collect());
        let mut handles = Vec::new();
        for idx in 0..n_workers {
            let shards = Arc::clone(&shards);
            let signal = Arc::clone(&signal);
            let registry = Arc::clone(&registry);
            let variant_stats = Arc::clone(&variant_stats);
            let batch_stats = Arc::clone(&batch_stats);
            let shard_stats = Arc::clone(&shard_stats);
            let target_workers = Arc::clone(&target_workers);
            let worker_alive = Arc::clone(&worker_alive);
            let cfg = cfg.clone();
            handles.push(std::thread::spawn(move || {
                // Drop guard, not a trailing store: the flag clears even
                // if the worker panics, so admission never divides by
                // capacity that no longer exists.
                struct AliveGuard<'a>(&'a AtomicBool);
                impl Drop for AliveGuard<'_> {
                    fn drop(&mut self) {
                        self.0.store(false, Ordering::Relaxed);
                    }
                }
                let _guard = AliveGuard(&worker_alive[idx]);
                worker_loop(
                    idx,
                    &cfg,
                    &shards,
                    &signal,
                    &registry,
                    &variant_stats,
                    &batch_stats,
                    &shard_stats,
                    &target_workers,
                );
            }));
        }
        PolicyServer {
            registry,
            cfg,
            n_shards,
            shards,
            signal,
            next_seq: AtomicU64::new(0),
            target_workers,
            worker_alive,
            variant_stats,
            batch_stats,
            shard_stats,
            handles: Mutex::new(handles),
        }
    }

    /// Worker-loss drill / degraded operation: retire workers down to
    /// `target` (floored at 1 — the server never becomes headless). A
    /// retiring worker finishes its in-flight batch and replies to every
    /// request in it; shrink can only lose *capacity*, never requests —
    /// survivors adopt the retired workers' shards (affine re-stride plus
    /// stealing). Growing back is not supported — restart the server.
    pub fn shrink_workers(&self, target: usize) {
        let target = target.clamp(1, self.cfg.workers.max(1));
        self.target_workers.fetch_min(target, Ordering::Relaxed);
    }

    /// Workers currently running their dispatch loop (tracks
    /// [`Self::shrink_workers`] with a latency of one idle tick / batch).
    pub fn live_workers(&self) -> usize {
        self.worker_alive.iter().filter(|a| a.load(Ordering::Relaxed)).count()
    }

    /// Dispatch shards (resolved: `cfg.shards`, or the worker count when
    /// configured 0/auto).
    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// Requests submitted but not yet past a closed batch-collection
    /// window, summed over shards.
    pub fn queue_depth(&self) -> usize {
        self.shards.iter().map(|s| s.depth()).sum()
    }

    /// Live collectors responsible for a shard, from the ACTUAL live
    /// index set (see [`affine_shard_workers`]).
    fn shard_workers(&self, shard: usize) -> usize {
        let alive: Vec<bool> =
            self.worker_alive.iter().map(|a| a.load(Ordering::Relaxed)).collect();
        affine_shard_workers(&alive, self.n_shards, shard)
    }

    /// Live collector count per shard — the admission divisors, exposed
    /// for tests and operational introspection.
    pub fn shard_worker_counts(&self) -> Vec<usize> {
        let alive: Vec<bool> =
            self.worker_alive.iter().map(|a| a.load(Ordering::Relaxed)).collect();
        (0..self.n_shards).map(|s| affine_shard_workers(&alive, self.n_shards, s)).collect()
    }

    /// Pending (undispatched) request counts per variant, summed over
    /// shards — the host-health payload routed admission prices remote
    /// requests against.
    pub fn pending_by_variant(&self) -> Vec<(String, u64)> {
        let mut agg: HashMap<String, u64> = HashMap::new();
        for s in self.shards.iter() {
            for (name, count) in s.pending_snapshot() {
                *agg.entry(name).or_insert(0) += count as u64;
            }
        }
        let mut out: Vec<(String, u64)> = agg.into_iter().collect();
        out.sort();
        out
    }

    /// Deadline-aware admission gate: `Err(Overloaded)` when the ROUTED
    /// estimate — the request's own shard's pending mix, priced at
    /// per-variant service rates — predicts `deadline` cannot be met from
    /// the back of that shard's queue. Other shards' backlogs are
    /// invisible here: a slow variant drowning its own shard no longer
    /// sheds requests for a fast variant on an idle shard. Conservative
    /// on cold stats — sheds nothing until the request's variant has
    /// `min_samples` served requests.
    fn admit(&self, variant: &str, deadline: Duration) -> Result<(), ServeError> {
        let AdmissionControl::DeadlineAware { min_samples } = self.cfg.admission else {
            return Ok(());
        };
        let shard = shard_for(variant, self.n_shards);
        let depth = self.shards[shard].depth();
        if depth == 0 {
            return Ok(());
        }
        let pending = self.shards[shard].pending_snapshot();
        let est_us = {
            let g = self.variant_stats.lock().unwrap();
            let own = match g.get(variant) {
                Some(v) if v.compute.count() as u64 >= min_samples => v,
                _ => return Ok(()),
            };
            let own_rate = per_request_service_us(own.compute.mean_us(), own.batches.mean());
            let mix: Vec<(f64, f64)> = pending
                .iter()
                .map(|(name, count)| {
                    let rate = match g.get(name.as_str()) {
                        Some(v) if v.compute.count() as u64 >= min_samples => {
                            per_request_service_us(v.compute.mean_us(), v.batches.mean())
                        }
                        // A cold co-tenant is priced at the requester's
                        // rate — better than silently pricing it free.
                        _ => own_rate,
                    };
                    (*count as f64, rate)
                })
                .collect();
            estimated_shard_wait_us(&mix, self.shard_workers(shard))
        };
        let deadline_us = deadline.as_secs_f64() * 1e6;
        if est_us > deadline_us {
            let mut g = self.variant_stats.lock().unwrap();
            g.entry(variant.to_string()).or_default().admission_sheds += 1;
            // The shard drains ~linearly at the estimated service rate, so
            // once the predicted excess past the deadline has elapsed the
            // same deadline should clear admission. Floored at 1 µs so a
            // backoff loop always makes forward progress.
            return Err(ServeError::Overloaded {
                queue_depth: depth,
                estimated_wait: Duration::from_micros(est_us as u64),
                retry_after_us: ((est_us - deadline_us).max(1.0)) as u64,
            });
        }
        Ok(())
    }

    /// Resolve a selector against the registry at submit time, so unknown
    /// variants fail fast instead of poisoning a batch.
    fn resolve(&self, sel: &VariantSelector) -> Result<(String, Arc<MiniVla>), ServeError> {
        match sel {
            VariantSelector::Named(name) => self
                .registry
                .get(name)
                .map(|m| (name.clone(), m))
                .ok_or_else(|| ServeError::UnknownVariant(name.clone())),
            VariantSelector::Default => {
                let name = self.registry.default_variant().ok_or(ServeError::NoVariants)?;
                let model = self.registry.get(&name).ok_or(ServeError::NoVariants)?;
                Ok((name, model))
            }
        }
    }

    /// Submit a request; blocks until the action chunk is decoded.
    pub fn submit(&self, req: ServeRequest) -> Result<ServeResponse, ServeError> {
        self.submit_async(req)?.wait()
    }

    /// Submit without blocking: returns a [`ResponseHandle`] immediately,
    /// so a client can pipeline many requests into one batch window.
    /// Observation shape is validated here against the resolved variant's
    /// serving interface — a malformed request is a typed error at submit,
    /// never a worker panic that would take down its whole batch.
    pub fn submit_async(&self, req: ServeRequest) -> Result<ResponseHandle, ServeError> {
        self.submit_async_inner(req, None)
    }

    /// Routed serving entry point: submit with a caller-assigned noise-
    /// stream sequence number. The router front door owns the global seq
    /// counter so WHICH host serves a request never changes its stochastic
    /// actions — a host-side server must use the router's seq, not mint
    /// its own.
    pub fn submit_async_with_seq(
        &self,
        req: ServeRequest,
        seq: u64,
    ) -> Result<ResponseHandle, ServeError> {
        self.submit_async_inner(req, Some(seq))
    }

    fn submit_async_inner(
        &self,
        req: ServeRequest,
        seq: Option<u64>,
    ) -> Result<ResponseHandle, ServeError> {
        let (variant, model) = self.resolve(&req.variant)?;
        let cfg = &model.cfg;
        if req.obs.visual_raw.rows != cfg.d_vis_in
            || req.obs.visual_raw.cols != cfg.n_visual
            || req.obs.proprio.len() != cfg.d_proprio
            || req.obs.instr_id >= cfg.vocab
        {
            return Err(ServeError::InvalidObservation {
                got: format!(
                    "visual {}x{}, proprio {}, instr {}",
                    req.obs.visual_raw.rows,
                    req.obs.visual_raw.cols,
                    req.obs.proprio.len(),
                    req.obs.instr_id
                ),
            });
        }
        // Routed deadline-aware admission: shed at the door when the
        // request's OWN shard already implies a miss (cheaper than
        // queueing + triaging).
        if let Some(d) = req.deadline {
            self.admit(&variant, d)?;
        }
        let shard = shard_for(&variant, self.n_shards);
        let (reply_tx, reply_rx) = channel();
        let inner = Request {
            obs: req.obs,
            variant,
            deadline: req.deadline,
            submitted: Instant::now(),
            seq: seq.unwrap_or_else(|| self.next_seq.fetch_add(1, Ordering::Relaxed)),
            reply: reply_tx,
        };
        // Push counts the request into the shard's admission depth under
        // the shard lock (no separate increment to roll back); a closed
        // shard hands the request back — the server has stopped.
        if self.shards[shard].push(inner).is_err() {
            return Err(ServeError::Stopped);
        }
        self.signal.notify();
        Ok(ResponseHandle { rx: reply_rx })
    }

    /// Convenience: one observation on the default variant.
    pub fn submit_obs(&self, obs: Observation) -> Result<ServeResponse, ServeError> {
        self.submit(ServeRequest::new(obs))
    }

    /// End-to-end latency over every variant (merged).
    pub fn latency_stats(&self) -> LatencyStats {
        let g = self.variant_stats.lock().unwrap();
        let mut all = LatencyStats::new();
        for v in g.values() {
            all.merge(&v.total);
        }
        all
    }

    /// Per-variant latency/deadline statistics.
    pub fn variant_stats(&self) -> HashMap<String, VariantStats> {
        self.variant_stats.lock().unwrap().clone()
    }

    /// Batch-size statistics (bounded ring + exact totals).
    pub fn batch_stats(&self) -> BatchStats {
        self.batch_stats.lock().unwrap().clone()
    }

    pub fn mean_batch_size(&self) -> f64 {
        self.batch_stats.lock().unwrap().mean()
    }

    /// Mean same-variant group size over every dispatched request — the
    /// number the batched packed GEMM actually sees (a mixed batch of 8
    /// split 3 ways computes like three small batches, not one big one).
    pub fn mean_group_size(&self) -> f64 {
        let g = self.variant_stats.lock().unwrap();
        let (mut requests, mut groups) = (0u64, 0u64);
        for v in g.values() {
            requests += v.batches.requests();
            groups += v.batches.count();
        }
        if groups == 0 {
            0.0
        } else {
            requests as f64 / groups as f64
        }
    }

    /// Per-shard dispatch statistics, indexed by shard.
    pub fn shard_stats(&self) -> Vec<ShardStats> {
        self.shard_stats.iter().map(|s| s.lock().unwrap().clone()).collect()
    }

    /// Shut down: close every shard and join workers. Requests already
    /// accepted are still drained and answered. Explicit, idempotent, and
    /// safe to race with in-flight `submit` calls — later submits get
    /// [`ServeError::Stopped`] instead of panicking.
    pub fn shutdown(&self) {
        for s in self.shards.iter() {
            s.close();
        }
        self.signal.notify();
        let handles: Vec<_> = self.handles.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for PolicyServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    idx: usize,
    cfg: &ServeConfig,
    shards: &[ShardQueue],
    signal: &WorkSignal,
    registry: &ModelRegistry,
    variant_stats: &Mutex<HashMap<String, VariantStats>>,
    batch_stats: &Mutex<BatchStats>,
    shard_stats: &[Mutex<ShardStats>],
    target_workers: &AtomicUsize,
) {
    let n_shards = shards.len();
    loop {
        // Retirement check between batches only: a retiring worker never
        // abandons requests it already pulled.
        let target = target_workers.load(Ordering::Relaxed);
        if idx >= target {
            break;
        }
        // Affine serve set: worker idx homes on shard idx % n_shards.
        // When fewer workers than shards are live (small configs, or
        // after a worker-loss drill), each survivor adopts the shards
        // congruent to its index — every shard keeps an owner, so a hot
        // orphan can't starve behind busy foreign owners' steal checks.
        let stride = target.min(n_shards).max(1);
        let seen = signal.generation();
        let mut opened: Option<(usize, Vec<Request>)> = None;
        let mut s = idx % stride;
        while s < n_shards {
            let got = shards[s].pop_upto(cfg.max_batch);
            if !got.is_empty() {
                opened = Some((s, got));
                break;
            }
            s += stride;
        }
        let mut stolen = false;
        if opened.is_none() {
            // Idle: steal the whole front same-variant group from the
            // deepest foreign shard. Whole groups only — a steal must
            // never dilute anyone's same-variant batch density.
            let mut victim = None;
            let mut best = 0usize;
            for v in 0..n_shards {
                if v % stride == idx % stride {
                    continue;
                }
                let len = shards[v].queue_len();
                if len > best {
                    best = len;
                    victim = Some(v);
                }
            }
            if let Some(v) = victim {
                let group = shards[v].steal_group(cfg.max_batch);
                if !group.is_empty() {
                    opened = Some((v, group));
                    stolen = true;
                }
            }
        }
        let (src, mut batch) = match opened {
            Some(x) => x,
            None => {
                // Nothing anywhere. After close no new work can appear,
                // so closed-and-drained everywhere is a monotone exit
                // condition; otherwise park until a submit bumps the
                // signal (or the idle tick re-checks the shrink target).
                if shards.iter().all(|sh| sh.closed_and_empty()) {
                    break;
                }
                signal.wait_past(seen, WORKER_IDLE_TICK);
                continue;
            }
        };
        if !stolen {
            // Hold the batch window open WITHOUT holding any lock: other
            // workers keep collecting concurrently from this and every
            // other shard — this is the convoy fix. Stolen groups skip
            // the window entirely (they dispatch as-is).
            let wait_deadline = Instant::now() + cfg.max_wait;
            let mut seen = signal.generation();
            while batch.len() < cfg.max_batch {
                let more = shards[src].pop_upto(cfg.max_batch - batch.len());
                let progressed = !more.is_empty();
                batch.extend(more);
                if batch.len() >= cfg.max_batch {
                    break;
                }
                if progressed {
                    continue;
                }
                let left = wait_deadline.saturating_duration_since(Instant::now());
                if left.is_zero() {
                    break;
                }
                seen = signal.wait_past(seen, left);
            }
            // Window closed: these requests leave the admission depth.
            // (A stolen group's depth was released at steal time.)
            shards[src].finish_batch(batch.iter().map(|r| r.variant.as_str()));
        }
        batch_stats.lock().unwrap().record(batch.len());
        {
            let mut ss = shard_stats[src].lock().unwrap();
            ss.batches.record(batch.len());
            if stolen {
                ss.stolen_groups += 1;
                ss.stolen_requests += batch.len() as u64;
            }
        }

        // Group by variant, preserving arrival order within each group.
        // Under variant-affine routing most batches are one group already;
        // mixed groups appear when variants collide on a shard.
        let mut groups: Vec<(String, Vec<Request>)> = Vec::new();
        for req in batch.drain(..) {
            match groups.iter_mut().find(|(name, _)| *name == req.variant) {
                Some((_, g)) => g.push(req),
                None => groups.push((req.variant.clone(), vec![req])),
            }
        }
        for (name, reqs) in groups {
            shard_stats[src].lock().unwrap().groups.record(reqs.len());
            dispatch_group(&name, reqs, registry, variant_stats);
        }
    }
}

/// Triage, execute, and reply to one same-variant group through a single
/// batched forward.
fn dispatch_group(
    name: &str,
    reqs: Vec<Request>,
    registry: &ModelRegistry,
    variant_stats: &Mutex<HashMap<String, VariantStats>>,
) {
    // Per-group dispatch stamp: in a mixed batch, later groups queue
    // behind earlier groups' compute — their queue time and deadline
    // triage must include it.
    let group_dispatch = Instant::now();
    // Deadline triage before spending compute.
    let mut live: Vec<Request> = Vec::new();
    for req in reqs {
        let queued = group_dispatch.saturating_duration_since(req.submitted);
        if let Some(d) = req.deadline {
            if queued > d {
                let mut g = variant_stats.lock().unwrap();
                g.entry(name.to_string()).or_default().deadline_misses += 1;
                let _ = req.reply.send(Err(ServeError::DeadlineExceeded { queued }));
                continue;
            }
        }
        live.push(req);
    }
    if live.is_empty() {
        return;
    }
    // The variant can have been replaced — or REMOVED (registry
    // hot-swap / the variant-kill drill) — since submit: re-resolving at
    // dispatch means a removed variant fails the whole queued group with
    // a typed error instead of serving deregistered weights.
    let model = match registry.get(name) {
        Some(m) => m,
        None => {
            for req in live {
                let _ = req.reply.send(Err(ServeError::UnknownVariant(name.to_string())));
            }
            return;
        }
    };
    // One batched forward for the whole same-variant group: the packed
    // variants execute the multi-token packed GEMM here. Pool-aware:
    // with N groups in flight process-wide, each forward takes ~1/N of
    // the kernel pool's row-parallel width — co-planned parallelism
    // instead of N full-width requests serializing on the pool. Capping
    // never changes results (kernels are bit-identical at any width).
    struct Slot;
    impl Drop for Slot {
        fn drop(&mut self) {
            ACTIVE_DISPATCHERS.fetch_sub(1, Ordering::Relaxed);
        }
    }
    let active = ACTIVE_DISPATCHERS.fetch_add(1, Ordering::Relaxed) + 1;
    let _slot = Slot;
    let cap = threadpool::pool_threads().div_ceil(active);
    let t0 = Instant::now();
    let actions = threadpool::with_thread_cap(cap, || {
        let inputs: Vec<ObsInput> = live
            .iter()
            .map(|r| ObsInput {
                visual_raw: &r.obs.visual_raw,
                instr_id: r.obs.instr_id,
                proprio: &r.obs.proprio,
            })
            .collect();
        let feats = model.features_batch(&inputs);
        // Noise streams keyed by each request's own submission seq: batch
        // composition never changes a served stochastic action.
        let mut rngs: Vec<Rng> =
            live.iter().map(|r| Rng::with_stream(0x5E4E_D1F, r.seq)).collect();
        model.decode_batch(&feats, &mut rngs)
    });
    let compute = t0.elapsed();

    let mut g = variant_stats.lock().unwrap();
    let stats = g.entry(name.to_string()).or_default();
    // The variant's own served-group size: denominator of its
    // per-request service rate in routed admission.
    stats.batches.record(live.len());
    for (req, act) in live.into_iter().zip(actions) {
        let queue_time = group_dispatch.saturating_duration_since(req.submitted);
        stats.requests += 1;
        stats.queue.record(queue_time);
        stats.compute.record(compute);
        stats.total.record(req.submitted.elapsed());
        let _ = req.reply.send(Ok(ServeResponse {
            actions: act,
            variant_served: name.to_string(),
            queue_time,
            compute_time: compute,
        }));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{HeadKind, MiniVla, VlaConfig};
    use crate::sim::observe::{observe, ObsParams};
    use crate::sim::tasks::libero_suite;
    use crate::tensor::matrix::Matrix;

    fn sample_obs(model: &MiniVla) -> Observation {
        let task = &libero_suite("object")[0];
        let mut rng = Rng::new(1);
        let scene = task.instantiate(&mut rng);
        observe(&scene, task.stages[0].instr(), 100, model, &ObsParams::clean(), &mut rng)
    }

    fn single_registry(model: MiniVla) -> Arc<ModelRegistry> {
        let r = ModelRegistry::new();
        r.register("dense", Arc::new(model)).unwrap();
        Arc::new(r)
    }

    #[test]
    fn serves_requests_and_records_latency() {
        let model = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
        let chunk_len = model.chunk_len();
        let obs = sample_obs(&model);
        let server = PolicyServer::start(single_registry(model), ServeConfig::default());
        for _ in 0..12 {
            let rsp = server.submit(ServeRequest::new(obs.clone())).unwrap();
            assert_eq!(rsp.actions.len(), chunk_len);
            assert_eq!(rsp.variant_served, "dense");
            assert!(rsp.latency().as_nanos() > 0);
        }
        let stats = server.latency_stats();
        assert_eq!(stats.count(), 12);
        let per = server.variant_stats();
        assert_eq!(per["dense"].requests, 12);
        assert_eq!(per["dense"].deadline_misses, 0);
        server.shutdown();
    }

    #[test]
    fn routes_per_request_variant_and_packed_matches_dense_twin() {
        // The deploy property, now on ONE server: requests routed to the
        // packed variant must produce the same actions as requests routed
        // to the dense dequantization of those same weights.
        let mut packed_model = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
        let mut rng = Rng::new(17);
        let head_dims = packed_model.store.dims("head.main");
        packed_model
            .store
            .set("head.main", Matrix::gauss(head_dims.0, head_dims.1, 0.1, &mut rng));
        let n_packed = packed_model.store.pack_quantizable(64);
        assert!(n_packed > 0);
        let mut dense_model = packed_model.clone();
        assert_eq!(dense_model.store.dequantize_all(), n_packed);

        let obs = sample_obs(&packed_model);
        let registry = ModelRegistry::new();
        registry.register("packed", Arc::new(packed_model)).unwrap();
        registry.register("dense", Arc::new(dense_model)).unwrap();
        let server = PolicyServer::start(Arc::new(registry), ServeConfig::default());
        for _ in 0..4 {
            let rp =
                server.submit(ServeRequest::new(obs.clone()).with_variant("packed")).unwrap();
            let rd = server.submit(ServeRequest::new(obs.clone()).with_variant("dense")).unwrap();
            assert_eq!(rp.variant_served, "packed");
            assert_eq!(rd.variant_served, "dense");
            assert_eq!(rp.actions.len(), rd.actions.len());
            for (ca, cb) in rp.actions.iter().zip(&rd.actions) {
                for (a, b) in ca.iter().zip(cb) {
                    assert!((a - b).abs() < 1e-3, "packed {a} vs dense-twin {b}");
                }
            }
        }
        let per = server.variant_stats();
        assert_eq!(per["packed"].requests, 4);
        assert_eq!(per["dense"].requests, 4);
        server.shutdown();
    }

    #[test]
    fn concurrent_clients_batch() {
        let model = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
        let obs = sample_obs(&model);
        let server = Arc::new(PolicyServer::start(
            single_registry(model),
            ServeConfig { workers: 1, max_batch: 4, max_wait: Duration::from_millis(2), ..Default::default() },
        ));
        std::thread::scope(|s| {
            for _ in 0..4 {
                let srv = Arc::clone(&server);
                let o = obs.clone();
                s.spawn(move || {
                    for _ in 0..8 {
                        let rsp = srv.submit(ServeRequest::new(o.clone())).unwrap();
                        assert!(!rsp.actions.is_empty());
                    }
                });
            }
        });
        assert_eq!(server.latency_stats().count(), 32);
        assert!(server.mean_batch_size() >= 1.0);
        assert_eq!(server.batch_stats().requests(), 32);
    }

    #[test]
    fn async_submit_coalesces_one_compute_batch() {
        let model = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
        let obs = sample_obs(&model);
        // max_batch equals the request count, so the batch closes on count
        // as soon as all submits land; the long max_wait only matters if
        // the submitter is descheduled mid-burst, keeping the coalescing
        // assertion below deterministic on loaded CI runners.
        let server = PolicyServer::start(
            single_registry(model),
            ServeConfig { workers: 1, max_batch: 8, max_wait: Duration::from_millis(500), ..Default::default() },
        );
        let handles: Vec<ResponseHandle> = (0..8)
            .map(|_| server.submit_async(ServeRequest::new(obs.clone())).unwrap())
            .collect();
        let mut responses = Vec::new();
        for h in handles {
            responses.push(h.wait().unwrap());
        }
        assert_eq!(responses.len(), 8);
        // At least one dispatched batch held several coalesced requests.
        assert!(server.batch_stats().max_recent() >= 2, "batching never coalesced");
        server.shutdown();
    }

    #[test]
    fn more_shards_than_workers_still_serves_every_shard() {
        // workers=1, shards=4: the lone worker adopts every shard
        // (affine re-stride), so liveness never depends on stealing.
        let model = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
        let obs = sample_obs(&model);
        let server = PolicyServer::start(
            single_registry(model),
            ServeConfig { workers: 1, shards: 4, ..Default::default() },
        );
        assert_eq!(server.n_shards(), 4);
        for _ in 0..6 {
            let rsp = server.submit(ServeRequest::new(obs.clone())).unwrap();
            assert_eq!(rsp.variant_served, "dense");
        }
        assert_eq!(server.latency_stats().count(), 6);
        assert_eq!(server.shard_stats().len(), 4);
        server.shutdown();
    }

    #[test]
    fn shard_queue_steal_takes_whole_front_group_and_releases_depth() {
        let model = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
        let obs = sample_obs(&model);
        let mk = |variant: &str, seq: u64| {
            let (reply, _rx) = channel();
            Request {
                obs: obs.clone(),
                variant: variant.to_string(),
                deadline: None,
                submitted: Instant::now(),
                seq,
                reply,
            }
        };
        let q = ShardQueue::new();
        q.push(mk("x", 0)).map_err(|_| ()).unwrap();
        q.push(mk("y", 1)).map_err(|_| ()).unwrap();
        q.push(mk("x", 2)).map_err(|_| ()).unwrap();
        assert_eq!(q.depth(), 3);
        assert_eq!(q.queue_len(), 3);
        let mut pending = q.pending_snapshot();
        pending.sort();
        assert_eq!(pending, vec![("x".to_string(), 2), ("y".to_string(), 1)]);
        // Steal = the WHOLE front group: both "x" requests, arrival order,
        // skipping the interleaved "y"; depth released at steal time.
        let group = q.steal_group(8);
        assert_eq!(group.iter().map(|r| r.seq).collect::<Vec<_>>(), vec![0, 2]);
        assert!(group.iter().all(|r| r.variant == "x"));
        assert_eq!(q.depth(), 1);
        // Popping into a window does NOT release depth; finish_batch does.
        let batch = q.pop_upto(8);
        assert_eq!(batch.len(), 1);
        assert_eq!(q.depth(), 1);
        q.finish_batch(batch.iter().map(|r| r.variant.as_str()));
        assert_eq!(q.depth(), 0);
        assert!(q.pending_snapshot().is_empty());
        // Closed shards refuse new work (the caller maps this to Stopped).
        q.close();
        assert!(q.push(mk("x", 3)).is_err());
        assert!(q.closed_and_empty());
    }

    #[test]
    fn unknown_variant_is_an_error_not_a_panic() {
        let model = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
        let obs = sample_obs(&model);
        let server = PolicyServer::start(single_registry(model), ServeConfig::default());
        let err = server
            .submit(ServeRequest::new(obs).with_variant("no-such-variant"))
            .unwrap_err();
        assert_eq!(err, ServeError::UnknownVariant("no-such-variant".to_string()));
        server.shutdown();
    }

    #[test]
    fn stopped_server_errors_and_double_shutdown_is_safe() {
        let model = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
        let obs = sample_obs(&model);
        let server = PolicyServer::start(single_registry(model), ServeConfig::default());
        server.submit(ServeRequest::new(obs.clone())).unwrap();
        server.shutdown();
        // Submitting after shutdown surfaces ServeError::Stopped.
        assert_eq!(server.submit(ServeRequest::new(obs)).unwrap_err(), ServeError::Stopped);
        // Shutdown is idempotent (and Drop will run it a third time).
        server.shutdown();
    }

    #[test]
    fn malformed_observation_is_an_error_not_a_worker_panic() {
        let model = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
        let obs = sample_obs(&model);
        let server = PolicyServer::start(single_registry(model), ServeConfig::default());
        let mut bad = obs.clone();
        bad.proprio.push(0.0);
        let err = server.submit(ServeRequest::new(bad)).unwrap_err();
        assert!(matches!(err, ServeError::InvalidObservation { .. }), "{err:?}");
        let mut bad_instr = obs.clone();
        bad_instr.instr_id = usize::MAX;
        assert!(server.submit(ServeRequest::new(bad_instr)).is_err());
        // The workers survived: well-formed requests still serve.
        server.submit(ServeRequest::new(obs)).unwrap();
        server.shutdown();
    }

    #[test]
    fn admission_estimate_formula() {
        // depth scales linearly; workers and batch size divide; the 1 µs
        // compute floor keeps sub-µs models from disabling admission.
        assert_eq!(estimated_queue_wait_us(0, 100.0, 2, 4.0), 0.0);
        assert_eq!(estimated_queue_wait_us(8, 100.0, 2, 4.0), 100.0);
        assert_eq!(estimated_queue_wait_us(8, 100.0, 1, 1.0), 800.0);
        assert_eq!(estimated_queue_wait_us(4, 0.0, 1, 1.0), 4.0); // floor
        assert_eq!(estimated_queue_wait_us(4, 100.0, 0, 0.0), 400.0); // clamped divisors
    }

    #[test]
    fn routed_admission_estimate_formula() {
        // Per-variant service rate: compute ÷ the variant's OWN group
        // size, floored exactly like the legacy formula.
        assert_eq!(per_request_service_us(100.0, 4.0), 25.0);
        assert_eq!(per_request_service_us(0.0, 4.0), 0.25); // compute floor
        assert_eq!(per_request_service_us(100.0, 0.0), 100.0); // group floor
        // The shard estimate prices each variant at its own rate and
        // divides by the shard's collectors.
        assert_eq!(estimated_shard_wait_us(&[], 2), 0.0);
        assert_eq!(estimated_shard_wait_us(&[(8.0, 25.0)], 2), 100.0);
        assert_eq!(estimated_shard_wait_us(&[(8.0, 25.0), (2.0, 400.0)], 2), 500.0);
        assert_eq!(estimated_shard_wait_us(&[(4.0, 1.0)], 0), 4.0); // clamped divisor
        // Single-variant shards reduce EXACTLY to the legacy estimate.
        let legacy = estimated_queue_wait_us(8, 100.0, 2, 4.0);
        let routed = estimated_shard_wait_us(&[(8.0, per_request_service_us(100.0, 4.0))], 2);
        assert_eq!(legacy, routed);
    }

    #[test]
    fn admission_sheds_deadline_request_under_queue_pressure() {
        let model = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
        let obs = sample_obs(&model);
        // One worker; a huge batch window so that once a (deadline-free)
        // request opens a batch, later submits observe queue depth ≥ 1
        // deterministically for the whole window.
        let server = PolicyServer::start(
            single_registry(model),
            ServeConfig {
                workers: 1,
                max_batch: 64,
                max_wait: Duration::from_millis(500),
                admission: AdmissionControl::DeadlineAware { min_samples: 4 },
                ..Default::default()
            },
        );
        // Cold stats: deadline-bearing requests are admitted (and served)
        // while fewer than min_samples requests have completed.
        for _ in 0..4 {
            server
                .submit(ServeRequest::new(obs.clone()).with_deadline(Duration::from_secs(5)))
                .unwrap();
        }
        // Warm stats, pending queue: the first async request holds a batch
        // window open; an impossible deadline behind it must be shed at
        // submit with Overloaded — before ever queueing.
        let pending = server.submit_async(ServeRequest::new(obs.clone())).unwrap();
        let err = server
            .submit(ServeRequest::new(obs.clone()).with_deadline(Duration::from_nanos(1)))
            .unwrap_err();
        match err {
            ServeError::Overloaded { queue_depth, estimated_wait, retry_after_us } => {
                assert!(queue_depth >= 1);
                assert!(estimated_wait > Duration::from_nanos(1));
                // Excess past a ~zero deadline ≈ the whole estimated wait,
                // and never below the 1 µs forward-progress floor.
                assert!(retry_after_us >= 1);
                assert!(retry_after_us <= estimated_wait.as_micros() as u64 + 1);
            }
            other => panic!("expected Overloaded, got {other:?}"),
        }
        // A lax deadline is still admitted under the same queue.
        let ok = server.submit_async(
            ServeRequest::new(obs.clone()).with_deadline(Duration::from_secs(30)),
        );
        assert!(ok.is_ok(), "lax deadline must be admitted");
        pending.wait().unwrap();
        ok.unwrap().wait().unwrap();
        let per = server.variant_stats();
        assert_eq!(per["dense"].admission_sheds, 1);
        assert!(per["dense"].summary().contains("sheds=1"));
        server.shutdown();
    }

    #[test]
    fn shrink_workers_degrades_without_dropping_requests() {
        let model = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
        let obs = sample_obs(&model);
        let server = PolicyServer::start(
            single_registry(model),
            ServeConfig { workers: 4, ..Default::default() },
        );
        assert_eq!(server.live_workers(), 4);
        server.shrink_workers(1);
        // Retired workers park on the idle tick; give them a few ticks.
        for _ in 0..200 {
            if server.live_workers() == 1 {
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(server.live_workers(), 1);
        // The survivor still serves (adopting every shard), and shrink
        // never goes below 1 — nor back up (growth is a restart, not a
        // runtime op).
        server.shrink_workers(0);
        server.shrink_workers(8);
        for _ in 0..6 {
            server.submit(ServeRequest::new(obs.clone())).unwrap();
        }
        assert_eq!(server.live_workers(), 1);
        assert_eq!(server.latency_stats().count(), 6);
        server.shutdown();
    }

    #[test]
    fn affine_shard_workers_counts_actual_live_indices() {
        // Non-prefix survival: worker 0 retired (or panicked) while
        // workers 1..3 live, 2 shards. The live indices are {1, 2, 3}:
        // shard 0 is served by worker 2 only; shard 1 by workers 1 and 3.
        let alive = [false, true, true, true];
        assert_eq!(affine_shard_workers(&alive, 2, 0), 1);
        assert_eq!(affine_shard_workers(&alive, 2, 1), 2);
        // The old `(0..live)` formula assumed survivors were the prefix
        // {0, 1, 2} and got it exactly backwards (2 and 1).
        let live = alive.iter().filter(|&&a| a).count();
        assert_eq!((0..live).filter(|i| i % 2 == 0).count(), 2);
        assert_eq!((0..live).filter(|i| i % 2 == 1).count(), 1);
        // All live: the affine striding count.
        let all = [true; 4];
        assert_eq!(affine_shard_workers(&all, 2, 0), 2);
        assert_eq!(affine_shard_workers(&all, 2, 1), 2);
        // Fewer live workers than shards: survivors adopt orphaned
        // shards, every divisor floors at 1.
        let one = [true, false, false, false];
        for shard in 0..4 {
            assert_eq!(affine_shard_workers(&one, 4, shard), 1);
        }
        // Degenerate inputs stay clamped, never zero.
        assert_eq!(affine_shard_workers(&[false, false], 2, 0), 1);
        assert_eq!(affine_shard_workers(&[], 0, 0), 1);
    }

    #[test]
    fn shrink_under_more_shards_than_workers_keeps_admission_divisors_sane() {
        // The satellite regression: shards > workers, then a worker-loss
        // drill. Per-shard admission divisors must track the ACTUAL live
        // set (never exceeding it, never zero), and the survivor must
        // still serve every shard.
        let model = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
        let obs = sample_obs(&model);
        let server = PolicyServer::start(
            single_registry(model),
            ServeConfig { workers: 2, shards: 4, ..Default::default() },
        );
        assert_eq!(server.live_workers(), 2);
        // Before the drill: 2 live workers over 4 shards → every shard's
        // divisor is the floor, 1.
        assert_eq!(server.shard_worker_counts(), vec![1, 1, 1, 1]);
        server.shrink_workers(1);
        for _ in 0..200 {
            if server.live_workers() == 1 {
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(server.live_workers(), 1);
        let counts = server.shard_worker_counts();
        assert_eq!(counts.len(), 4);
        for (shard, &c) in counts.iter().enumerate() {
            assert_eq!(c, 1, "shard {shard} divisor drifted to {c} after worker loss");
        }
        for _ in 0..6 {
            server.submit(ServeRequest::new(obs.clone())).unwrap();
        }
        assert_eq!(server.latency_stats().count(), 6);
        server.shutdown();
        // After shutdown every flag is down and the counts stay clamped.
        assert_eq!(server.live_workers(), 0);
        assert_eq!(server.shard_worker_counts(), vec![1, 1, 1, 1]);
    }

    #[test]
    fn caller_assigned_seq_pins_stochastic_actions() {
        // The routed-parity primitive: a Diffusion head decodes through
        // its noise stream, keyed by the request seq. Two submissions with
        // the SAME caller-assigned seq must produce bit-identical actions
        // regardless of interleaved traffic consuming the server's own
        // counter.
        let model = MiniVla::new(VlaConfig::tiny(HeadKind::Diffusion));
        let obs = sample_obs(&model);
        let server = PolicyServer::start(single_registry(model), ServeConfig::default());
        let a = server
            .submit_async_with_seq(ServeRequest::new(obs.clone()), 7)
            .unwrap()
            .wait()
            .unwrap();
        // Interleaved auto-seq traffic (would shift a server-minted seq).
        for _ in 0..3 {
            server.submit(ServeRequest::new(obs.clone())).unwrap();
        }
        let b = server
            .submit_async_with_seq(ServeRequest::new(obs.clone()), 7)
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(a.actions.len(), b.actions.len());
        for (ca, cb) in a.actions.iter().zip(&b.actions) {
            for (x, y) in ca.iter().zip(cb) {
                assert_eq!(x.to_bits(), y.to_bits(), "seq-pinned actions must be bit-equal");
            }
        }
        server.shutdown();
    }

    #[test]
    fn deadline_exceeded_is_reported() {
        let model = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
        let obs = sample_obs(&model);
        let server = PolicyServer::start(
            single_registry(model),
            ServeConfig { workers: 1, max_batch: 4, max_wait: Duration::from_millis(1), ..Default::default() },
        );
        // A 1 ns deadline always expires in the queue.
        let err = server
            .submit(ServeRequest::new(obs).with_deadline(Duration::from_nanos(1)))
            .unwrap_err();
        match err {
            ServeError::DeadlineExceeded { queued } => assert!(queued.as_nanos() > 0),
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
        let per = server.variant_stats();
        assert_eq!(per["dense"].deadline_misses, 1);
        assert_eq!(per["dense"].requests, 0);
        server.shutdown();
    }
}
