//! The L3 coordinator: layer-parallel PTQ scheduling, parallel closed-loop
//! rollout, and a multi-model batched policy-serving router
//! (vLLM-router-like) fed by a variant registry.

pub mod metrics;
pub mod registry;
pub mod rollout;
pub mod scheduler;
pub mod server;
pub mod shard;

pub use metrics::{BatchStats, LatencyStats, ShardStats, VariantStats};
pub use registry::{ModelRegistry, RegistryError};
pub use rollout::{eval_tasks, RolloutConfig, SuiteResult};
pub use scheduler::{
    quantize_exact_into_registry, quantize_into_registry, quantize_model, quantize_model_exact,
    register_a8_variant, register_static_scale_variant, QuantJobReport,
};
pub use server::{
    estimated_queue_wait_us, estimated_shard_wait_us, per_request_service_us, AdmissionControl,
    PolicyServer, ResponseHandle, ServeConfig, ServeError, ServeRequest, ServeResponse,
    VariantSelector,
};
pub use shard::shard_for;
