//! The L3 coordinator: layer-parallel PTQ scheduling, parallel closed-loop
//! rollout, a multi-model batched policy-serving router
//! (vLLM-router-like) fed by a variant registry, and the multi-host front
//! door (length-prefixed wire protocol + placement-hashed router) that
//! spans N `PolicyServer` processes.

pub mod metrics;
pub mod registry;
pub mod rollout;
pub mod router;
pub mod scheduler;
pub mod server;
pub mod shard;
pub mod wire;

pub use metrics::{BatchStats, LatencyStats, ShardStats, VariantStats};
pub use registry::{ModelRegistry, RegistryError};
pub use rollout::{eval_tasks, RolloutConfig, SuiteResult};
pub use router::{
    estimated_host_wait_us, HostCounters, LocalCluster, Router, RouterConfig, WireHost,
};
pub use scheduler::{
    quantize_exact_into_registry, quantize_into_registry, quantize_model, quantize_model_exact,
    register_a8_variant, register_static_scale_variant, QuantJobReport,
};
pub use server::{
    affine_shard_workers, estimated_queue_wait_us, estimated_shard_wait_us,
    per_request_service_us, AdmissionControl, PolicyServer, ResponseHandle, ServeConfig,
    ServeError, ServeRequest, ServeResponse, VariantSelector,
};
pub use shard::shard_for;
pub use wire::{HostHealth, WireError, MAX_FRAME_BYTES, PROTOCOL_VERSION};
