//! The L3 coordinator: layer-parallel PTQ scheduling, parallel closed-loop
//! rollout, and a batched policy-serving router (vLLM-router-like).

pub mod metrics;
pub mod rollout;
pub mod scheduler;
pub mod server;

pub use metrics::LatencyStats;
pub use rollout::{eval_tasks, RolloutConfig, SuiteResult};
pub use scheduler::{quantize_model, QuantJobReport};
pub use server::{PolicyServer, ServeConfig};
