//! The L3 coordinator: layer-parallel PTQ scheduling, parallel closed-loop
//! rollout, and a multi-model batched policy-serving router
//! (vLLM-router-like) fed by a variant registry.

pub mod metrics;
pub mod registry;
pub mod rollout;
pub mod scheduler;
pub mod server;

pub use metrics::{BatchStats, LatencyStats, VariantStats};
pub use registry::{ModelRegistry, RegistryError};
pub use rollout::{eval_tasks, RolloutConfig, SuiteResult};
pub use scheduler::{quantize_into_registry, quantize_model, register_a8_variant, QuantJobReport};
pub use server::{
    PolicyServer, ResponseHandle, ServeConfig, ServeError, ServeRequest, ServeResponse,
    VariantSelector,
};
