//! Variant-affine shard queues for the [`crate::coordinator::server`]
//! dispatch path.
//!
//! The pre-shard router funneled every request through one
//! `Mutex<Receiver<Request>>`, and each worker held that lock for its
//! ENTIRE `max_wait` batch-collection window — a textbook convoy: a
//! 4-worker server collected batches strictly one worker at a time.
//! Here every shard owns its own queue and lock; workers pull whatever
//! is currently queued under a short critical section and then hold
//! their batch window open WITHOUT any lock, re-polling on a shared
//! generation-counter signal. Collection windows on different shards
//! (and even on the same shard) overlap freely.
//!
//! Variant affinity: requests route to `shard_for(variant, n)`, so one
//! shard's queue is single-variant under single-variant traffic and
//! near-affine under mixed traffic — batches stay same-variant-dense,
//! which is what the batched packed GEMM path wants. Idle workers steal
//! a WHOLE same-variant group from the deepest foreign shard (never a
//! mixed slice), so stealing raises utilization without diluting group
//! sizes.
//!
//! Admission accounting: a request contributes to its shard's `depth`
//! (and per-variant `pending` counts) from push until its batch window
//! CLOSES — not until it is popped. Routed admission therefore sees
//! requests that are queued *or* riding a still-open window, matching
//! the pre-shard semantics where depth dropped only when a batch went to
//! dispatch.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::server::Request;

/// Route a variant to its home shard: FNV-1a over the variant name,
/// reduced mod `shards`. Stable across runs and platforms (pure bytes),
/// so tests can pick variant names with known placements.
pub fn shard_for(variant: &str, shards: usize) -> usize {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in variant.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    (h % shards.max(1) as u64) as usize
}

#[derive(Default)]
struct ShardState {
    queue: VecDeque<Request>,
    /// Per-variant requests submitted to this shard whose batch window
    /// has not closed (queued + in an open collection window). The mix
    /// routed admission prices per variant.
    pending: Vec<(String, usize)>,
    closed: bool,
}

fn bump(pending: &mut Vec<(String, usize)>, variant: &str, n: usize) {
    match pending.iter_mut().find(|(v, _)| v == variant) {
        Some((_, c)) => *c += n,
        None => pending.push((variant.to_string(), n)),
    }
}

fn dec(pending: &mut Vec<(String, usize)>, variant: &str, n: usize) {
    if let Some(i) = pending.iter().position(|(v, _)| v == variant) {
        let c = &mut pending[i].1;
        *c = c.saturating_sub(n);
        if *c == 0 {
            pending.swap_remove(i);
        }
    }
}

/// One dispatch shard: its own queue, its own lock, its own admission
/// depth. All depth/pending updates happen under the state lock, so the
/// lock-free `depth()` read can never observe an underflowed counter.
pub(crate) struct ShardQueue {
    state: Mutex<ShardState>,
    /// Mirror of queued + in-open-window request count for lock-free
    /// admission reads.
    depth: AtomicUsize,
}

impl ShardQueue {
    pub(crate) fn new() -> Self {
        ShardQueue { state: Mutex::new(ShardState::default()), depth: AtomicUsize::new(0) }
    }

    /// Enqueue a request; returns it back if the shard is closed (the
    /// caller surfaces `Stopped`). Counts toward admission depth
    /// immediately — a request is "queued" the instant push succeeds.
    pub(crate) fn push(&self, req: Request) -> Result<(), Request> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err(req);
        }
        bump(&mut st.pending, &req.variant, 1);
        self.depth.fetch_add(1, Ordering::Relaxed);
        st.queue.push_back(req);
        Ok(())
    }

    /// Requests queued or riding a still-open batch window.
    pub(crate) fn depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    /// Requests actually sitting in the queue (stealable work).
    pub(crate) fn queue_len(&self) -> usize {
        self.state.lock().unwrap().queue.len()
    }

    /// Per-variant pending counts — the traffic mix routed admission
    /// prices with per-variant service rates.
    pub(crate) fn pending_snapshot(&self) -> Vec<(String, usize)> {
        self.state.lock().unwrap().pending.clone()
    }

    /// Pop up to `max` requests from the front, any variant, preserving
    /// arrival order. Popped requests STAY in the admission depth until
    /// [`Self::finish_batch`] — they are in an open window, not dispatched.
    pub(crate) fn pop_upto(&self, max: usize) -> Vec<Request> {
        let mut st = self.state.lock().unwrap();
        let n = st.queue.len().min(max);
        st.queue.drain(..n).collect()
    }

    /// A batch-collection window closed over these requests: they are
    /// dispatching now, so release their admission depth.
    pub(crate) fn finish_batch<'a>(&self, variants: impl Iterator<Item = &'a str>) {
        let mut st = self.state.lock().unwrap();
        let mut n = 0;
        for v in variants {
            dec(&mut st.pending, v, 1);
            n += 1;
        }
        self.depth.fetch_sub(n, Ordering::Relaxed);
    }

    /// Steal the whole same-variant group at the head of the queue: every
    /// queued request of the front request's variant (up to `max`), in
    /// arrival order. The thief dispatches the group immediately — no
    /// window — so the steal itself releases admission depth.
    pub(crate) fn steal_group(&self, max: usize) -> Vec<Request> {
        let mut st = self.state.lock().unwrap();
        let variant = match st.queue.front() {
            Some(r) => r.variant.clone(),
            None => return Vec::new(),
        };
        let mut group = Vec::new();
        let mut i = 0;
        while i < st.queue.len() && group.len() < max {
            if st.queue[i].variant == variant {
                group.push(st.queue.remove(i).expect("index checked"));
            } else {
                i += 1;
            }
        }
        dec(&mut st.pending, &variant, group.len());
        self.depth.fetch_sub(group.len(), Ordering::Relaxed);
        group
    }

    /// Refuse new pushes. Already-queued requests stay and MUST still be
    /// drained (shutdown answers everything it accepted).
    pub(crate) fn close(&self) {
        self.state.lock().unwrap().closed = true;
    }

    /// True once the shard can never yield work again: closed and its
    /// queue fully drained (monotone after close — the worker exit test).
    pub(crate) fn closed_and_empty(&self) -> bool {
        let st = self.state.lock().unwrap();
        st.closed && st.queue.is_empty()
    }
}

/// A cross-shard wakeup channel: submits bump a generation counter and
/// notify; idle workers re-scan when the generation moves past what they
/// last saw. One tiny critical section per submit (increment + notify) —
/// nothing like the old full-window queue lock — and no lost wakeups:
/// a worker that captured the generation BEFORE scanning the queues
/// returns immediately from `wait_past` if anything landed since.
pub(crate) struct WorkSignal {
    gen: Mutex<u64>,
    cvar: Condvar,
}

impl WorkSignal {
    pub(crate) fn new() -> Self {
        WorkSignal { gen: Mutex::new(0), cvar: Condvar::new() }
    }

    pub(crate) fn generation(&self) -> u64 {
        *self.gen.lock().unwrap()
    }

    pub(crate) fn notify(&self) {
        *self.gen.lock().unwrap() += 1;
        self.cvar.notify_all();
    }

    /// Block until the generation moves past `seen` or `timeout` elapses;
    /// returns the current generation (the caller's next `seen`).
    pub(crate) fn wait_past(&self, seen: u64, timeout: Duration) -> u64 {
        let deadline = Instant::now() + timeout;
        let mut g = self.gen.lock().unwrap();
        while *g == seen {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                break;
            }
            g = self.cvar.wait_timeout(g, left).unwrap().0;
        }
        *g
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_routing_is_stable_and_in_range() {
        for shards in [1usize, 2, 3, 4, 7] {
            for name in ["dense", "rtn-packed", "rtn-packed-a8", "hbvla-exact", ""] {
                let s = shard_for(name, shards);
                assert!(s < shards, "{name} -> {s} of {shards}");
                assert_eq!(s, shard_for(name, shards), "routing must be deterministic");
            }
        }
        // One shard degenerates to the single-queue router.
        assert_eq!(shard_for("anything", 1), 0);
        assert_eq!(shard_for("anything", 0), 0, "shards floor at 1");
    }

    #[test]
    fn distinct_names_spread_across_shards() {
        // Not a uniformity proof — just that the hash doesn't collapse a
        // realistic variant set onto one shard.
        let names =
            ["dense", "rtn-packed", "rtn-packed-a8", "hbvla-packed-a8", "hbvla-exact", "ref"];
        let hit: std::collections::HashSet<usize> =
            names.iter().map(|n| shard_for(n, 4)).collect();
        assert!(hit.len() >= 2, "all of {names:?} landed on one of 4 shards");
    }

    #[test]
    fn work_signal_wakes_on_notify_and_times_out() {
        let sig = WorkSignal::new();
        let seen = sig.generation();
        // Notify before waiting: wait_past returns immediately.
        sig.notify();
        let t0 = Instant::now();
        let now = sig.wait_past(seen, Duration::from_secs(5));
        assert!(now > seen);
        assert!(t0.elapsed() < Duration::from_secs(1), "must not block after a missed notify");
        // Nothing new: the timeout bounds the wait.
        let t0 = Instant::now();
        let same = sig.wait_past(now, Duration::from_millis(10));
        assert_eq!(same, now);
        assert!(t0.elapsed() >= Duration::from_millis(9));
    }
}
