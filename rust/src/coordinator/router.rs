//! Multi-host serving: N [`PolicyServer`] processes ("hosts") behind one
//! [`Router`] front door, speaking the length-prefixed binary protocol of
//! [`crate::coordinator::wire`] over `std::net` TCP.
//!
//! Placement reuses [`shard_for`] — the same FNV-1a hash that routes
//! variants to in-process shards routes them to hosts, so same-variant
//! traffic coalesces on its home host and rides the host's own
//! variant-affine shards from there (two levels of the same hash). When a
//! host dies, its variants re-home deterministically along the probe
//! sequence `home, home+1, …` over the surviving hosts — no rendezvous
//! state to reconcile, just the hash re-evaluated against liveness.
//!
//! Admission is host-aware: every response/error piggybacks a
//! [`HostHealth`] snapshot (queue depth, live workers, observed
//! per-variant service rates), and the router prices a deadline request
//! against its TARGET host — the router's own in-flight counts for that
//! host, priced at the host's reported rates, divided by the host's live
//! workers ([`estimated_host_wait_us`], pure and unit-testable). In-flight
//! counts are router-local, so the estimate is fresh even when health
//! snapshots lag (single-front-door topology; multiple routers would each
//! see only their own contribution).
//!
//! Failure semantics: a lost connection marks the host dead, drains its
//! in-flight requests with typed [`ServeError::WorkerDropped`] — never a
//! hang — and subsequent submissions re-home. A host that receives a
//! malformed frame drops that CONNECTION and keeps serving others; the
//! router treats its end of the drop identically to a host loss.
//!
//! Bit-parity carries across the wire: the router owns the global
//! submission `seq` (the noise-stream id) and transmits it in each
//! Request frame, and observations/actions travel as IEEE-754 bit
//! patterns — so actions served through the router are bit-identical to
//! the direct in-process forward for EVERY host count, pinned by
//! `tests/multi_host.rs`.

use std::collections::HashMap;
use std::io::{self, Read};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::coordinator::registry::ModelRegistry;
use crate::coordinator::server::{
    per_request_service_us, AdmissionControl, PolicyServer, ResponseHandle, ServeConfig,
    ServeError, ServeRequest, ServeResponse, VariantSelector,
};
use crate::coordinator::shard::shard_for;
use crate::coordinator::wire::{write_frame, Frame, FrameReader, HostHealth};

/// How often host-side socket loops re-check the stop flag while idle.
const HOST_POLL: Duration = Duration::from_millis(5);
/// Host writer idle sleep between pending-handle scans.
const WRITER_IDLE: Duration = Duration::from_micros(100);

// ------------------------------------------------------------------ host

/// Build a host's health snapshot from its server's public telemetry.
fn health_of(server: &PolicyServer) -> HostHealth {
    let mut rates: Vec<(String, f64, u64)> = server
        .variant_stats()
        .into_iter()
        .map(|(name, v)| {
            let rate = per_request_service_us(v.compute.mean_us(), v.batches.mean());
            (name, rate, v.compute.count() as u64)
        })
        .collect();
    rates.sort_by(|a, b| a.0.cmp(&b.0));
    HostHealth {
        depth: server.queue_depth() as u64,
        live_workers: server.live_workers() as u32,
        pending: server.pending_by_variant(),
        rates,
    }
}

/// Per-connection state shared between a host's reader and writer thread.
struct ConnShared {
    alive: AtomicBool,
    /// Routed requests in flight on the local server: `(wire id, handle)`.
    pending: Mutex<Vec<(u64, ResponseHandle)>>,
    /// Frames to send immediately (submit errors, health replies).
    outbox: Mutex<Vec<Frame>>,
}

/// One `PolicyServer` process behind a TCP accept loop — the "host" half
/// of multi-host serving. In production each host is its own process
/// (`serve --listen`); tests and the loopback bench spawn several in one
/// process, which exercises the identical socket path.
pub struct WireHost {
    server: Arc<PolicyServer>,
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    threads: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl WireHost {
    /// Bind `addr` (use port 0 to auto-assign) and serve the registry
    /// through `cfg`. Returns once the listener is live.
    pub fn spawn(
        registry: Arc<ModelRegistry>,
        cfg: ServeConfig,
        addr: &str,
    ) -> io::Result<WireHost> {
        let server = Arc::new(PolicyServer::start(registry, cfg));
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let threads = Mutex::new(Vec::new());
        let host = WireHost { server, addr, stop, threads };
        let server = Arc::clone(&host.server);
        let stop_flag = Arc::clone(&host.stop);
        let accept = std::thread::spawn(move || {
            let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
            while !stop_flag.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        let server = Arc::clone(&server);
                        let stop = Arc::clone(&stop_flag);
                        conns.push(std::thread::spawn(move || {
                            serve_connection(stream, &server, &stop);
                        }));
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                        std::thread::sleep(HOST_POLL);
                    }
                    Err(_) => std::thread::sleep(HOST_POLL),
                }
            }
            for c in conns {
                let _ = c.join();
            }
        });
        host.threads.lock().unwrap().push(accept);
        Ok(host)
    }

    /// The bound listen address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Direct access to the underlying server (loopback tests/benches).
    pub fn server(&self) -> &PolicyServer {
        &self.server
    }

    /// Stop accepting, tear down live connections (their in-flight
    /// requests surface router-side as [`ServeError::WorkerDropped`]),
    /// and shut the server down. Idempotent.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Relaxed);
        let threads: Vec<_> = self.threads.lock().unwrap().drain(..).collect();
        for t in threads {
            let _ = t.join();
        }
        self.server.shutdown();
    }
}

impl Drop for WireHost {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Host side of one client connection: a reader (frames in → local
/// submissions) paired with a writer (completed handles → frames out).
/// A wire error drops THIS connection only — the host keeps serving.
fn serve_connection(stream: TcpStream, server: &Arc<PolicyServer>, stop: &Arc<AtomicBool>) {
    let _ = stream.set_nodelay(true);
    let shared = Arc::new(ConnShared {
        alive: AtomicBool::new(true),
        pending: Mutex::new(Vec::new()),
        outbox: Mutex::new(vec![Frame::Health(health_of(server))]),
    });
    let writer = {
        let stream = match stream.try_clone() {
            Ok(s) => s,
            Err(_) => return,
        };
        let shared = Arc::clone(&shared);
        let server = Arc::clone(server);
        let stop = Arc::clone(stop);
        std::thread::spawn(move || write_loop(stream, &shared, &server, &stop))
    };
    read_loop(stream, &shared, server, stop);
    shared.alive.store(false, Ordering::Relaxed);
    let _ = writer.join();
}

fn read_loop(
    mut stream: TcpStream,
    shared: &ConnShared,
    server: &Arc<PolicyServer>,
    stop: &AtomicBool,
) {
    let _ = stream.set_read_timeout(Some(HOST_POLL));
    let mut fr = FrameReader::new();
    let mut chunk = [0u8; 16 * 1024];
    while !stop.load(Ordering::Relaxed) && shared.alive.load(Ordering::Relaxed) {
        match fr.next_frame() {
            Ok(Some(frame)) => {
                if !handle_client_frame(frame, shared, server) {
                    break;
                }
                continue;
            }
            Ok(None) => {}
            // Malformed bytes: framing is lost — drop the connection
            // (typed locally; the router sees the drop as host loss for
            // this link). The host itself survives.
            Err(_) => break,
        }
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => fr.extend(&chunk[..n]),
            Err(e)
                if e.kind() == io::ErrorKind::WouldBlock
                    || e.kind() == io::ErrorKind::TimedOut
                    || e.kind() == io::ErrorKind::Interrupted => {}
            Err(_) => break,
        }
    }
    shared.alive.store(false, Ordering::Relaxed);
}

/// Returns `false` on a protocol violation (connection must drop).
fn handle_client_frame(frame: Frame, shared: &ConnShared, server: &Arc<PolicyServer>) -> bool {
    match frame {
        Frame::Request { id, seq, req } => {
            // The router-assigned seq IS the noise-stream id — the host
            // must not mint its own, or parity would depend on placement.
            match server.submit_async_with_seq(req, seq) {
                Ok(handle) => shared.pending.lock().unwrap().push((id, handle)),
                Err(err) => shared
                    .outbox
                    .lock()
                    .unwrap()
                    .push(Frame::Error { id, err, health: health_of(server) }),
            }
            true
        }
        Frame::Ping => {
            shared.outbox.lock().unwrap().push(Frame::Health(health_of(server)));
            true
        }
        Frame::Shrink { target } => {
            server.shrink_workers(target as usize);
            true
        }
        // Response/Error/Health only flow host → router.
        Frame::Response { .. } | Frame::Error { .. } | Frame::Health(_) => false,
    }
}

fn write_loop(
    mut stream: TcpStream,
    shared: &ConnShared,
    server: &Arc<PolicyServer>,
    stop: &AtomicBool,
) {
    loop {
        let stopping = stop.load(Ordering::Relaxed) || !shared.alive.load(Ordering::Relaxed);
        let mut wrote = false;
        let outbox: Vec<Frame> = shared.outbox.lock().unwrap().drain(..).collect();
        for frame in &outbox {
            if write_frame(&mut stream, frame).is_err() {
                shared.alive.store(false, Ordering::Relaxed);
                return;
            }
            wrote = true;
        }
        // Completed local requests → response/error frames with a fresh
        // health piggyback. Scan in place; order on the wire is
        // completion order, the router correlates by id.
        let done: Vec<(u64, Result<ServeResponse, ServeError>)> = {
            let mut pending = shared.pending.lock().unwrap();
            let mut done = Vec::new();
            let mut i = 0;
            while i < pending.len() {
                match pending[i].1.try_wait() {
                    Some(result) => {
                        let (id, _) = pending.swap_remove(i);
                        done.push((id, result));
                    }
                    None => i += 1,
                }
            }
            done
        };
        for (id, result) in done {
            let health = health_of(server);
            let frame = match result {
                Ok(rsp) => Frame::Response { id, rsp, health },
                Err(err) => Frame::Error { id, err, health },
            };
            if write_frame(&mut stream, &frame).is_err() {
                shared.alive.store(false, Ordering::Relaxed);
                return;
            }
            wrote = true;
        }
        if stopping {
            // Final drain done (best effort); sever the link so the
            // router's reader unblocks immediately.
            let _ = stream.shutdown(Shutdown::Both);
            return;
        }
        if !wrote {
            std::thread::sleep(WRITER_IDLE);
        }
    }
}

// ---------------------------------------------------------------- router

/// The routed admission estimate against ONE host: the router's own
/// in-flight mix on that host, priced at the host's reported per-variant
/// service rates, divided by the host's live workers. Cold stats admit:
/// returns `None` until the REQUEST's variant has `min_samples` served
/// requests in the host's rate table (cold co-tenants in the mix are
/// priced at the requester's rate, mirroring in-process admission).
pub fn estimated_host_wait_us(
    inflight: &[(String, u64)],
    rates: &[(String, f64, u64)],
    variant: &str,
    min_samples: u64,
    live_workers: usize,
) -> Option<f64> {
    let own_rate = rates
        .iter()
        .find(|(name, _, samples)| name == variant && *samples >= min_samples)
        .map(|(_, rate, _)| *rate)?;
    let total: f64 = inflight
        .iter()
        .map(|(name, count)| {
            let rate = rates
                .iter()
                .find(|(n, _, samples)| n == name && *samples >= min_samples)
                .map(|(_, r, _)| *r)
                .unwrap_or(own_rate);
            *count as f64 * rate
        })
        .sum();
    Some(total / live_workers.max(1) as f64)
}

#[derive(Clone, Debug, Default)]
pub struct RouterConfig {
    /// Deadline-aware admission at the front door, priced against the
    /// target host (same policy enum as the in-process server).
    pub admission: AdmissionControl,
}

struct Inflight {
    variant: String,
    tx: Sender<Result<ServeResponse, ServeError>>,
}

struct HostSlot {
    addr: String,
    alive: AtomicBool,
    writer: Mutex<TcpStream>,
    inflight: Mutex<HashMap<u64, Inflight>>,
    health: Mutex<HostHealth>,
}

impl HostSlot {
    /// Mark dead and fail every in-flight request with a typed error —
    /// the zero-hangs half of the re-homing contract.
    fn drain_dead(&self) {
        self.alive.store(false, Ordering::Relaxed);
        let drained: Vec<Inflight> =
            self.inflight.lock().unwrap().drain().map(|(_, v)| v).collect();
        for inflight in drained {
            let _ = inflight.tx.send(Err(ServeError::WorkerDropped));
        }
    }
}

/// The front door over N hosts. `submit`/`submit_async` mirror
/// [`PolicyServer`]'s API (same [`ResponseHandle`]), so clients and the
/// fleet harness are agnostic to whether they're talking to a process or
/// a cluster.
pub struct Router {
    hosts: Vec<Arc<HostSlot>>,
    cfg: RouterConfig,
    next_id: AtomicU64,
    next_seq: AtomicU64,
    readers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Router {
    /// Connect to every host address. Fails if ANY host is unreachable —
    /// a router that silently started degraded would skew placement.
    pub fn connect<A: ToSocketAddrs + std::fmt::Display>(
        addrs: &[A],
        cfg: RouterConfig,
    ) -> io::Result<Router> {
        let mut hosts = Vec::with_capacity(addrs.len());
        let mut readers = Vec::with_capacity(addrs.len());
        for addr in addrs {
            let stream = TcpStream::connect(addr)?;
            stream.set_nodelay(true)?;
            let reader_stream = stream.try_clone()?;
            let slot = Arc::new(HostSlot {
                addr: addr.to_string(),
                alive: AtomicBool::new(true),
                writer: Mutex::new(stream),
                inflight: Mutex::new(HashMap::new()),
                health: Mutex::new(HostHealth::default()),
            });
            let slot2 = Arc::clone(&slot);
            readers.push(std::thread::spawn(move || router_read_loop(reader_stream, &slot2)));
            hosts.push(slot);
        }
        Ok(Router { hosts, cfg, next_id: AtomicU64::new(0), next_seq: AtomicU64::new(0), readers: Mutex::new(readers) })
    }

    pub fn n_hosts(&self) -> usize {
        self.hosts.len()
    }

    /// Hosts whose connection is currently up.
    pub fn live_hosts(&self) -> usize {
        self.hosts.iter().filter(|h| h.alive.load(Ordering::Relaxed)).count()
    }

    /// Last reported health per host (`None` for dead hosts).
    pub fn host_health(&self) -> Vec<Option<HostHealth>> {
        self.hosts
            .iter()
            .map(|h| {
                h.alive
                    .load(Ordering::Relaxed)
                    .then(|| h.health.lock().unwrap().clone())
            })
            .collect()
    }

    /// The placement probe sequence for a variant: home host first
    /// (`shard_for` over the FULL host list, so placement is stable
    /// across loss), then successors mod N — the first LIVE entry wins.
    /// Deterministic, so re-homing needs no coordination state.
    fn probe_order(&self, variant_key: &str) -> impl Iterator<Item = usize> + '_ {
        let n = self.hosts.len();
        let home = shard_for(variant_key, n.max(1));
        (0..n).map(move |i| (home + i) % n)
    }

    /// Router-side admission against the target host (see
    /// [`estimated_host_wait_us`]). `Ok` on cold stats, missing health,
    /// or no deadline — the host's own admission gate still applies.
    fn admit(&self, host: &HostSlot, variant_key: &str, deadline: Duration) -> Result<(), ServeError> {
        let AdmissionControl::DeadlineAware { min_samples } = self.cfg.admission else {
            return Ok(());
        };
        let mut counts: HashMap<String, u64> = HashMap::new();
        for inflight in host.inflight.lock().unwrap().values() {
            *counts.entry(inflight.variant.clone()).or_insert(0) += 1;
        }
        if counts.is_empty() {
            return Ok(());
        }
        let inflight: Vec<(String, u64)> = counts.into_iter().collect();
        let health = host.health.lock().unwrap().clone();
        let est_us = match estimated_host_wait_us(
            &inflight,
            &health.rates,
            variant_key,
            min_samples,
            health.live_workers as usize,
        ) {
            Some(est) => est,
            None => return Ok(()),
        };
        let deadline_us = deadline.as_secs_f64() * 1e6;
        if est_us > deadline_us {
            let depth: u64 = inflight.iter().map(|(_, c)| c).sum();
            return Err(ServeError::Overloaded {
                queue_depth: depth as usize,
                estimated_wait: Duration::from_micros(est_us as u64),
                retry_after_us: ((est_us - deadline_us).max(1.0)) as u64,
            });
        }
        Ok(())
    }

    /// Route one request: place by variant hash, shed at the front door
    /// if the target host's estimate implies a deadline miss, then write
    /// the frame — falling through the probe sequence on dead hosts.
    pub fn submit_async(&self, req: ServeRequest) -> Result<ResponseHandle, ServeError> {
        let variant_key = match &req.variant {
            VariantSelector::Named(name) => name.clone(),
            VariantSelector::Default => String::new(),
        };
        // Admission prices the HOME host (the first live probe) before a
        // seq is consumed, mirroring the in-process order: a shed
        // request never perturbs the noise-stream sequence.
        let target = self
            .probe_order(&variant_key)
            .find(|&i| self.hosts[i].alive.load(Ordering::Relaxed));
        let Some(target) = target else {
            return Err(ServeError::Stopped);
        };
        if let Some(d) = req.deadline {
            self.admit(&self.hosts[target], &variant_key, d)?;
        }
        let seq = self.next_seq.fetch_add(1, Ordering::Relaxed);
        let frame_req = req;
        // Probe from the target onward (skipping the liveness re-check on
        // the first): a write failure marks the host dead, drains it, and
        // re-homes THIS request to the next live host.
        let n = self.hosts.len();
        let start = target;
        for step in 0..n {
            let i = (start + step) % n;
            let host = &self.hosts[i];
            if !host.alive.load(Ordering::Relaxed) {
                continue;
            }
            let id = self.next_id.fetch_add(1, Ordering::Relaxed);
            let (tx, rx) = channel();
            host.inflight
                .lock()
                .unwrap()
                .insert(id, Inflight { variant: variant_key.clone(), tx });
            let frame = Frame::Request { id, seq, req: frame_req.clone() };
            let ok = {
                let mut w = host.writer.lock().unwrap();
                write_frame(&mut *w, &frame).is_ok()
            };
            if ok {
                return Ok(ResponseHandle::new(rx));
            }
            // Remove our own entry first so the retry doesn't receive
            // this host's WorkerDropped, then drain the rest.
            host.inflight.lock().unwrap().remove(&id);
            host.drain_dead();
        }
        Err(ServeError::Stopped)
    }

    /// Route and block for the response.
    pub fn submit(&self, req: ServeRequest) -> Result<ServeResponse, ServeError> {
        self.submit_async(req)?.wait()
    }

    /// Ask every live host to retire workers down to `target` — the
    /// worker-loss drill across the wire.
    pub fn broadcast_shrink(&self, target: usize) {
        for host in &self.hosts {
            if !host.alive.load(Ordering::Relaxed) {
                continue;
            }
            let mut w = host.writer.lock().unwrap();
            if write_frame(&mut *w, &Frame::Shrink { target: target as u32 }).is_err() {
                drop(w);
                host.drain_dead();
            }
        }
    }

    /// Sum of live hosts' last-reported live workers (floored at the
    /// number of live hosts — a connected host serves with ≥1 worker).
    pub fn live_workers(&self) -> usize {
        let mut total = 0usize;
        let mut live = 0usize;
        for host in &self.hosts {
            if host.alive.load(Ordering::Relaxed) {
                live += 1;
                total += host.health.lock().unwrap().live_workers as usize;
            }
        }
        total.max(live)
    }

    /// Sever every connection and fail all in-flight requests with typed
    /// errors. Hosts are NOT shut down — they belong to their processes.
    pub fn shutdown(&self) {
        for host in &self.hosts {
            {
                let w = host.writer.lock().unwrap();
                let _ = w.shutdown(Shutdown::Both);
            }
            host.drain_dead();
        }
        let readers: Vec<_> = self.readers.lock().unwrap().drain(..).collect();
        for r in readers {
            let _ = r.join();
        }
    }

    /// The address list, with liveness (for reporting).
    pub fn host_addrs(&self) -> Vec<(String, bool)> {
        self.hosts
            .iter()
            .map(|h| (h.addr.clone(), h.alive.load(Ordering::Relaxed)))
            .collect()
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Router's per-host reader: completes in-flight requests and absorbs
/// health. EOF or any wire error ⇒ the host is lost — drain with typed
/// errors so no caller ever hangs on a dead host.
fn router_read_loop(mut stream: TcpStream, slot: &HostSlot) {
    let mut fr = FrameReader::new();
    let mut chunk = [0u8; 16 * 1024];
    loop {
        match fr.next_frame() {
            Ok(Some(frame)) => {
                match frame {
                    Frame::Response { id, rsp, health } => {
                        *slot.health.lock().unwrap() = health;
                        if let Some(inflight) = slot.inflight.lock().unwrap().remove(&id) {
                            let _ = inflight.tx.send(Ok(rsp));
                        }
                    }
                    Frame::Error { id, err, health } => {
                        *slot.health.lock().unwrap() = health;
                        if let Some(inflight) = slot.inflight.lock().unwrap().remove(&id) {
                            let _ = inflight.tx.send(Err(err));
                        }
                    }
                    Frame::Health(health) => {
                        *slot.health.lock().unwrap() = health;
                    }
                    // Request/Ping/Shrink only flow router → host.
                    Frame::Request { .. } | Frame::Ping | Frame::Shrink { .. } => {
                        slot.drain_dead();
                        return;
                    }
                }
                continue;
            }
            Ok(None) => {}
            Err(_) => {
                slot.drain_dead();
                return;
            }
        }
        match stream.read(&mut chunk) {
            Ok(0) => {
                slot.drain_dead();
                return;
            }
            Ok(n) => fr.extend(&chunk[..n]),
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(_) => {
                slot.drain_dead();
                return;
            }
        }
    }
}

// ---------------------------------------------------------- local cluster

/// N loopback [`WireHost`]s plus a connected [`Router`] in one process —
/// the unit the fleet's `--hosts` mode and the `multi_host` bench drive.
/// Every byte still crosses real TCP sockets; only process isolation is
/// elided (the `route` CLI subcommand spawns true child processes).
pub struct LocalCluster {
    hosts: Mutex<Vec<Option<WireHost>>>,
    pub router: Router,
}

impl LocalCluster {
    pub fn spawn(
        registry: Arc<ModelRegistry>,
        cfg: ServeConfig,
        n_hosts: usize,
        router_cfg: RouterConfig,
    ) -> io::Result<LocalCluster> {
        let hosts: Vec<WireHost> = (0..n_hosts.max(1))
            .map(|_| WireHost::spawn(Arc::clone(&registry), cfg.clone(), "127.0.0.1:0"))
            .collect::<io::Result<_>>()?;
        let addrs: Vec<String> = hosts.iter().map(|h| h.addr().to_string()).collect();
        let router = Router::connect(&addrs, router_cfg)?;
        Ok(LocalCluster { hosts: Mutex::new(hosts.into_iter().map(Some).collect()), router })
    }

    /// Kill one live host (never the last), returning its address — the
    /// `host-loss` drill primitive. The router observes the connection
    /// drop and re-homes the host's variants.
    pub fn kill_host(&self) -> Option<String> {
        let mut hosts = self.hosts.lock().unwrap();
        if hosts.iter().filter(|h| h.is_some()).count() < 2 {
            return None;
        }
        // Kill the highest-index live host: deterministic, and the
        // re-homed variants spread over the remaining prefix.
        let idx = hosts.iter().rposition(|h| h.is_some())?;
        let host = hosts[idx].take()?;
        let addr = host.addr().to_string();
        host.shutdown();
        Some(addr)
    }

    pub fn live_hosts(&self) -> usize {
        self.hosts.lock().unwrap().iter().filter(|h| h.is_some()).count()
    }

    pub fn shutdown(&self) {
        self.router.shutdown();
        for host in self.hosts.lock().unwrap().iter_mut() {
            if let Some(h) = host.take() {
                h.shutdown();
            }
        }
    }
}

impl Drop for LocalCluster {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_wait_estimate_prices_inflight_at_host_rates() {
        let rates = vec![
            ("fast".to_string(), 25.0, 100u64),
            ("slow".to_string(), 400.0, 100u64),
            ("cold".to_string(), 9999.0, 2u64),
        ];
        // Cold own variant (insufficient samples): admit unconditionally.
        assert_eq!(
            estimated_host_wait_us(&[("cold".into(), 8)], &rates, "cold", 16, 2),
            None
        );
        // Warm: each in-flight variant priced at its own rate, divided by
        // live workers; cold co-tenants priced at the requester's rate.
        let inflight =
            vec![("fast".to_string(), 8u64), ("slow".to_string(), 2), ("cold".to_string(), 4)];
        let est = estimated_host_wait_us(&inflight, &rates, "fast", 16, 2).unwrap();
        assert_eq!(est, (8.0 * 25.0 + 2.0 * 400.0 + 4.0 * 25.0) / 2.0);
        // Worker divisor clamps at 1.
        let est1 = estimated_host_wait_us(&[("fast".into(), 4)], &rates, "fast", 16, 0).unwrap();
        assert_eq!(est1, 100.0);
    }

    #[test]
    fn probe_order_rehomes_deterministically() {
        // Placement is shard_for over the FULL host list; liveness only
        // filters the probe sequence. We exercise the pure pieces here —
        // the live re-homing path is pinned in tests/multi_host.rs.
        let n = 4;
        let home = shard_for("hbvla-packed", n);
        let order: Vec<usize> = (0..n).map(|i| (home + i) % n).collect();
        assert_eq!(order.len(), 4);
        assert_eq!(order[0], home);
        let unique: std::collections::HashSet<_> = order.iter().collect();
        assert_eq!(unique.len(), 4, "probe order must cover every host once");
    }
}
