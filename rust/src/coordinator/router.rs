//! Multi-host serving: N [`PolicyServer`] processes ("hosts") behind one
//! [`Router`] front door, speaking the length-prefixed binary protocol of
//! [`crate::coordinator::wire`] over `std::net` TCP.
//!
//! Placement reuses [`shard_for`] — the same FNV-1a hash that routes
//! variants to in-process shards routes them to hosts, so same-variant
//! traffic coalesces on its home host and rides the host's own
//! variant-affine shards from there (two levels of the same hash). When a
//! host dies, its variants re-home deterministically along the probe
//! sequence `home, home+1, …` over the surviving hosts — no rendezvous
//! state to reconcile, just the hash re-evaluated against liveness.
//!
//! Admission is host-aware: every response/error piggybacks a
//! [`HostHealth`] snapshot (queue depth, live workers, observed
//! per-variant service rates), and the router prices a deadline request
//! against its TARGET host — the router's own in-flight counts for that
//! host, priced at the host's reported rates, divided by the host's live
//! workers ([`estimated_host_wait_us`], pure and unit-testable). In-flight
//! counts are router-local, so the estimate is fresh even when health
//! snapshots lag (single-front-door topology; multiple routers would each
//! see only their own contribution).
//!
//! Failure semantics — the serving plane self-heals. A lost connection
//! marks the host dead and fails its in-flight work over to the next
//! live replica of each request's variant (same seq — see below), or
//! with a typed [`ServeError::WorkerDropped`] when no replica exists —
//! never a hang. A reconnect supervisor keeps re-dialing every dead
//! address with deterministic per-(host, attempt) jittered exponential
//! backoff (the same splitmix discipline as the fleet's robot retries);
//! a successful re-dial re-arms the slot after a `Hello` handshake
//! (protocol version + host identity) that rejects mismatched or stale
//! peers with a typed [`WireError`] instead of decoding garbage. A host
//! that receives a malformed frame drops that CONNECTION and keeps
//! serving others; the router treats its end of the drop identically to
//! a host loss.
//!
//! Replication: [`RouterConfig::replicas`] = r places each variant on
//! its home host plus the next r-1 probe-order hosts; submissions pick
//! the least-loaded live replica (router-local in-flight depth priced at
//! the host's reported service rate), and failover re-submits to the
//! next live, untried replica REUSING the router-minted seq — so a
//! failed-over decode is bit-identical to the no-fault run.
//!
//! Bit-parity carries across the wire: the router owns the global
//! submission `seq` (the noise-stream id) and transmits it in each
//! Request frame, and observations/actions travel as IEEE-754 bit
//! patterns — so actions served through the router are bit-identical to
//! the direct in-process forward for EVERY host count, pinned by
//! `tests/multi_host.rs`.

use std::collections::HashMap;
use std::io::{self, Read};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::registry::ModelRegistry;
use crate::coordinator::server::{
    per_request_service_us, AdmissionControl, PolicyServer, ResponseHandle, ServeConfig,
    ServeError, ServeRequest, ServeResponse, VariantSelector,
};
use crate::coordinator::shard::shard_for;
use crate::coordinator::wire::{
    write_frame, Frame, FrameReader, HostHealth, WireError, PROTOCOL_VERSION,
};
use crate::util::rng::backoff_jitter_us;

/// How often host-side socket loops re-check the stop flag while idle.
const HOST_POLL: Duration = Duration::from_millis(5);
/// Host writer idle sleep between pending-handle scans.
const WRITER_IDLE: Duration = Duration::from_micros(100);
/// Initial-dial retry budget: `route` child processes race their bind,
/// so `Router::connect` retries each address with bounded backoff
/// instead of failing fast on the first refused connection.
const DIAL_ATTEMPTS: u32 = 30;
const DIAL_BASE_US: u64 = 2_000;
const DIAL_CAP_US: u64 = 200_000;
/// Re-dial (dead-slot reconnect) backoff schedule — slower than the
/// initial dial: a dead host is expected to stay dead for a while.
const REDIAL_BASE_US: u64 = 10_000;
const REDIAL_CAP_US: u64 = 500_000;
/// Reconnect-supervisor scan period (it only dials when a dead slot's
/// backoff deadline has passed).
const RECONNECT_POLL: Duration = Duration::from_millis(2);
/// How long the handshake waits for the peer's Hello before rejecting
/// it typed — a silent or garbage peer must not wedge a dial.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(2);

/// Exponential backoff base for `attempt`, capped.
fn backoff_us(attempt: u32, base: u64, cap: u64) -> u64 {
    (base << attempt.min(16)).min(cap)
}

// ------------------------------------------------------------------ host

/// Process-wide host-identity counter: every spawned [`WireHost`] gets a
/// distinct id even when several live in one process (LocalCluster).
static HOST_SEQ: AtomicU64 = AtomicU64::new(0);

/// Mint a host identity: a splitmix-style mix of (pid, per-process
/// counter), so ids are unique across `route` child processes AND across
/// in-process respawns of the same address — a restarted host presents a
/// NEW identity, which is how the router tells a rejoin from a stale
/// connection.
fn mint_host_id() -> u64 {
    let raw = ((std::process::id() as u64) << 32) | HOST_SEQ.fetch_add(1, Ordering::Relaxed);
    let mut z = raw.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Build a host's health snapshot from its server's public telemetry.
fn health_of(server: &PolicyServer) -> HostHealth {
    let mut rates: Vec<(String, f64, u64)> = server
        .variant_stats()
        .into_iter()
        .map(|(name, v)| {
            let rate = per_request_service_us(v.compute.mean_us(), v.batches.mean());
            (name, rate, v.compute.count() as u64)
        })
        .collect();
    rates.sort_by(|a, b| a.0.cmp(&b.0));
    HostHealth {
        depth: server.queue_depth() as u64,
        live_workers: server.live_workers() as u32,
        pending: server.pending_by_variant(),
        rates,
    }
}

/// Per-connection state shared between a host's reader and writer thread.
struct ConnShared {
    alive: AtomicBool,
    /// Routed requests in flight on the local server: `(wire id, handle)`.
    pending: Mutex<Vec<(u64, ResponseHandle)>>,
    /// Frames to send immediately (submit errors, health replies).
    outbox: Mutex<Vec<Frame>>,
}

/// One `PolicyServer` process behind a TCP accept loop — the "host" half
/// of multi-host serving. In production each host is its own process
/// (`serve --listen`); tests and the loopback bench spawn several in one
/// process, which exercises the identical socket path.
pub struct WireHost {
    server: Arc<PolicyServer>,
    addr: SocketAddr,
    host_id: u64,
    stop: Arc<AtomicBool>,
    threads: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl WireHost {
    /// Bind `addr` (use port 0 to auto-assign) and serve the registry
    /// through `cfg`. Returns once the listener is live.
    pub fn spawn(
        registry: Arc<ModelRegistry>,
        cfg: ServeConfig,
        addr: &str,
    ) -> io::Result<WireHost> {
        let server = Arc::new(PolicyServer::start(registry, cfg));
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let threads = Mutex::new(Vec::new());
        let host_id = mint_host_id();
        let host = WireHost { server, addr, host_id, stop, threads };
        let server = Arc::clone(&host.server);
        let stop_flag = Arc::clone(&host.stop);
        let accept = std::thread::spawn(move || {
            let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
            while !stop_flag.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        let server = Arc::clone(&server);
                        let stop = Arc::clone(&stop_flag);
                        conns.push(std::thread::spawn(move || {
                            serve_connection(stream, &server, &stop, host_id);
                        }));
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                        std::thread::sleep(HOST_POLL);
                    }
                    Err(_) => std::thread::sleep(HOST_POLL),
                }
            }
            for c in conns {
                let _ = c.join();
            }
        });
        host.threads.lock().unwrap().push(accept);
        Ok(host)
    }

    /// The bound listen address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Direct access to the underlying server (loopback tests/benches).
    pub fn server(&self) -> &PolicyServer {
        &self.server
    }

    /// This host's wire identity (greeted in the Hello handshake).
    pub fn host_id(&self) -> u64 {
        self.host_id
    }

    /// Stop accepting, tear down live connections (their in-flight
    /// requests surface router-side as [`ServeError::WorkerDropped`]),
    /// and shut the server down. Idempotent.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Relaxed);
        let threads: Vec<_> = self.threads.lock().unwrap().drain(..).collect();
        for t in threads {
            let _ = t.join();
        }
        self.server.shutdown();
    }
}

impl Drop for WireHost {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Host side of one client connection: a reader (frames in → local
/// submissions) paired with a writer (completed handles → frames out).
/// A wire error drops THIS connection only — the host keeps serving.
fn serve_connection(
    stream: TcpStream,
    server: &Arc<PolicyServer>,
    stop: &Arc<AtomicBool>,
    host_id: u64,
) {
    let _ = stream.set_nodelay(true);
    // Greet FIRST with the handshake (protocol version + host identity),
    // then the health snapshot — the router rejects anything else.
    let shared = Arc::new(ConnShared {
        alive: AtomicBool::new(true),
        pending: Mutex::new(Vec::new()),
        outbox: Mutex::new(vec![
            Frame::Hello { version: PROTOCOL_VERSION, host_id },
            Frame::Health(health_of(server)),
        ]),
    });
    let writer = {
        let stream = match stream.try_clone() {
            Ok(s) => s,
            Err(_) => return,
        };
        let shared = Arc::clone(&shared);
        let server = Arc::clone(server);
        let stop = Arc::clone(stop);
        std::thread::spawn(move || write_loop(stream, &shared, &server, &stop))
    };
    read_loop(stream, &shared, server, stop);
    shared.alive.store(false, Ordering::Relaxed);
    let _ = writer.join();
}

fn read_loop(
    mut stream: TcpStream,
    shared: &ConnShared,
    server: &Arc<PolicyServer>,
    stop: &AtomicBool,
) {
    let _ = stream.set_read_timeout(Some(HOST_POLL));
    let mut fr = FrameReader::new();
    let mut chunk = [0u8; 16 * 1024];
    while !stop.load(Ordering::Relaxed) && shared.alive.load(Ordering::Relaxed) {
        match fr.next_frame() {
            Ok(Some(frame)) => {
                if !handle_client_frame(frame, shared, server) {
                    break;
                }
                continue;
            }
            Ok(None) => {}
            // Malformed bytes: framing is lost — drop the connection
            // (typed locally; the router sees the drop as host loss for
            // this link). The host itself survives.
            Err(_) => break,
        }
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => fr.extend(&chunk[..n]),
            Err(e)
                if e.kind() == io::ErrorKind::WouldBlock
                    || e.kind() == io::ErrorKind::TimedOut
                    || e.kind() == io::ErrorKind::Interrupted => {}
            Err(_) => break,
        }
    }
    shared.alive.store(false, Ordering::Relaxed);
}

/// Returns `false` on a protocol violation (connection must drop).
fn handle_client_frame(frame: Frame, shared: &ConnShared, server: &Arc<PolicyServer>) -> bool {
    match frame {
        Frame::Request { id, seq, req } => {
            // The router-assigned seq IS the noise-stream id — the host
            // must not mint its own, or parity would depend on placement.
            match server.submit_async_with_seq(req, seq) {
                Ok(handle) => shared.pending.lock().unwrap().push((id, handle)),
                Err(err) => shared
                    .outbox
                    .lock()
                    .unwrap()
                    .push(Frame::Error { id, err, health: health_of(server) }),
            }
            true
        }
        Frame::Ping => {
            shared.outbox.lock().unwrap().push(Frame::Health(health_of(server)));
            true
        }
        Frame::Shrink { target } => {
            server.shrink_workers(target as usize);
            true
        }
        // Response/Error/Health only flow host → router, and Hello only
        // host → client: a client greeting US is a confused peer.
        Frame::Response { .. } | Frame::Error { .. } | Frame::Health(_) | Frame::Hello { .. } => {
            false
        }
    }
}

fn write_loop(
    mut stream: TcpStream,
    shared: &ConnShared,
    server: &Arc<PolicyServer>,
    stop: &AtomicBool,
) {
    loop {
        let stopping = stop.load(Ordering::Relaxed) || !shared.alive.load(Ordering::Relaxed);
        let mut wrote = false;
        let outbox: Vec<Frame> = shared.outbox.lock().unwrap().drain(..).collect();
        for frame in &outbox {
            if write_frame(&mut stream, frame).is_err() {
                shared.alive.store(false, Ordering::Relaxed);
                return;
            }
            wrote = true;
        }
        // Completed local requests → response/error frames with a fresh
        // health piggyback. Scan in place; order on the wire is
        // completion order, the router correlates by id.
        let done: Vec<(u64, Result<ServeResponse, ServeError>)> = {
            let mut pending = shared.pending.lock().unwrap();
            let mut done = Vec::new();
            let mut i = 0;
            while i < pending.len() {
                match pending[i].1.try_wait() {
                    Some(result) => {
                        let (id, _) = pending.swap_remove(i);
                        done.push((id, result));
                    }
                    None => i += 1,
                }
            }
            done
        };
        for (id, result) in done {
            let health = health_of(server);
            let frame = match result {
                Ok(rsp) => Frame::Response { id, rsp, health },
                Err(err) => Frame::Error { id, err, health },
            };
            if write_frame(&mut stream, &frame).is_err() {
                shared.alive.store(false, Ordering::Relaxed);
                return;
            }
            wrote = true;
        }
        if stopping {
            // Final drain done (best effort); sever the link so the
            // router's reader unblocks immediately.
            let _ = stream.shutdown(Shutdown::Both);
            return;
        }
        if !wrote {
            std::thread::sleep(WRITER_IDLE);
        }
    }
}

// ---------------------------------------------------------------- router

/// The routed admission estimate against ONE host: the router's own
/// in-flight mix on that host, priced at the host's reported per-variant
/// service rates, divided by the host's live workers. Cold stats admit:
/// returns `None` until the REQUEST's variant has `min_samples` served
/// requests in the host's rate table (cold co-tenants in the mix are
/// priced at the requester's rate, mirroring in-process admission).
pub fn estimated_host_wait_us(
    inflight: &[(String, u64)],
    rates: &[(String, f64, u64)],
    variant: &str,
    min_samples: u64,
    live_workers: usize,
) -> Option<f64> {
    let own_rate = rates
        .iter()
        .find(|(name, _, samples)| name == variant && *samples >= min_samples)
        .map(|(_, rate, _)| *rate)?;
    let total: f64 = inflight
        .iter()
        .map(|(name, count)| {
            let rate = rates
                .iter()
                .find(|(n, _, samples)| n == name && *samples >= min_samples)
                .map(|(_, r, _)| *r)
                .unwrap_or(own_rate);
            *count as f64 * rate
        })
        .sum();
    Some(total / live_workers.max(1) as f64)
}

#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Deadline-aware admission at the front door, priced against the
    /// target host (same policy enum as the in-process server).
    pub admission: AdmissionControl,
    /// How many hosts serve each variant: its home host plus the next
    /// `replicas - 1` along the probe order (clamped to the cluster
    /// size). 1 — the default — is PR-9 single placement; higher values
    /// enable transparent per-request failover when a replica drops a
    /// request mid-flight.
    pub replicas: usize,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig { admission: AdmissionControl::default(), replicas: 1 }
    }
}

struct Inflight {
    variant: String,
    /// The router-minted noise-stream id — REUSED verbatim on failover,
    /// so a re-submitted request decodes bit-identically to the no-fault
    /// run.
    seq: u64,
    /// The original request, retained so a dropped host's in-flight work
    /// can be re-submitted to the next live replica.
    req: ServeRequest,
    /// Host indices this request was already written to (failover never
    /// revisits one).
    tried: Vec<usize>,
    tx: Sender<Result<ServeResponse, ServeError>>,
}

/// Progress-mark sentinel for "never happened".
const SEQ_NEVER: u64 = u64::MAX;

struct HostSlot {
    addr: String,
    alive: AtomicBool,
    /// Peer identity from the Hello handshake (changes when the host
    /// process restarts — how a rejoin is told apart from a stale peer).
    host_id: AtomicU64,
    writer: Mutex<TcpStream>,
    inflight: Mutex<HashMap<u64, Inflight>>,
    health: Mutex<HostHealth>,
    /// Dial attempts against this address, failures included (initial
    /// connect + every reconnect probe).
    dial_attempts: AtomicU64,
    /// Successful re-dials after a death — the rejoin count.
    redials: AtomicU64,
    /// Requests this host dropped that were failed over to a replica.
    failovers: AtomicU64,
    /// Progress marks: the global seq counter's value when this host
    /// last died / last rejoined ([`SEQ_NEVER`] = never).
    last_death_seq: AtomicU64,
    last_rejoin_seq: AtomicU64,
}

impl HostSlot {
    fn fresh(addr: String, stream: TcpStream, host_id: u64, dial_attempts: u64) -> HostSlot {
        HostSlot {
            addr,
            alive: AtomicBool::new(true),
            host_id: AtomicU64::new(host_id),
            writer: Mutex::new(stream),
            inflight: Mutex::new(HashMap::new()),
            health: Mutex::new(HostHealth::default()),
            dial_attempts: AtomicU64::new(dial_attempts),
            redials: AtomicU64::new(0),
            failovers: AtomicU64::new(0),
            last_death_seq: AtomicU64::new(SEQ_NEVER),
            last_rejoin_seq: AtomicU64::new(SEQ_NEVER),
        }
    }
}

/// Per-host self-healing counters, for summaries and the bench JSON.
#[derive(Clone, Debug)]
pub struct HostCounters {
    pub addr: String,
    pub alive: bool,
    pub dial_attempts: u64,
    pub redials: u64,
    pub failovers: u64,
    /// Global-seq progress marks of the last death / rejoin (`None` =
    /// never happened).
    pub last_death_seq: Option<u64>,
    pub last_rejoin_seq: Option<u64>,
}

/// Read the peer's greeting: the FIRST frame must be a
/// [`Frame::Hello`] with our protocol version. Returns the peer's host
/// identity plus the [`FrameReader`] holding whatever arrived behind the
/// Hello (typically the greeting Health frame) — the reader thread picks
/// up from there, so no bytes are lost. A silent, closing, or
/// wrong-version peer fails typed; the read timeout is cleared before
/// returning.
fn expect_hello(stream: &TcpStream) -> Result<(u64, FrameReader), WireError> {
    stream.set_read_timeout(Some(HANDSHAKE_TIMEOUT))?;
    let mut fr = FrameReader::new();
    let mut chunk = [0u8; 4096];
    let result = loop {
        match fr.next_frame() {
            Ok(Some(Frame::Hello { version, host_id })) => {
                if version == PROTOCOL_VERSION {
                    break Ok((host_id, fr));
                }
                break Err(WireError::VersionMismatch { peer: version, local: PROTOCOL_VERSION });
            }
            Ok(Some(_)) => {
                break Err(WireError::BadHandshake { context: "first frame was not hello" })
            }
            Ok(None) => {}
            Err(e) => break Err(e),
        }
        match (&*stream).read(&mut chunk) {
            Ok(0) => break Err(WireError::BadHandshake { context: "peer closed before hello" }),
            Ok(n) => fr.extend(&chunk[..n]),
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e)
                if e.kind() == io::ErrorKind::WouldBlock
                    || e.kind() == io::ErrorKind::TimedOut =>
            {
                break Err(WireError::BadHandshake { context: "no hello before timeout" })
            }
            Err(e) => break Err(e.into()),
        }
    };
    let _ = stream.set_read_timeout(None);
    result
}

/// One dial + handshake against a host address. Handshake failures
/// (silent peer, version mismatch, non-Hello greeting) surface as
/// `InvalidData` io errors carrying the typed [`WireError`].
fn dial_and_greet(addr: &str) -> io::Result<(TcpStream, u64, FrameReader)> {
    let stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true)?;
    let (host_id, fr) =
        expect_hello(&stream).map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e))?;
    Ok((stream, host_id, fr))
}

/// The replica window: the `replicas` probe-order positions starting at
/// `home` (clamped to the cluster size). Pure, so the placement math is
/// unit-testable without sockets.
fn replica_window_of(home: usize, n: usize, replicas: usize) -> Vec<usize> {
    let n = n.max(1);
    (0..replicas.clamp(1, n)).map(|i| (home + i) % n).collect()
}

/// Shared core behind [`Router`]: the host slots plus everything the
/// reader threads and the reconnect supervisor need to self-heal without
/// borrowing the `Router` itself.
struct RouterShared {
    hosts: Vec<Arc<HostSlot>>,
    cfg: RouterConfig,
    next_id: AtomicU64,
    next_seq: AtomicU64,
    stop: AtomicBool,
    readers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl RouterShared {
    /// The placement probe sequence for a variant: home host first
    /// (`shard_for` over the FULL host list, so placement is stable
    /// across loss), then successors mod N — the first LIVE entry wins.
    /// Deterministic, so re-homing needs no coordination state.
    fn probe_order(&self, variant_key: &str) -> impl Iterator<Item = usize> + '_ {
        let n = self.hosts.len();
        let home = shard_for(variant_key, n.max(1));
        (0..n).map(move |i| (home + i) % n)
    }

    /// The hosts a variant is replicated on (its home plus the next
    /// `replicas - 1` along the probe order).
    fn replica_window(&self, variant_key: &str) -> Vec<usize> {
        let n = self.hosts.len();
        replica_window_of(shard_for(variant_key, n.max(1)), n, self.cfg.replicas)
    }

    /// Pick the submission target: the least-loaded LIVE replica, scored
    /// as router-local in-flight depth × the host's reported service rate
    /// for this variant ÷ its live workers. Rates only enter when EVERY
    /// candidate has one (consistent units); ties break toward the
    /// earlier probe position, so a single-replica or cold cluster
    /// degrades to exactly the PR-9 home-first placement. When the whole
    /// window is dead, falls back to the first live host anywhere on the
    /// probe sequence (re-homing).
    fn best_replica(&self, variant_key: &str) -> Option<usize> {
        let live: Vec<usize> = self
            .replica_window(variant_key)
            .into_iter()
            .filter(|&i| self.hosts[i].alive.load(Ordering::Relaxed))
            .collect();
        match live.len() {
            0 => self.probe_order(variant_key).find(|&i| self.hosts[i].alive.load(Ordering::Relaxed)),
            1 => Some(live[0]),
            _ => {
                let rates: Vec<Option<f64>> = live
                    .iter()
                    .map(|&i| {
                        let h = self.hosts[i].health.lock().unwrap();
                        h.rates
                            .iter()
                            .find(|(name, _, samples)| name == variant_key && *samples > 0)
                            .map(|(_, rate, _)| *rate)
                    })
                    .collect();
                let all_warm = rates.iter().all(|r| r.is_some());
                let mut best = live[0];
                let mut best_score = f64::INFINITY;
                for (k, &i) in live.iter().enumerate() {
                    let host = &self.hosts[i];
                    let depth = host.inflight.lock().unwrap().len() as f64;
                    let rate = if all_warm { rates[k].unwrap() } else { 1.0 };
                    let workers = host.health.lock().unwrap().live_workers.max(1) as f64;
                    let score = depth * rate / workers;
                    // Strict `<` keeps the FIRST minimal candidate — the
                    // earlier probe position — on ties (`Iterator::min_by`
                    // would keep the last).
                    if score < best_score {
                        best_score = score;
                        best = i;
                    }
                }
                Some(best)
            }
        }
    }

    /// Router-side admission against the target host (see
    /// [`estimated_host_wait_us`]). `Ok` on cold stats, missing health,
    /// or no deadline — the host's own admission gate still applies.
    fn admit(&self, host: &HostSlot, variant_key: &str, deadline: Duration) -> Result<(), ServeError> {
        let AdmissionControl::DeadlineAware { min_samples } = self.cfg.admission else {
            return Ok(());
        };
        let mut counts: HashMap<String, u64> = HashMap::new();
        for inflight in host.inflight.lock().unwrap().values() {
            *counts.entry(inflight.variant.clone()).or_insert(0) += 1;
        }
        if counts.is_empty() {
            return Ok(());
        }
        let inflight: Vec<(String, u64)> = counts.into_iter().collect();
        let health = host.health.lock().unwrap().clone();
        let est_us = match estimated_host_wait_us(
            &inflight,
            &health.rates,
            variant_key,
            min_samples,
            health.live_workers as usize,
        ) {
            Some(est) => est,
            None => return Ok(()),
        };
        let deadline_us = deadline.as_secs_f64() * 1e6;
        if est_us > deadline_us {
            let depth: u64 = inflight.iter().map(|(_, c)| c).sum();
            return Err(ServeError::Overloaded {
                queue_depth: depth as usize,
                estimated_wait: Duration::from_micros(est_us as u64),
                retry_after_us: ((est_us - deadline_us).max(1.0)) as u64,
            });
        }
        Ok(())
    }

    /// Route one request: place on the best live replica, shed at the
    /// front door if that host's estimate implies a deadline miss, then
    /// write the frame — falling through the probe sequence on dead
    /// hosts. The seq is minted AFTER admission (a shed never perturbs
    /// the noise stream) and travels with the request through any
    /// failover.
    fn submit_async(&self, req: ServeRequest) -> Result<ResponseHandle, ServeError> {
        let variant_key = match &req.variant {
            VariantSelector::Named(name) => name.clone(),
            VariantSelector::Default => String::new(),
        };
        let Some(target) = self.best_replica(&variant_key) else {
            return Err(ServeError::Stopped);
        };
        if let Some(d) = req.deadline {
            self.admit(&self.hosts[target], &variant_key, d)?;
        }
        let seq = self.next_seq.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = channel();
        let n = self.hosts.len();
        for step in 0..n {
            let i = (target + step) % n;
            let host = &self.hosts[i];
            if !host.alive.load(Ordering::Relaxed) {
                continue;
            }
            let id = self.next_id.fetch_add(1, Ordering::Relaxed);
            host.inflight.lock().unwrap().insert(
                id,
                Inflight {
                    variant: variant_key.clone(),
                    seq,
                    req: req.clone(),
                    tried: vec![i],
                    tx: tx.clone(),
                },
            );
            let frame = Frame::Request { id, seq, req: req.clone() };
            let ok = {
                let mut w = host.writer.lock().unwrap();
                write_frame(&mut *w, &frame).is_ok()
            };
            if ok {
                // The write can land in a socket whose peer died after the
                // death-drain already ran — our entry would be orphaned
                // and the handle would hang. Re-check liveness: if the
                // host died, reclaim our own entry (present ⇒ we still
                // own it, keep probing; absent ⇒ the drain owns it and
                // failover is already queued on this same channel).
                if !host.alive.load(Ordering::Relaxed)
                    && host.inflight.lock().unwrap().remove(&id).is_some()
                {
                    continue;
                }
                return Ok(ResponseHandle::new(rx));
            }
            // Remove our own entry first so the probe retry doesn't
            // receive this host's failover/WorkerDropped, then drain.
            host.inflight.lock().unwrap().remove(&id);
            self.handle_host_death(i);
        }
        Err(ServeError::Stopped)
    }

    /// Mark a host dead (recording the progress mark once per death) and
    /// fail its in-flight work over to live replicas — or with a typed
    /// error when none exist. The zero-hangs half of the re-homing
    /// contract.
    fn handle_host_death(&self, idx: usize) {
        let host = &self.hosts[idx];
        if host.alive.swap(false, Ordering::Relaxed) {
            host.last_death_seq.store(self.next_seq.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        let drained: Vec<Inflight> =
            host.inflight.lock().unwrap().drain().map(|(_, v)| v).collect();
        for inf in drained {
            self.failover_or_fail(idx, inf);
        }
    }

    /// Re-submit a dropped request to the next live, untried replica —
    /// REUSING its seq, so the decode is bit-identical to the no-fault
    /// run — or deliver a typed [`ServeError::WorkerDropped`] when the
    /// window is exhausted (or the router is stopping).
    fn failover_or_fail(&self, from: usize, mut inf: Inflight) {
        loop {
            if self.stop.load(Ordering::Relaxed) {
                let _ = inf.tx.send(Err(ServeError::WorkerDropped));
                return;
            }
            let next = self.replica_window(&inf.variant).into_iter().find(|&i| {
                self.hosts[i].alive.load(Ordering::Relaxed) && !inf.tried.contains(&i)
            });
            let Some(next) = next else {
                let _ = inf.tx.send(Err(ServeError::WorkerDropped));
                return;
            };
            inf.tried.push(next);
            self.hosts[from].failovers.fetch_add(1, Ordering::Relaxed);
            let host = &self.hosts[next];
            let id = self.next_id.fetch_add(1, Ordering::Relaxed);
            host.inflight.lock().unwrap().insert(
                id,
                Inflight {
                    variant: inf.variant.clone(),
                    seq: inf.seq,
                    req: inf.req.clone(),
                    tried: inf.tried.clone(),
                    tx: inf.tx.clone(),
                },
            );
            let frame = Frame::Request { id, seq: inf.seq, req: inf.req.clone() };
            let ok = {
                let mut w = host.writer.lock().unwrap();
                write_frame(&mut *w, &frame).is_ok()
            };
            if ok {
                // Same orphan race as submit: reclaim ⇒ keep failing
                // over; absent ⇒ the new host's drain owns the entry.
                if !host.alive.load(Ordering::Relaxed) {
                    match host.inflight.lock().unwrap().remove(&id) {
                        Some(reclaimed) => {
                            inf = reclaimed;
                            continue;
                        }
                        None => return,
                    }
                }
                return;
            }
            host.inflight.lock().unwrap().remove(&id);
            // Bounded mutual recursion: each level marks a DISTINCT host
            // dead, so depth ≤ n_hosts.
            self.handle_host_death(next);
        }
    }

    /// Re-arm a dead slot with a freshly greeted connection: new writer,
    /// reset health (the peer's greeting Health follows in `fr`), new
    /// identity, counters — and only THEN flip `alive`, so no submission
    /// races a half-armed slot.
    fn rearm_slot(
        self: &Arc<Self>,
        idx: usize,
        stream: TcpStream,
        host_id: u64,
        fr: FrameReader,
    ) -> io::Result<()> {
        if self.stop.load(Ordering::Relaxed) {
            return Ok(());
        }
        let reader_stream = stream.try_clone()?;
        let host = &self.hosts[idx];
        *host.writer.lock().unwrap() = stream;
        *host.health.lock().unwrap() = HostHealth::default();
        host.host_id.store(host_id, Ordering::Relaxed);
        host.redials.fetch_add(1, Ordering::Relaxed);
        host.last_rejoin_seq.store(self.next_seq.load(Ordering::Relaxed), Ordering::Relaxed);
        host.alive.store(true, Ordering::Relaxed);
        let shared = Arc::clone(self);
        let handle =
            std::thread::spawn(move || router_read_loop(reader_stream, &shared, idx, fr));
        self.readers.lock().unwrap().push(handle);
        Ok(())
    }
}

/// The reconnect supervisor: keeps re-dialing every dead slot's address
/// with jittered exponential backoff (deterministic per (host, attempt)
/// — no reconnect stampede), handshakes each success, and re-arms the
/// slot. A peer whose identity matches another LIVE slot is stale
/// (cross-wired address) and is dropped; the dial retries later.
fn reconnect_loop(shared: &Arc<RouterShared>) {
    let n = shared.hosts.len();
    let mut attempts = vec![0u32; n];
    let mut next_try = vec![Instant::now(); n];
    while !shared.stop.load(Ordering::Relaxed) {
        for idx in 0..n {
            let host = &shared.hosts[idx];
            if host.alive.load(Ordering::Relaxed) {
                attempts[idx] = 0;
                continue;
            }
            if Instant::now() < next_try[idx] {
                continue;
            }
            host.dial_attempts.fetch_add(1, Ordering::Relaxed);
            let rearmed = match dial_and_greet(&host.addr) {
                Ok((stream, host_id, fr)) => {
                    let stale = shared.hosts.iter().enumerate().any(|(j, h)| {
                        j != idx
                            && h.alive.load(Ordering::Relaxed)
                            && h.host_id.load(Ordering::Relaxed) == host_id
                    });
                    !stale && shared.rearm_slot(idx, stream, host_id, fr).is_ok()
                }
                Err(_) => false,
            };
            if rearmed {
                attempts[idx] = 0;
            } else {
                attempts[idx] = attempts[idx].saturating_add(1);
                let base = backoff_us(attempts[idx], REDIAL_BASE_US, REDIAL_CAP_US);
                let wait = base + backoff_jitter_us(idx as u64, attempts[idx], base);
                next_try[idx] = Instant::now() + Duration::from_micros(wait);
            }
        }
        std::thread::sleep(RECONNECT_POLL);
    }
}

/// The front door over N hosts. `submit`/`submit_async` mirror
/// [`PolicyServer`]'s API (same [`ResponseHandle`]), so clients and the
/// fleet harness are agnostic to whether they're talking to a process or
/// a cluster.
pub struct Router {
    shared: Arc<RouterShared>,
    supervisor: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Router {
    /// Connect to every host address. Each initial dial retries with the
    /// same bounded jittered backoff as reconnects (`route` children race
    /// their binds), but still fails if ANY host never comes up — a
    /// router that silently started degraded would skew placement. Also
    /// rejects two addresses answering with the SAME host identity
    /// (typed [`WireError::StalePeer`]): that is one host wearing two
    /// slots, which would double its placement weight.
    pub fn connect<A: ToSocketAddrs + std::fmt::Display>(
        addrs: &[A],
        cfg: RouterConfig,
    ) -> io::Result<Router> {
        let mut dialed: Vec<(String, TcpStream, u64, FrameReader, u64)> =
            Vec::with_capacity(addrs.len());
        for (idx, addr) in addrs.iter().enumerate() {
            let addr = addr.to_string();
            let mut attempts: u64 = 0;
            let (stream, host_id, fr) = loop {
                attempts += 1;
                match dial_and_greet(&addr) {
                    Ok(conn) => break conn,
                    Err(e) => {
                        if attempts >= DIAL_ATTEMPTS as u64 {
                            return Err(e);
                        }
                        let base = backoff_us(attempts as u32 - 1, DIAL_BASE_US, DIAL_CAP_US);
                        let wait = base + backoff_jitter_us(idx as u64, attempts as u32, base);
                        std::thread::sleep(Duration::from_micros(wait));
                    }
                }
            };
            if dialed.iter().any(|(_, _, existing, _, _)| *existing == host_id) {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    WireError::StalePeer { host_id },
                ));
            }
            dialed.push((addr, stream, host_id, fr, attempts));
        }
        let mut hosts = Vec::with_capacity(dialed.len());
        let mut reader_parts = Vec::with_capacity(dialed.len());
        for (addr, stream, host_id, fr, attempts) in dialed {
            let reader_stream = stream.try_clone()?;
            hosts.push(Arc::new(HostSlot::fresh(addr, stream, host_id, attempts)));
            reader_parts.push((reader_stream, fr));
        }
        let shared = Arc::new(RouterShared {
            hosts,
            cfg,
            next_id: AtomicU64::new(0),
            next_seq: AtomicU64::new(0),
            stop: AtomicBool::new(false),
            readers: Mutex::new(Vec::new()),
        });
        for (idx, (stream, fr)) in reader_parts.into_iter().enumerate() {
            let sh = Arc::clone(&shared);
            let handle = std::thread::spawn(move || router_read_loop(stream, &sh, idx, fr));
            shared.readers.lock().unwrap().push(handle);
        }
        let supervisor = {
            let sh = Arc::clone(&shared);
            std::thread::spawn(move || reconnect_loop(&sh))
        };
        Ok(Router { shared, supervisor: Mutex::new(Some(supervisor)) })
    }

    pub fn n_hosts(&self) -> usize {
        self.shared.hosts.len()
    }

    /// Hosts whose connection is currently up.
    pub fn live_hosts(&self) -> usize {
        self.shared.hosts.iter().filter(|h| h.alive.load(Ordering::Relaxed)).count()
    }

    /// Last reported health per host (`None` for dead hosts).
    pub fn host_health(&self) -> Vec<Option<HostHealth>> {
        self.shared
            .hosts
            .iter()
            .map(|h| {
                h.alive
                    .load(Ordering::Relaxed)
                    .then(|| h.health.lock().unwrap().clone())
            })
            .collect()
    }

    /// See [`RouterShared::submit_async`].
    pub fn submit_async(&self, req: ServeRequest) -> Result<ResponseHandle, ServeError> {
        self.shared.submit_async(req)
    }

    /// Route and block for the response.
    pub fn submit(&self, req: ServeRequest) -> Result<ServeResponse, ServeError> {
        self.submit_async(req)?.wait()
    }

    /// Ask every live host to retire workers down to `target` — the
    /// worker-loss drill across the wire.
    pub fn broadcast_shrink(&self, target: usize) {
        for (idx, host) in self.shared.hosts.iter().enumerate() {
            if !host.alive.load(Ordering::Relaxed) {
                continue;
            }
            let failed = {
                let mut w = host.writer.lock().unwrap();
                write_frame(&mut *w, &Frame::Shrink { target: target as u32 }).is_err()
            };
            if failed {
                self.shared.handle_host_death(idx);
            }
        }
    }

    /// Sum of live hosts' last-reported live workers (floored at the
    /// number of live hosts — a connected host serves with ≥1 worker).
    pub fn live_workers(&self) -> usize {
        let mut total = 0usize;
        let mut live = 0usize;
        for host in &self.shared.hosts {
            if host.alive.load(Ordering::Relaxed) {
                live += 1;
                total += host.health.lock().unwrap().live_workers as usize;
            }
        }
        total.max(live)
    }

    /// Stop self-healing, sever every connection, and fail all in-flight
    /// requests with typed errors. Ordering matters: the supervisor is
    /// joined FIRST so no slot re-arms after its writer is severed (a
    /// late re-armed reader would block the final join forever). Hosts
    /// are NOT shut down — they belong to their processes.
    pub fn shutdown(&self) {
        self.shared.stop.store(true, Ordering::Relaxed);
        if let Some(sup) = self.supervisor.lock().unwrap().take() {
            let _ = sup.join();
        }
        for (idx, host) in self.shared.hosts.iter().enumerate() {
            {
                let w = host.writer.lock().unwrap();
                let _ = w.shutdown(Shutdown::Both);
            }
            self.shared.handle_host_death(idx);
        }
        let readers: Vec<_> = self.shared.readers.lock().unwrap().drain(..).collect();
        for r in readers {
            let _ = r.join();
        }
    }

    /// The address list with liveness and cumulative dial attempts (for
    /// reporting — `route` prints these per host).
    pub fn host_addrs(&self) -> Vec<(String, bool, u64)> {
        self.shared
            .hosts
            .iter()
            .map(|h| {
                (
                    h.addr.clone(),
                    h.alive.load(Ordering::Relaxed),
                    h.dial_attempts.load(Ordering::Relaxed),
                )
            })
            .collect()
    }

    /// Per-host self-healing counters (summaries + bench JSON).
    pub fn host_counters(&self) -> Vec<HostCounters> {
        self.shared
            .hosts
            .iter()
            .map(|h| {
                let mark = |a: &AtomicU64| {
                    let v = a.load(Ordering::Relaxed);
                    (v != SEQ_NEVER).then_some(v)
                };
                HostCounters {
                    addr: h.addr.clone(),
                    alive: h.alive.load(Ordering::Relaxed),
                    dial_attempts: h.dial_attempts.load(Ordering::Relaxed),
                    redials: h.redials.load(Ordering::Relaxed),
                    failovers: h.failovers.load(Ordering::Relaxed),
                    last_death_seq: mark(&h.last_death_seq),
                    last_rejoin_seq: mark(&h.last_rejoin_seq),
                }
            })
            .collect()
    }

    /// Total successful re-dials (rejoins) across all hosts.
    pub fn redials_total(&self) -> u64 {
        self.shared.hosts.iter().map(|h| h.redials.load(Ordering::Relaxed)).sum()
    }

    /// Total requests failed over to a replica across all hosts.
    pub fn failovers_total(&self) -> u64 {
        self.shared.hosts.iter().map(|h| h.failovers.load(Ordering::Relaxed)).sum()
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Router's per-host reader: completes in-flight requests and absorbs
/// health. Starts from the handshake's leftover [`FrameReader`] so the
/// greeting Health frame is never lost. EOF or any wire error ⇒ the host
/// is lost — its in-flight work fails over (or errors typed) so no
/// caller ever hangs; the reconnect supervisor takes it from there. A
/// host-side [`ServeError::WorkerDropped`] (the host's own workers died
/// mid-request) also fails over: the connection is fine but the request
/// was dropped, which is exactly what replicas are for.
fn router_read_loop(
    mut stream: TcpStream,
    shared: &Arc<RouterShared>,
    idx: usize,
    mut fr: FrameReader,
) {
    let slot = &shared.hosts[idx];
    let mut chunk = [0u8; 16 * 1024];
    loop {
        match fr.next_frame() {
            Ok(Some(frame)) => {
                match frame {
                    Frame::Response { id, rsp, health } => {
                        *slot.health.lock().unwrap() = health;
                        if let Some(inflight) = slot.inflight.lock().unwrap().remove(&id) {
                            let _ = inflight.tx.send(Ok(rsp));
                        }
                    }
                    Frame::Error { id, err, health } => {
                        *slot.health.lock().unwrap() = health;
                        if let Some(inflight) = slot.inflight.lock().unwrap().remove(&id) {
                            if matches!(err, ServeError::WorkerDropped) {
                                shared.failover_or_fail(idx, inflight);
                            } else {
                                let _ = inflight.tx.send(Err(err));
                            }
                        }
                    }
                    Frame::Health(health) => {
                        *slot.health.lock().unwrap() = health;
                    }
                    // Request/Ping/Shrink only flow router → host, and
                    // Hello was consumed by the handshake — a second one
                    // mid-stream is a protocol violation.
                    Frame::Request { .. }
                    | Frame::Ping
                    | Frame::Shrink { .. }
                    | Frame::Hello { .. } => {
                        shared.handle_host_death(idx);
                        return;
                    }
                }
                continue;
            }
            Ok(None) => {}
            Err(_) => {
                shared.handle_host_death(idx);
                return;
            }
        }
        match stream.read(&mut chunk) {
            Ok(0) => {
                shared.handle_host_death(idx);
                return;
            }
            Ok(n) => fr.extend(&chunk[..n]),
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(_) => {
                shared.handle_host_death(idx);
                return;
            }
        }
    }
}

// ---------------------------------------------------------- local cluster

/// N loopback [`WireHost`]s plus a connected [`Router`] in one process —
/// the unit the fleet's `--hosts` mode and the `multi_host` bench drive.
/// Every byte still crosses real TCP sockets; only process isolation is
/// elided (the `route` CLI subcommand spawns true child processes).
pub struct LocalCluster {
    hosts: Mutex<Vec<Option<WireHost>>>,
    /// Retained so a killed host can be revived on its original address
    /// (the rejoin drill primitive).
    registry: Arc<ModelRegistry>,
    cfg: ServeConfig,
    addrs: Vec<String>,
    pub router: Router,
}

impl LocalCluster {
    pub fn spawn(
        registry: Arc<ModelRegistry>,
        cfg: ServeConfig,
        n_hosts: usize,
        router_cfg: RouterConfig,
    ) -> io::Result<LocalCluster> {
        let hosts: Vec<WireHost> = (0..n_hosts.max(1))
            .map(|_| WireHost::spawn(Arc::clone(&registry), cfg.clone(), "127.0.0.1:0"))
            .collect::<io::Result<_>>()?;
        let addrs: Vec<String> = hosts.iter().map(|h| h.addr().to_string()).collect();
        let router = Router::connect(&addrs, router_cfg)?;
        Ok(LocalCluster {
            hosts: Mutex::new(hosts.into_iter().map(Some).collect()),
            registry,
            cfg,
            addrs,
            router,
        })
    }

    /// Kill one live host (never the last), returning its address — the
    /// `host-loss` drill primitive. The router observes the connection
    /// drop and re-homes the host's variants.
    pub fn kill_host(&self) -> Option<String> {
        let mut hosts = self.hosts.lock().unwrap();
        if hosts.iter().filter(|h| h.is_some()).count() < 2 {
            return None;
        }
        // Kill the highest-index live host: deterministic, and the
        // re-homed variants spread over the remaining prefix.
        let idx = hosts.iter().rposition(|h| h.is_some())?;
        let host = hosts[idx].take()?;
        let addr = host.addr().to_string();
        host.shutdown();
        Some(addr)
    }

    /// Respawn the first killed host on its ORIGINAL address (std's
    /// listener sets SO_REUSEADDR, so the exact rebind works), returning
    /// that address. The router's reconnect supervisor re-dials it and
    /// snaps re-homed variants back — the caller only restarts the
    /// process-equivalent. The revived host presents a fresh identity in
    /// its Hello, which is what lets the router trust it.
    pub fn revive_host(&self) -> Option<String> {
        let mut hosts = self.hosts.lock().unwrap();
        let idx = hosts.iter().position(|h| h.is_none())?;
        let host =
            WireHost::spawn(Arc::clone(&self.registry), self.cfg.clone(), &self.addrs[idx]).ok()?;
        let addr = host.addr().to_string();
        hosts[idx] = Some(host);
        Some(addr)
    }

    /// The registry every host serves from (shared, so hot-swaps are
    /// visible cluster-wide — the variant-kill drill uses this).
    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.registry
    }

    pub fn live_hosts(&self) -> usize {
        self.hosts.lock().unwrap().iter().filter(|h| h.is_some()).count()
    }

    pub fn shutdown(&self) {
        self.router.shutdown();
        for host in self.hosts.lock().unwrap().iter_mut() {
            if let Some(h) = host.take() {
                h.shutdown();
            }
        }
    }
}

impl Drop for LocalCluster {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_wait_estimate_prices_inflight_at_host_rates() {
        let rates = vec![
            ("fast".to_string(), 25.0, 100u64),
            ("slow".to_string(), 400.0, 100u64),
            ("cold".to_string(), 9999.0, 2u64),
        ];
        // Cold own variant (insufficient samples): admit unconditionally.
        assert_eq!(
            estimated_host_wait_us(&[("cold".into(), 8)], &rates, "cold", 16, 2),
            None
        );
        // Warm: each in-flight variant priced at its own rate, divided by
        // live workers; cold co-tenants priced at the requester's rate.
        let inflight =
            vec![("fast".to_string(), 8u64), ("slow".to_string(), 2), ("cold".to_string(), 4)];
        let est = estimated_host_wait_us(&inflight, &rates, "fast", 16, 2).unwrap();
        assert_eq!(est, (8.0 * 25.0 + 2.0 * 400.0 + 4.0 * 25.0) / 2.0);
        // Worker divisor clamps at 1.
        let est1 = estimated_host_wait_us(&[("fast".into(), 4)], &rates, "fast", 16, 0).unwrap();
        assert_eq!(est1, 100.0);
    }

    #[test]
    fn probe_order_rehomes_deterministically() {
        // Placement is shard_for over the FULL host list; liveness only
        // filters the probe sequence. We exercise the pure pieces here —
        // the live re-homing path is pinned in tests/multi_host.rs.
        let n = 4;
        let home = shard_for("hbvla-packed", n);
        let order: Vec<usize> = (0..n).map(|i| (home + i) % n).collect();
        assert_eq!(order.len(), 4);
        assert_eq!(order[0], home);
        let unique: std::collections::HashSet<_> = order.iter().collect();
        assert_eq!(unique.len(), 4, "probe order must cover every host once");
    }

    #[test]
    fn replica_window_is_probe_prefix_and_clamps() {
        // The window is the first `replicas` probe positions…
        assert_eq!(replica_window_of(2, 4, 1), vec![2]);
        assert_eq!(replica_window_of(2, 4, 2), vec![2, 3]);
        assert_eq!(replica_window_of(3, 4, 3), vec![3, 0, 1]);
        // …clamped to the cluster size, and floored at one replica.
        assert_eq!(replica_window_of(1, 3, 99), vec![1, 2, 0]);
        assert_eq!(replica_window_of(0, 2, 0), vec![0]);
        assert_eq!(replica_window_of(0, 1, 5), vec![0]);
    }

    #[test]
    fn dial_backoff_is_exponential_capped_and_jitter_bounded() {
        assert_eq!(backoff_us(0, DIAL_BASE_US, DIAL_CAP_US), DIAL_BASE_US);
        assert_eq!(backoff_us(1, DIAL_BASE_US, DIAL_CAP_US), 2 * DIAL_BASE_US);
        assert_eq!(backoff_us(63, DIAL_BASE_US, DIAL_CAP_US), DIAL_CAP_US);
        for attempt in 0..64 {
            assert!(backoff_us(attempt, REDIAL_BASE_US, REDIAL_CAP_US) <= REDIAL_CAP_US);
        }
        // Jitter is deterministic per (host, attempt) and bounded by
        // half the base — the fleet's retry discipline, shared.
        for attempt in 0..32 {
            let j = backoff_jitter_us(3, attempt, 1000);
            assert_eq!(j, backoff_jitter_us(3, attempt, 1000));
            assert!(j <= 500);
        }
    }
}
