//! Length-prefixed binary wire protocol for multi-host serving.
//!
//! The typed serving contract ([`ServeRequest`] / [`ServeResponse`] /
//! [`ServeError`]) crosses process boundaries here: a [`Frame`] is a
//! `u32` little-endian body length followed by a one-byte tag and the
//! tag's payload. The crate stays dep-free — encoding is hand-rolled
//! over `std::io`, floats travel as IEEE-754 bit patterns
//! (`to_bits`/`from_bits`, the same convention as the fleet digests), so
//! an observation decoded on a host is bit-identical to the one the
//! client serialized and routed serving can honor the bit-parity
//! invariant end to end.
//!
//! Robustness contract: decoding NEVER panics. Truncated frames,
//! oversize length prefixes and garbage bytes all surface as typed
//! [`WireError`]s — a host drops the offending connection; the router
//! marks the host lost and re-homes its variants. [`FrameReader`] is the
//! incremental decoder both ends share: feed it whatever the socket
//! returned, drain complete frames.
//!
//! Requests carry a router-assigned `seq` — the noise-stream id of
//! stochastic decodes. The FRONT DOOR owns the sequence numbers, so
//! WHICH host serves a request never changes its actions (the same
//! argument that makes in-process sharding bit-identical, lifted across
//! the wire).

use std::io::{self, Read, Write};
use std::time::Duration;

use crate::coordinator::server::{ServeError, ServeRequest, ServeResponse, VariantSelector};
use crate::sim::observe::Observation;
use crate::tensor::matrix::Matrix;

/// Hard cap on one frame's body. Observations at MiniVLA scale are a few
/// hundred KiB; 64 MiB leaves headroom for large batch responses while
/// keeping a hostile length prefix from allocating the machine away.
pub const MAX_FRAME_BYTES: u32 = 64 * 1024 * 1024;

/// Wire protocol version, exchanged in the [`Frame::Hello`] handshake.
/// A host greets every connection with `Hello{version, host_id}` before
/// anything else; a router that sees a different version (or no Hello at
/// all) rejects the peer with a typed [`WireError`] instead of decoding
/// garbage from a stale or foreign process.
pub const PROTOCOL_VERSION: u8 = 1;

/// Typed wire failures — every malformed input lands here, never in a
/// panic.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireError {
    /// The body ended before the field being decoded.
    Truncated { context: &'static str },
    /// Length prefix beyond [`MAX_FRAME_BYTES`].
    Oversize { len: u64 },
    /// Unknown frame tag byte.
    BadTag(u8),
    /// Unknown [`ServeError`] code byte.
    BadErrorCode(u8),
    /// A string field holds invalid UTF-8.
    BadString,
    /// A count field implies a structurally impossible payload (e.g.
    /// matrix dims whose product overflows or exceeds the frame).
    BadShape { context: &'static str },
    /// Trailing bytes after a complete body — framing desync.
    TrailingBytes { extra: usize },
    /// The peer closed the connection at a frame boundary (clean EOF).
    Closed,
    /// Transport-level I/O failure.
    Io(io::ErrorKind),
    /// The connection handshake went wrong: the peer's first frame was
    /// not a [`Frame::Hello`], or it never arrived.
    BadHandshake { context: &'static str },
    /// The peer speaks a different [`PROTOCOL_VERSION`].
    VersionMismatch { peer: u8, local: u8 },
    /// The peer's Hello carried a host identity that is already live on
    /// another connection — a stale or duplicated host, not a rejoin.
    StalePeer { host_id: u64 },
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated { context } => write!(f, "truncated frame in {context}"),
            WireError::Oversize { len } => {
                write!(f, "length prefix {len} exceeds frame cap {MAX_FRAME_BYTES}")
            }
            WireError::BadTag(t) => write!(f, "unknown frame tag {t:#04x}"),
            WireError::BadErrorCode(c) => write!(f, "unknown serve-error code {c:#04x}"),
            WireError::BadString => write!(f, "invalid UTF-8 in string field"),
            WireError::BadShape { context } => write!(f, "impossible shape in {context}"),
            WireError::TrailingBytes { extra } => {
                write!(f, "{extra} trailing bytes after frame body")
            }
            WireError::Closed => write!(f, "peer closed the connection"),
            WireError::Io(kind) => write!(f, "transport error: {kind:?}"),
            WireError::BadHandshake { context } => write!(f, "bad handshake: {context}"),
            WireError::VersionMismatch { peer, local } => {
                write!(f, "peer speaks protocol v{peer}, this end speaks v{local}")
            }
            WireError::StalePeer { host_id } => {
                write!(f, "stale peer: host identity {host_id:#018x} is already connected")
            }
        }
    }
}

impl std::error::Error for WireError {}

impl From<io::Error> for WireError {
    fn from(e: io::Error) -> Self {
        WireError::Io(e.kind())
    }
}

/// One host's health snapshot: queue depth, live collectors, and the
/// observed per-variant service rates + pending mix. Piggybacked on
/// every response/error frame and sent standalone on connect (and in
/// reply to [`Frame::Ping`]), so the router prices a deadline request
/// against its target host without a network round trip.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct HostHealth {
    /// Requests submitted but not yet past a closed batch window.
    pub depth: u64,
    /// Workers currently running their dispatch loop.
    pub live_workers: u32,
    /// Per-variant pending request counts (summed over the host's
    /// shards) at snapshot time.
    pub pending: Vec<(String, u64)>,
    /// Per-variant `(per_request_service_us, samples)` — the same rate
    /// the host's own routed admission uses.
    pub rates: Vec<(String, f64, u64)>,
}

/// Everything that crosses the wire. `id` correlates a response to its
/// request on a pipelined connection (responses may return out of
/// order); `seq` is the router-assigned noise-stream id.
#[derive(Debug)]
pub enum Frame {
    Request { id: u64, seq: u64, req: ServeRequest },
    Response { id: u64, rsp: ServeResponse, health: HostHealth },
    Error { id: u64, err: ServeError, health: HostHealth },
    /// Standalone health heartbeat (on connect, and answering a ping).
    Health(HostHealth),
    Ping,
    /// Control: retire the host's workers down to `target` (the fleet's
    /// worker-loss drill, across the wire).
    Shrink { target: u32 },
    /// Handshake greeting — the FIRST frame a host sends on every
    /// accepted connection. `host_id` identifies the host process (it
    /// survives reconnects, changes on restart), so a router re-dialing
    /// a dead address can tell a rejoined host from a stale peer.
    Hello { version: u8, host_id: u64 },
}

const TAG_REQUEST: u8 = 1;
const TAG_RESPONSE: u8 = 2;
const TAG_ERROR: u8 = 3;
const TAG_HEALTH: u8 = 4;
const TAG_PING: u8 = 5;
const TAG_SHRINK: u8 = 6;
const TAG_HELLO: u8 = 7;

// ---------------------------------------------------------------- encode

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_bits().to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    put_u32(out, xs.len() as u32);
    for &x in xs {
        out.extend_from_slice(&x.to_bits().to_le_bytes());
    }
}

/// `Duration` as exact nanoseconds (u64: ~584 years of range).
fn put_duration(out: &mut Vec<u8>, d: Duration) {
    put_u64(out, d.as_nanos() as u64);
}

fn put_opt_duration(out: &mut Vec<u8>, d: Option<Duration>) {
    match d {
        None => out.push(0),
        Some(d) => {
            out.push(1);
            put_duration(out, d);
        }
    }
}

fn put_health(out: &mut Vec<u8>, h: &HostHealth) {
    put_u64(out, h.depth);
    put_u32(out, h.live_workers);
    put_u32(out, h.pending.len() as u32);
    for (name, count) in &h.pending {
        put_str(out, name);
        put_u64(out, *count);
    }
    put_u32(out, h.rates.len() as u32);
    for (name, rate_us, samples) in &h.rates {
        put_str(out, name);
        put_f64(out, *rate_us);
        put_u64(out, *samples);
    }
}

fn put_serve_error(out: &mut Vec<u8>, e: &ServeError) {
    match e {
        ServeError::UnknownVariant(name) => {
            out.push(1);
            put_str(out, name);
        }
        ServeError::NoVariants => out.push(2),
        ServeError::Stopped => out.push(3),
        ServeError::WorkerDropped => out.push(4),
        ServeError::DeadlineExceeded { queued } => {
            out.push(5);
            put_duration(out, *queued);
        }
        ServeError::Overloaded { queue_depth, estimated_wait, retry_after_us } => {
            out.push(6);
            put_u64(out, *queue_depth as u64);
            put_duration(out, *estimated_wait);
            put_u64(out, *retry_after_us);
        }
        ServeError::InvalidObservation { got } => {
            out.push(7);
            put_str(out, got);
        }
    }
}

/// Encode one frame BODY (tag + payload, no length prefix) — the unit
/// the property tests round-trip.
pub fn encode_frame(frame: &Frame) -> Vec<u8> {
    let mut out = Vec::new();
    match frame {
        Frame::Request { id, seq, req } => {
            out.push(TAG_REQUEST);
            put_u64(&mut out, *id);
            put_u64(&mut out, *seq);
            match &req.variant {
                VariantSelector::Default => out.push(0),
                VariantSelector::Named(name) => {
                    out.push(1);
                    put_str(&mut out, name);
                }
            }
            put_opt_duration(&mut out, req.deadline);
            put_u64(&mut out, req.obs.instr_id as u64);
            put_f32s(&mut out, &req.obs.proprio);
            put_u32(&mut out, req.obs.visual_raw.rows as u32);
            put_u32(&mut out, req.obs.visual_raw.cols as u32);
            for &x in &req.obs.visual_raw.data {
                out.extend_from_slice(&x.to_bits().to_le_bytes());
            }
        }
        Frame::Response { id, rsp, health } => {
            out.push(TAG_RESPONSE);
            put_u64(&mut out, *id);
            put_str(&mut out, &rsp.variant_served);
            put_duration(&mut out, rsp.queue_time);
            put_duration(&mut out, rsp.compute_time);
            put_u32(&mut out, rsp.actions.len() as u32);
            for step in &rsp.actions {
                put_f32s(&mut out, step);
            }
            put_health(&mut out, health);
        }
        Frame::Error { id, err, health } => {
            out.push(TAG_ERROR);
            put_u64(&mut out, *id);
            put_serve_error(&mut out, err);
            put_health(&mut out, health);
        }
        Frame::Health(h) => {
            out.push(TAG_HEALTH);
            put_health(&mut out, h);
        }
        Frame::Ping => out.push(TAG_PING),
        Frame::Shrink { target } => {
            out.push(TAG_SHRINK);
            put_u32(&mut out, *target);
        }
        Frame::Hello { version, host_id } => {
            out.push(TAG_HELLO);
            out.push(*version);
            put_u64(&mut out, *host_id);
        }
    }
    out
}

// ---------------------------------------------------------------- decode

/// Bounds-checked reader over one frame body.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize, context: &'static str) -> Result<&'a [u8], WireError> {
        if self.buf.len() - self.pos < n {
            return Err(WireError::Truncated { context });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self, context: &'static str) -> Result<u8, WireError> {
        Ok(self.take(1, context)?[0])
    }

    fn u32(&mut self, context: &'static str) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4, context)?.try_into().unwrap()))
    }

    fn u64(&mut self, context: &'static str) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8, context)?.try_into().unwrap()))
    }

    fn f64(&mut self, context: &'static str) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.u64(context)?))
    }

    /// A count that must be coverable by the REMAINING bytes at
    /// `min_elem_bytes` each — rejects hostile counts before allocating.
    fn count(&mut self, min_elem_bytes: usize, context: &'static str) -> Result<usize, WireError> {
        let n = self.u32(context)? as usize;
        if n.saturating_mul(min_elem_bytes) > self.buf.len() - self.pos {
            return Err(WireError::BadShape { context });
        }
        Ok(n)
    }

    fn string(&mut self, context: &'static str) -> Result<String, WireError> {
        let n = self.count(1, context)?;
        let bytes = self.take(n, context)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| WireError::BadString)
    }

    fn f32s(&mut self, context: &'static str) -> Result<Vec<f32>, WireError> {
        let n = self.count(4, context)?;
        let bytes = self.take(n * 4, context)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())))
            .collect())
    }

    fn duration(&mut self, context: &'static str) -> Result<Duration, WireError> {
        Ok(Duration::from_nanos(self.u64(context)?))
    }

    fn opt_duration(&mut self, context: &'static str) -> Result<Option<Duration>, WireError> {
        match self.u8(context)? {
            0 => Ok(None),
            1 => Ok(Some(self.duration(context)?)),
            _ => Err(WireError::BadShape { context }),
        }
    }

    fn health(&mut self) -> Result<HostHealth, WireError> {
        let depth = self.u64("health.depth")?;
        let live_workers = self.u32("health.live_workers")?;
        let n_pending = self.count(4 + 8, "health.pending")?;
        let mut pending = Vec::with_capacity(n_pending);
        for _ in 0..n_pending {
            let name = self.string("health.pending.name")?;
            let count = self.u64("health.pending.count")?;
            pending.push((name, count));
        }
        let n_rates = self.count(4 + 8 + 8, "health.rates")?;
        let mut rates = Vec::with_capacity(n_rates);
        for _ in 0..n_rates {
            let name = self.string("health.rates.name")?;
            let rate = self.f64("health.rates.rate")?;
            let samples = self.u64("health.rates.samples")?;
            rates.push((name, rate, samples));
        }
        Ok(HostHealth { depth, live_workers, pending, rates })
    }

    fn serve_error(&mut self) -> Result<ServeError, WireError> {
        match self.u8("error.code")? {
            1 => Ok(ServeError::UnknownVariant(self.string("error.variant")?)),
            2 => Ok(ServeError::NoVariants),
            3 => Ok(ServeError::Stopped),
            4 => Ok(ServeError::WorkerDropped),
            5 => Ok(ServeError::DeadlineExceeded { queued: self.duration("error.queued")? }),
            6 => Ok(ServeError::Overloaded {
                queue_depth: self.u64("error.depth")? as usize,
                estimated_wait: self.duration("error.wait")?,
                retry_after_us: self.u64("error.retry")?,
            }),
            7 => Ok(ServeError::InvalidObservation { got: self.string("error.got")? }),
            c => Err(WireError::BadErrorCode(c)),
        }
    }

    fn done(&self) -> Result<(), WireError> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            Err(WireError::TrailingBytes { extra: self.buf.len() - self.pos })
        }
    }
}

/// Decode one frame body. Total — every byte string returns a [`Frame`]
/// or a typed [`WireError`]; nothing panics.
pub fn decode_frame(body: &[u8]) -> Result<Frame, WireError> {
    let mut r = Reader::new(body);
    let frame = match r.u8("tag")? {
        TAG_REQUEST => {
            let id = r.u64("request.id")?;
            let seq = r.u64("request.seq")?;
            let variant = match r.u8("request.selector")? {
                0 => VariantSelector::Default,
                1 => VariantSelector::Named(r.string("request.variant")?),
                _ => return Err(WireError::BadShape { context: "request.selector" }),
            };
            let deadline = r.opt_duration("request.deadline")?;
            let instr_id = r.u64("request.instr_id")? as usize;
            let proprio = r.f32s("request.proprio")?;
            let rows = r.u32("request.visual.rows")? as usize;
            let cols = r.u32("request.visual.cols")? as usize;
            let n = rows
                .checked_mul(cols)
                .filter(|&n| n.saturating_mul(4) <= body.len())
                .ok_or(WireError::BadShape { context: "request.visual" })?;
            let bytes = r.take(n * 4, "request.visual.data")?;
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())))
                .collect();
            let visual_raw = Matrix::from_vec(rows, cols, data);
            Frame::Request {
                id,
                seq,
                req: ServeRequest {
                    obs: Observation { visual_raw, instr_id, proprio },
                    variant,
                    deadline,
                },
            }
        }
        TAG_RESPONSE => {
            let id = r.u64("response.id")?;
            let variant_served = r.string("response.variant")?;
            let queue_time = r.duration("response.queue")?;
            let compute_time = r.duration("response.compute")?;
            let n = r.count(4, "response.actions")?;
            let mut actions = Vec::with_capacity(n);
            for _ in 0..n {
                actions.push(r.f32s("response.actions.step")?);
            }
            let health = r.health()?;
            Frame::Response {
                id,
                rsp: ServeResponse { actions, variant_served, queue_time, compute_time },
                health,
            }
        }
        TAG_ERROR => {
            let id = r.u64("error.id")?;
            let err = r.serve_error()?;
            let health = r.health()?;
            Frame::Error { id, err, health }
        }
        TAG_HEALTH => Frame::Health(r.health()?),
        TAG_PING => Frame::Ping,
        TAG_SHRINK => Frame::Shrink { target: r.u32("shrink.target")? },
        TAG_HELLO => {
            Frame::Hello { version: r.u8("hello.version")?, host_id: r.u64("hello.host_id")? }
        }
        t => return Err(WireError::BadTag(t)),
    };
    r.done()?;
    Ok(frame)
}

// ---------------------------------------------------------------- framing

/// Write one length-prefixed frame. The body is assembled first so the
/// write is a single syscall-sized buffer (no interleaving between
/// concurrent writers beyond the caller's lock).
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> io::Result<()> {
    let body = encode_frame(frame);
    let mut buf = Vec::with_capacity(4 + body.len());
    buf.extend_from_slice(&(body.len() as u32).to_le_bytes());
    buf.extend_from_slice(&body);
    w.write_all(&buf)
}

/// Incremental frame decoder: feed raw socket bytes with [`Self::extend`],
/// drain complete frames with [`Self::next_frame`]. Both ends of every
/// connection use this, so partial reads and pipelined frames need no
/// special casing at the socket loop.
#[derive(Default)]
pub struct FrameReader {
    buf: Vec<u8>,
    /// Consumed prefix (compacted lazily to amortize the memmove).
    start: usize,
}

impl FrameReader {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn extend(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    fn pending(&self) -> &[u8] {
        &self.buf[self.start..]
    }

    /// The next complete frame, `Ok(None)` if more bytes are needed. A
    /// decode error poisons the stream (framing is lost) — the caller
    /// must drop the connection.
    pub fn next_frame(&mut self) -> Result<Option<Frame>, WireError> {
        let pending = self.pending();
        if pending.len() < 4 {
            return Ok(None);
        }
        let len = u32::from_le_bytes(pending[..4].try_into().unwrap());
        if len > MAX_FRAME_BYTES {
            return Err(WireError::Oversize { len: len as u64 });
        }
        let len = len as usize;
        if pending.len() < 4 + len {
            return Ok(None);
        }
        let frame = decode_frame(&pending[4..4 + len])?;
        self.start += 4 + len;
        if self.start >= self.buf.len() {
            self.buf.clear();
            self.start = 0;
        } else if self.start > 64 * 1024 {
            self.buf.drain(..self.start);
            self.start = 0;
        }
        Ok(Some(frame))
    }
}

/// Blocking read of one frame from a stream. Clean EOF at a frame
/// boundary is [`WireError::Closed`]; EOF mid-frame is `Truncated`.
pub fn read_frame(r: &mut impl Read, reader: &mut FrameReader) -> Result<Frame, WireError> {
    let mut chunk = [0u8; 16 * 1024];
    loop {
        if let Some(frame) = reader.next_frame()? {
            return Ok(frame);
        }
        match r.read(&mut chunk) {
            Ok(0) => {
                return if reader.pending().is_empty() {
                    Err(WireError::Closed)
                } else {
                    Err(WireError::Truncated { context: "eof mid-frame" })
                };
            }
            Ok(n) => reader.extend(&chunk[..n]),
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn health() -> HostHealth {
        HostHealth {
            depth: 7,
            live_workers: 3,
            pending: vec![("dense".into(), 4), ("hbvla-packed".into(), 3)],
            rates: vec![("dense".into(), 123.5, 40), ("hbvla-packed".into(), 88.25, 17)],
        }
    }

    #[test]
    fn health_and_control_frames_round_trip() {
        let h = health();
        match decode_frame(&encode_frame(&Frame::Health(h.clone()))).unwrap() {
            Frame::Health(got) => assert_eq!(got, h),
            other => panic!("{other:?}"),
        }
        assert!(matches!(decode_frame(&encode_frame(&Frame::Ping)).unwrap(), Frame::Ping));
        match decode_frame(&encode_frame(&Frame::Shrink { target: 2 })).unwrap() {
            Frame::Shrink { target } => assert_eq!(target, 2),
            other => panic!("{other:?}"),
        }
        let hello = Frame::Hello { version: PROTOCOL_VERSION, host_id: 0xDEAD_BEEF_CAFE_F00D };
        match decode_frame(&encode_frame(&hello)).unwrap() {
            Frame::Hello { version, host_id } => {
                assert_eq!(version, PROTOCOL_VERSION);
                assert_eq!(host_id, 0xDEAD_BEEF_CAFE_F00D);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn error_frames_round_trip_every_code() {
        let errs = [
            ServeError::UnknownVariant("evil\"name\\\n".into()),
            ServeError::NoVariants,
            ServeError::Stopped,
            ServeError::WorkerDropped,
            ServeError::DeadlineExceeded { queued: Duration::from_nanos(1_234_567) },
            ServeError::Overloaded {
                queue_depth: 42,
                estimated_wait: Duration::from_micros(999),
                retry_after_us: 512,
            },
            ServeError::InvalidObservation { got: "visual 3x4, proprio 9, instr 1".into() },
        ];
        for err in errs {
            let f = Frame::Error { id: 9, err: err.clone(), health: health() };
            match decode_frame(&encode_frame(&f)).unwrap() {
                Frame::Error { id, err: got, health: h } => {
                    assert_eq!(id, 9);
                    assert_eq!(got, err);
                    assert_eq!(h, health());
                }
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn frame_reader_handles_byte_at_a_time_and_pipelining() {
        let a = encode_frame(&Frame::Ping);
        let b = encode_frame(&Frame::Shrink { target: 1 });
        let mut stream = Vec::new();
        for body in [&a, &b] {
            stream.extend_from_slice(&(body.len() as u32).to_le_bytes());
            stream.extend_from_slice(body);
        }
        // Dripped one byte at a time, frames pop exactly when complete.
        let mut fr = FrameReader::new();
        let mut got = Vec::new();
        for &byte in &stream {
            fr.extend(&[byte]);
            while let Some(f) = fr.next_frame().unwrap() {
                got.push(f);
            }
        }
        assert_eq!(got.len(), 2);
        assert!(matches!(got[0], Frame::Ping));
        assert!(matches!(got[1], Frame::Shrink { target: 1 }));
        // Or both at once (pipelined).
        let mut fr = FrameReader::new();
        fr.extend(&stream);
        assert!(matches!(fr.next_frame().unwrap(), Some(Frame::Ping)));
        assert!(matches!(fr.next_frame().unwrap(), Some(Frame::Shrink { target: 1 })));
        assert!(fr.next_frame().unwrap().is_none());
    }

    #[test]
    fn oversize_length_prefix_is_typed() {
        let mut fr = FrameReader::new();
        fr.extend(&(MAX_FRAME_BYTES + 1).to_le_bytes());
        assert_eq!(
            fr.next_frame().unwrap_err(),
            WireError::Oversize { len: (MAX_FRAME_BYTES + 1) as u64 }
        );
    }

    /// A peer restart leaves a stale half-frame in the reader while the
    /// NEW peer's bytes land right behind it. The reader must surface a
    /// typed error once the stale framing resolves into garbage — never
    /// a panic, never a silently misparsed frame — and a fresh reader on
    /// the new peer's byte stream must resync cleanly.
    #[test]
    fn stale_half_frame_after_peer_restart_errors_typed_then_resyncs() {
        // Old peer died 10 bytes into a 44-byte frame whose first body
        // byte is an invalid tag — the stale prefix can only ever decode
        // to a typed error, whatever lands behind it.
        let mut stale = Vec::new();
        stale.extend_from_slice(&44u32.to_le_bytes());
        stale.push(0xFF); // bad tag
        stale.extend_from_slice(&[0u8; 5]); // 10 of 48 wire bytes arrived

        // The new peer (restarted host) greets with Hello + Health.
        let mut fresh = Vec::new();
        for frame in [
            Frame::Hello { version: PROTOCOL_VERSION, host_id: 7 },
            Frame::Health(health()),
        ] {
            let body = encode_frame(&frame);
            fresh.extend_from_slice(&(body.len() as u32).to_le_bytes());
            fresh.extend_from_slice(&body);
        }
        assert!(fresh.len() >= 38, "need enough new-peer bytes to complete the stale frame");

        // Interleaved into ONE reader (the reconnect-without-reset bug):
        // the stale length prefix swallows new-peer bytes until the
        // claimed 44-byte body completes, then decode fails typed.
        let mut fr = FrameReader::new();
        fr.extend(&stale);
        assert!(fr.next_frame().unwrap().is_none(), "half frame must not decode");
        let mut outcome = Ok(None);
        for &b in &fresh {
            fr.extend(&[b]);
            outcome = fr.next_frame();
            if outcome.is_err() {
                break;
            }
            assert!(
                matches!(outcome, Ok(None)),
                "stale framing must never yield a parsed frame: {outcome:?}"
            );
        }
        assert_eq!(outcome.unwrap_err(), WireError::BadTag(0xFF));

        // The contract after a poisoned stream: drop the connection and
        // start a FRESH reader on the new peer's bytes — clean resync,
        // dripped a byte at a time like a real reconnect race.
        let mut fr = FrameReader::new();
        let mut got = Vec::new();
        for &b in &fresh {
            fr.extend(&[b]);
            while let Some(f) = fr.next_frame().unwrap() {
                got.push(f);
            }
        }
        assert_eq!(got.len(), 2);
        assert!(matches!(got[0], Frame::Hello { version: PROTOCOL_VERSION, host_id: 7 }));
        match &got[1] {
            Frame::Health(h) => assert_eq!(*h, health()),
            other => panic!("{other:?}"),
        }
    }

    /// Same reconnect shape, but the stale prefix claims a body LONGER
    /// than everything the new peer sends: the reader must keep
    /// reporting "incomplete" (no misparse) until the caller times out
    /// and resets — and an oversize stale prefix fails immediately.
    #[test]
    fn stale_prefix_longer_than_new_stream_never_misparses() {
        let hello = encode_frame(&Frame::Hello { version: PROTOCOL_VERSION, host_id: 3 });
        let mut fr = FrameReader::new();
        fr.extend(&(10_000u32).to_le_bytes()); // stale: claims 10 KB body
        fr.extend(&[TAG_HELLO, PROTOCOL_VERSION]); // old peer died here
        fr.extend(&(hello.len() as u32).to_le_bytes());
        fr.extend(&hello);
        // All of the new peer's bytes are swallowed into the stale body;
        // the reader reports incomplete, never a frame.
        assert!(fr.next_frame().unwrap().is_none());

        let mut fr = FrameReader::new();
        fr.extend(&(MAX_FRAME_BYTES + 7).to_le_bytes()); // stale + hostile
        fr.extend(&hello);
        assert!(matches!(fr.next_frame(), Err(WireError::Oversize { .. })));
    }

    #[test]
    fn hostile_counts_do_not_allocate() {
        // A Response claiming u32::MAX action steps in a 32-byte body
        // must fail as BadShape, not attempt a giant Vec::with_capacity.
        let mut body = vec![TAG_RESPONSE];
        put_u64(&mut body, 1); // id
        put_str(&mut body, "v");
        put_u64(&mut body, 0); // queue ns
        put_u64(&mut body, 0); // compute ns
        put_u32(&mut body, u32::MAX); // actions count — hostile
        assert_eq!(
            decode_frame(&body).unwrap_err(),
            WireError::BadShape { context: "response.actions" }
        );
    }
}
