//! Named model variants behind one serving endpoint.
//!
//! A [`ModelRegistry`] holds the model variants a [`crate::coordinator::server::PolicyServer`]
//! can route requests to — e.g. `dense` (the FP checkpoint), `rtn-packed`
//! and `hbvla-packed` (PTQ commits of the same checkpoint) — keyed by
//! name. Which variant serves a request is a per-request choice
//! ([`crate::coordinator::server::VariantSelector`]), so a single endpoint
//! can A/B representations, fall back to dense for accuracy-critical
//! traffic, and serve compressed variants for the rest.
//!
//! All variants must agree on the *serving interface*
//! ([`crate::model::VlaConfig::serve_compatible`]): observation dims,
//! vocabulary and action shape. Internal widths may differ — a distilled
//! smaller trunk is a legal variant.
//!
//! Registration is thread-safe (`&self`), so quantization jobs can
//! publish variants while the server is live; the scheduler's
//! [`crate::coordinator::scheduler::quantize_into_registry`] makes
//! `quantize → register → serve` one flow.

use std::sync::{Arc, Mutex};

use crate::model::MiniVla;

/// Why a variant could not be registered.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RegistryError {
    /// The variant's serving interface (observation dims / action shape)
    /// differs from the variants already registered.
    IncompatibleConfig { variant: String },
    /// A derived registration (e.g. an `-a8` activation-precision twin)
    /// named a base variant that is not in the registry.
    UnknownVariant { variant: String },
    /// A quantize-for-variant flow asked for a deploy representation the
    /// method did not commit for a layer (e.g. requesting transform-exact
    /// serving from a direct-domain method like RTN). Typed, so the flow
    /// fails loudly instead of silently committing a different repr.
    UnsupportedRepr { variant: String, layer: String, wanted: &'static str },
}

impl std::fmt::Display for RegistryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RegistryError::IncompatibleConfig { variant } => {
                write!(f, "variant '{variant}' has an incompatible serving interface")
            }
            RegistryError::UnknownVariant { variant } => {
                write!(f, "variant '{variant}' is not registered")
            }
            RegistryError::UnsupportedRepr { variant, layer, wanted } => {
                write!(
                    f,
                    "variant '{variant}': method committed no {wanted} representation \
                     for layer '{layer}'"
                )
            }
        }
    }
}

impl std::error::Error for RegistryError {}

#[derive(Default)]
struct Inner {
    /// Insertion-ordered (name, model) pairs; names are unique.
    variants: Vec<(String, Arc<MiniVla>)>,
    default: Option<String>,
    /// Bumped on every replace/remove — an epoch-counted handle
    /// (`get_with_epoch`) lets a dispatcher detect a hot-swap: in-flight
    /// batches finish on the `Arc` they already hold (old weights), new
    /// submits resolve the new epoch's mapping.
    epoch: u64,
}

/// Thread-safe registry of named model variants sharing one serving
/// interface. The first registered variant becomes the default until
/// [`ModelRegistry::set_default`] overrides it.
#[derive(Default)]
pub struct ModelRegistry {
    inner: Mutex<Inner>,
}

impl ModelRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register (or replace) a variant. Fails if its config is not
    /// serve-compatible with the variants already present — including the
    /// one being replaced: a live server may be default-routing to it, so
    /// the interface can never change out from under clients.
    pub fn register(&self, name: &str, model: Arc<MiniVla>) -> Result<(), RegistryError> {
        let mut g = self.inner.lock().unwrap();
        if let Some((_, existing)) = g.variants.first() {
            if !existing.cfg.serve_compatible(&model.cfg) {
                return Err(RegistryError::IncompatibleConfig { variant: name.to_string() });
            }
        }
        match g.variants.iter_mut().find(|(n, _)| n == name) {
            Some(slot) => {
                slot.1 = model;
                g.epoch += 1;
            }
            None => g.variants.push((name.to_string(), model)),
        }
        if g.default.is_none() {
            g.default = Some(name.to_string());
        }
        Ok(())
    }

    /// Atomically deregister a variant (the hot-swap "kill" primitive).
    /// In-flight batches keep the `Arc<MiniVla>` they resolved at
    /// dispatch and finish on the old weights; every later resolve —
    /// new submits AND queued groups that re-resolve at dispatch — fails
    /// with a typed [`crate::coordinator::ServeError::UnknownVariant`].
    /// If the removed variant was the default, the default re-points at
    /// the first remaining variant (or clears when none remain).
    pub fn remove(&self, name: &str) -> Result<Arc<MiniVla>, RegistryError> {
        let mut g = self.inner.lock().unwrap();
        let idx = g
            .variants
            .iter()
            .position(|(n, _)| n == name)
            .ok_or_else(|| RegistryError::UnknownVariant { variant: name.to_string() })?;
        let (_, model) = g.variants.remove(idx);
        if g.default.as_deref() == Some(name) {
            g.default = g.variants.first().map(|(n, _)| n.clone());
        }
        g.epoch += 1;
        Ok(model)
    }

    /// Look up a variant by name.
    pub fn get(&self, name: &str) -> Option<Arc<MiniVla>> {
        let g = self.inner.lock().unwrap();
        g.variants.iter().find(|(n, _)| n == name).map(|(_, m)| Arc::clone(m))
    }

    /// Look up a variant together with the registry epoch the handle was
    /// minted at — stale if [`ModelRegistry::epoch`] has moved since.
    pub fn get_with_epoch(&self, name: &str) -> Option<(Arc<MiniVla>, u64)> {
        let g = self.inner.lock().unwrap();
        g.variants.iter().find(|(n, _)| n == name).map(|(_, m)| (Arc::clone(m), g.epoch))
    }

    /// Mutation epoch: bumped on every variant replace or remove (new
    /// registrations under a fresh name don't invalidate any handle, so
    /// they leave it alone).
    pub fn epoch(&self) -> u64 {
        self.inner.lock().unwrap().epoch
    }

    /// The default variant's name (first registered unless overridden).
    pub fn default_variant(&self) -> Option<String> {
        self.inner.lock().unwrap().default.clone()
    }

    /// Point the default at an existing variant; false if unknown.
    pub fn set_default(&self, name: &str) -> bool {
        let mut g = self.inner.lock().unwrap();
        if g.variants.iter().any(|(n, _)| n == name) {
            g.default = Some(name.to_string());
            true
        } else {
            false
        }
    }

    /// Registered variant names, in insertion order.
    pub fn names(&self) -> Vec<String> {
        self.inner.lock().unwrap().variants.iter().map(|(n, _)| n.clone()).collect()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().variants.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{HeadKind, VlaConfig};

    fn tiny_model(seed: u64) -> Arc<MiniVla> {
        Arc::new(MiniVla::new(VlaConfig::tiny(HeadKind::Chunk).with_seed(seed)))
    }

    #[test]
    fn register_get_and_default() {
        let r = ModelRegistry::new();
        assert!(r.is_empty());
        assert_eq!(r.default_variant(), None);
        r.register("dense", tiny_model(1)).unwrap();
        r.register("packed", tiny_model(2)).unwrap();
        assert_eq!(r.len(), 2);
        assert_eq!(r.names(), vec!["dense".to_string(), "packed".to_string()]);
        assert_eq!(r.default_variant().as_deref(), Some("dense"));
        assert!(r.get("packed").is_some());
        assert!(r.get("missing").is_none());
        assert!(r.set_default("packed"));
        assert_eq!(r.default_variant().as_deref(), Some("packed"));
        assert!(!r.set_default("missing"));
    }

    #[test]
    fn replace_keeps_single_slot() {
        let r = ModelRegistry::new();
        r.register("m", tiny_model(1)).unwrap();
        let replacement = tiny_model(9);
        r.register("m", Arc::clone(&replacement)).unwrap();
        assert_eq!(r.len(), 1);
        assert_eq!(r.get("m").unwrap().cfg.seed, 9);
    }

    #[test]
    fn remove_is_atomic_epoch_counted_and_repoints_default() {
        let r = ModelRegistry::new();
        assert_eq!(r.epoch(), 0);
        r.register("dense", tiny_model(1)).unwrap();
        r.register("packed", tiny_model(2)).unwrap();
        assert_eq!(r.epoch(), 0, "fresh names do not invalidate handles");

        // A handle minted before the swap stays on the old weights.
        let (held, epoch_at_mint) = r.get_with_epoch("packed").unwrap();
        assert_eq!(held.cfg.seed, 2);

        // Removing the DEFAULT re-points it at the first survivor.
        let removed = r.remove("dense").unwrap();
        assert_eq!(removed.cfg.seed, 1);
        assert_eq!(r.default_variant().as_deref(), Some("packed"));
        assert_eq!(r.epoch(), 1);

        // Replace bumps the epoch too; the held Arc is now detectably
        // stale but still serves the old weights (in-flight contract).
        r.register("packed", tiny_model(9)).unwrap();
        assert!(r.epoch() > epoch_at_mint);
        assert_eq!(held.cfg.seed, 2);
        assert_eq!(r.get("packed").unwrap().cfg.seed, 9);

        // Removing the last variant clears the default; unknown names
        // fail typed.
        r.remove("packed").unwrap();
        assert!(r.is_empty());
        assert_eq!(r.default_variant(), None);
        assert_eq!(
            r.remove("packed").unwrap_err(),
            RegistryError::UnknownVariant { variant: "packed".to_string() }
        );
    }

    #[test]
    fn incompatible_interface_rejected() {
        let r = ModelRegistry::new();
        r.register("dense", tiny_model(1)).unwrap();
        // A Token-head model answers with a different action contract.
        let other = Arc::new(MiniVla::new(VlaConfig::tiny(HeadKind::Token)));
        let err = r.register("tok", other).unwrap_err();
        assert_eq!(err, RegistryError::IncompatibleConfig { variant: "tok".to_string() });
        assert_eq!(r.len(), 1);
        // Same interface with different internals is fine.
        let mut cfg = VlaConfig::tiny(HeadKind::Chunk);
        cfg.d_model = 64;
        cfg.heads = 4;
        r.register("wide", Arc::new(MiniVla::new(cfg))).unwrap();
        assert_eq!(r.len(), 2);
        // Replacing the sole (default) variant with an incompatible model
        // is rejected too — a live server may be default-routing to it.
        let solo = ModelRegistry::new();
        solo.register("only", tiny_model(1)).unwrap();
        let swap = Arc::new(MiniVla::new(VlaConfig::tiny(HeadKind::Diffusion)));
        assert!(solo.register("only", swap).is_err());
        assert_eq!(solo.get("only").unwrap().cfg.head, HeadKind::Chunk);
    }
}
