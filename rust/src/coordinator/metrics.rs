//! Latency/throughput accounting for the serving router and the perf pass.

#[derive(Clone, Debug, Default)]
pub struct LatencyStats {
    samples_us: Vec<u64>,
}

impl LatencyStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_us(&mut self, us: u64) {
        self.samples_us.push(us);
    }

    pub fn record(&mut self, d: std::time::Duration) {
        self.record_us(d.as_micros() as u64);
    }

    pub fn merge(&mut self, other: &LatencyStats) {
        self.samples_us.extend_from_slice(&other.samples_us);
    }

    pub fn count(&self) -> usize {
        self.samples_us.len()
    }

    pub fn mean_us(&self) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        self.samples_us.iter().sum::<u64>() as f64 / self.samples_us.len() as f64
    }

    pub fn percentile_us(&self, p: f64) -> u64 {
        if self.samples_us.is_empty() {
            return 0;
        }
        let mut v = self.samples_us.clone();
        v.sort_unstable();
        let idx = ((p.clamp(0.0, 1.0) * (v.len() - 1) as f64).round()) as usize;
        v[idx]
    }

    pub fn p50_us(&self) -> u64 {
        self.percentile_us(0.50)
    }

    pub fn p99_us(&self) -> u64 {
        self.percentile_us(0.99)
    }

    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.1}us p50={}us p99={}us",
            self.count(),
            self.mean_us(),
            self.p50_us(),
            self.p99_us()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut s = LatencyStats::new();
        for i in 1..=100 {
            s.record_us(i);
        }
        assert_eq!(s.count(), 100);
        assert!((s.mean_us() - 50.5).abs() < 1e-9);
        assert!(s.p50_us() <= s.p99_us());
        assert_eq!(s.percentile_us(0.0), 1);
        assert_eq!(s.percentile_us(1.0), 100);
    }

    #[test]
    fn empty_stats_safe() {
        let s = LatencyStats::new();
        assert_eq!(s.mean_us(), 0.0);
        assert_eq!(s.p99_us(), 0);
    }

    #[test]
    fn merge_combines() {
        let mut a = LatencyStats::new();
        a.record_us(10);
        let mut b = LatencyStats::new();
        b.record_us(20);
        a.merge(&b);
        assert_eq!(a.count(), 2);
    }
}
