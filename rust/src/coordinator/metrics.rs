//! Latency/throughput accounting for the serving router and the perf pass.
//!
//! Every accumulator here is bounded: long-running serves must hold
//! constant memory, so counts and sums are tracked exactly (u64 running
//! totals) while percentile-bearing samples live in fixed-capacity rings
//! covering the most recent window.
//!
//! Durations are recorded in NANOSECONDS internally. The public accessors
//! stay in microseconds (rounded half-up), but sub-microsecond samples no
//! longer truncate to 0 — on tiny models a whole batch can complete in
//! hundreds of nanoseconds, and the old `as_micros()` path biased means
//! and percentiles down by up to 1µs per sample.

/// Samples retained for percentile estimation; counts/means stay exact
/// beyond this window.
pub const LATENCY_WINDOW: usize = 4096;

/// Recent batch sizes retained by [`BatchStats`].
pub const BATCH_WINDOW: usize = 1024;

/// Round a nanosecond sample to microseconds, half-up — `record_us(7)`
/// reads back as exactly 7, and a 500ns sample reads as 1µs, not 0.
#[inline]
fn ns_to_us(ns: u64) -> u64 {
    (ns + 500) / 1_000
}

#[derive(Clone, Debug, Default)]
pub struct LatencyStats {
    /// Ring of the most recent samples, in nanoseconds. While not full it
    /// is chronological from index 0; once full, `next` is the oldest
    /// slot (the ring unrolls as `window[next..] ++ window[..next]`).
    window: Vec<u64>,
    /// Next ring slot once the window is full.
    next: usize,
    /// Exact totals over the whole run.
    count: u64,
    sum_ns: u64,
}

impl LatencyStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_ns(&mut self, ns: u64) {
        self.count += 1;
        self.sum_ns = self.sum_ns.saturating_add(ns);
        self.push_window(ns);
    }

    pub fn record_us(&mut self, us: u64) {
        self.record_ns(us.saturating_mul(1_000));
    }

    fn push_window(&mut self, ns: u64) {
        if self.window.len() < LATENCY_WINDOW {
            self.window.push(ns);
        } else {
            self.window[self.next] = ns;
            self.next = (self.next + 1) % LATENCY_WINDOW;
        }
    }

    pub fn record(&mut self, d: std::time::Duration) {
        self.record_ns(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    /// The retained window unrolled oldest → newest. The ring cursor
    /// `next` points at the oldest slot only once the window is full;
    /// before that the window is already chronological from 0.
    fn chronological(&self) -> Vec<u64> {
        if self.window.len() < LATENCY_WINDOW || self.next == 0 {
            return self.window.clone();
        }
        let mut out = Vec::with_capacity(self.window.len());
        out.extend_from_slice(&self.window[self.next..]);
        out.extend_from_slice(&self.window[..self.next]);
        out
    }

    /// Merge another accumulator. Counts and sums add exactly; when the
    /// combined percentile windows exceed capacity, an evenly-spaced
    /// subsample keeps BOTH sources proportionally represented (naively
    /// pushing `other`'s window would overwrite this one's entirely).
    ///
    /// Both rings are unrolled chronologically BEFORE concatenation, so
    /// the merged window is oldest-first from slot 0 and the reset ring
    /// cursor is correct: post-merge `record*` calls overwrite the oldest
    /// blended samples, preserving the "most recent window" invariant.
    /// (The old code concatenated raw ring storage and then reset
    /// `next = 0`, so later records clobbered from an arbitrary point in
    /// the blend.)
    pub fn merge(&mut self, other: &LatencyStats) {
        self.count += other.count;
        self.sum_ns = self.sum_ns.saturating_add(other.sum_ns);
        let mut all = self.chronological();
        all.extend(other.chronological());
        if all.len() > LATENCY_WINDOW {
            let step = all.len() as f64 / LATENCY_WINDOW as f64;
            self.window = (0..LATENCY_WINDOW).map(|i| all[(i as f64 * step) as usize]).collect();
        } else {
            self.window = all;
        }
        // Chronological with the oldest at 0: slot 0 is the correct
        // overwrite point whether or not the merged window is full.
        self.next = 0;
    }

    /// Exact number of samples ever recorded (not capped by the window).
    pub fn count(&self) -> usize {
        self.count as usize
    }

    /// Exact mean over every sample ever recorded (µs, from the exact
    /// nanosecond sum — no per-sample truncation).
    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum_ns as f64 / 1_000.0 / self.count as f64
    }

    /// Nearest-rank percentiles over the retained window for a list of
    /// quantiles, sharing ONE sort of the window. `summary()` and report
    /// rows ask for p50/p99/p999 together — three separate
    /// [`Self::percentile_us`] calls would clone+sort the 4096-sample
    /// window three times.
    pub fn percentiles_us(&self, ps: &[f64]) -> Vec<u64> {
        if self.window.is_empty() {
            return vec![0; ps.len()];
        }
        let mut v = self.window.clone();
        v.sort_unstable();
        ps.iter()
            .map(|&p| {
                let rank = (p.clamp(0.0, 1.0) * v.len() as f64).ceil() as usize;
                ns_to_us(v[rank.clamp(1, v.len()) - 1])
            })
            .collect()
    }

    /// Nearest-rank percentile over the retained window (the most recent
    /// [`LATENCY_WINDOW`] samples): the smallest sample with at least
    /// `p·n` samples ≤ it, so high quantiles (p99.9) report an observed
    /// value instead of an interpolated one.
    pub fn percentile_us(&self, p: f64) -> u64 {
        self.percentiles_us(&[p])[0]
    }

    pub fn p50_us(&self) -> u64 {
        self.percentile_us(0.50)
    }

    pub fn p99_us(&self) -> u64 {
        self.percentile_us(0.99)
    }

    pub fn p999_us(&self) -> u64 {
        self.percentile_us(0.999)
    }

    pub fn summary(&self) -> String {
        let p = self.percentiles_us(&[0.50, 0.99, 0.999]);
        format!(
            "n={} mean={:.1}us p50={}us p99={}us p999={}us",
            self.count(),
            self.mean_us(),
            p[0],
            p[1],
            p[2]
        )
    }
}

/// Batch-size accounting with bounded memory: exact running count/sum plus
/// a fixed-capacity ring of the most recent sizes (replaces the unbounded
/// `Vec<usize>` the server used to grow per batch).
#[derive(Clone, Debug, Default)]
pub struct BatchStats {
    recent: Vec<usize>,
    next: usize,
    count: u64,
    sum: u64,
}

impl BatchStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, size: usize) {
        self.count += 1;
        self.sum += size as u64;
        if self.recent.len() < BATCH_WINDOW {
            self.recent.push(size);
        } else {
            self.recent[self.next] = size;
            self.next = (self.next + 1) % BATCH_WINDOW;
        }
    }

    /// Batches ever dispatched (exact).
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Requests ever dispatched (exact).
    pub fn requests(&self) -> u64 {
        self.sum
    }

    /// Exact mean batch size over the whole run.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum as f64 / self.count as f64
    }

    /// Largest batch in the retained window.
    pub fn max_recent(&self) -> usize {
        self.recent.iter().copied().max().unwrap_or(0)
    }

    /// The retained window of recent batch sizes (unordered ring).
    pub fn recent(&self) -> &[usize] {
        &self.recent
    }
}

/// Per-shard dispatch accounting for the variant-affine sharded router:
/// batch/group sizes dispatched from this shard's queue, plus how much of
/// its backlog was carried away by work stealing. Indexed by the shard
/// the requests were QUEUED on — `stolen_*` counts work other shards'
/// idle workers took from it, which is exactly the load-imbalance signal
/// the bench rows report.
#[derive(Clone, Debug, Default)]
pub struct ShardStats {
    /// Sizes of whole batches dispatched from this shard's queue
    /// (including stolen groups, which dispatch as their own batch).
    pub batches: BatchStats,
    /// Sizes of same-variant groups dispatched from this shard — the
    /// "mean same-variant batch size" metric of the mixed-traffic bench.
    pub groups: BatchStats,
    /// Whole same-variant groups stolen FROM this shard by idle workers
    /// of other shards.
    pub stolen_groups: u64,
    /// Requests those stolen groups carried.
    pub stolen_requests: u64,
}

impl ShardStats {
    pub fn summary(&self) -> String {
        format!(
            "batches={} mean_batch={:.2} mean_group={:.2} stolen_groups={} stolen_requests={}",
            self.batches.count(),
            self.batches.mean(),
            self.groups.mean(),
            self.stolen_groups,
            self.stolen_requests
        )
    }
}

/// Per-variant serving metrics: end-to-end latency with its queue/compute
/// split, request count, and deadline misses.
#[derive(Clone, Debug, Default)]
pub struct VariantStats {
    /// submit → response (queue + compute).
    pub total: LatencyStats,
    /// submit → batch dispatch.
    pub queue: LatencyStats,
    /// Batch compute wall time attributed to each request.
    pub compute: LatencyStats,
    /// Same-variant group sizes this variant's requests dispatched in —
    /// the per-variant service-rate denominator of routed admission
    /// (a variant served in big coalesced groups drains faster per
    /// request than the global mean batch would suggest, and vice versa).
    pub batches: BatchStats,
    pub requests: u64,
    pub deadline_misses: u64,
    /// Requests shed at submit by deadline-aware admission control
    /// (never queued; disjoint from `deadline_misses`, which are triaged
    /// at dispatch).
    pub admission_sheds: u64,
}

impl VariantStats {
    pub fn summary(&self) -> String {
        format!(
            "requests={} misses={} sheds={} mean_group={:.2} total[{}] queue[{}] compute[{}]",
            self.requests,
            self.deadline_misses,
            self.admission_sheds,
            self.batches.mean(),
            self.total.summary(),
            self.queue.summary(),
            self.compute.summary()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut s = LatencyStats::new();
        for i in 1..=100 {
            s.record_us(i);
        }
        assert_eq!(s.count(), 100);
        assert!((s.mean_us() - 50.5).abs() < 1e-9);
        assert!(s.p50_us() <= s.p99_us());
        assert_eq!(s.percentile_us(0.0), 1);
        assert_eq!(s.percentile_us(1.0), 100);
    }

    #[test]
    fn nearest_rank_percentiles() {
        // 1000 samples 1..=1000 µs: nearest-rank p is exactly sample
        // ⌈p·n⌉ — the µs accessors stay exact on µs-granular input even
        // though storage is nanoseconds.
        let mut s = LatencyStats::new();
        for i in 1..=1000 {
            s.record_us(i);
        }
        assert_eq!(s.p50_us(), 500);
        assert_eq!(s.p99_us(), 990);
        assert_eq!(s.p999_us(), 999);
        assert_eq!(s.percentile_us(1.0), 1000);
        assert_eq!(s.percentile_us(0.0), 1);
        // One shared sort returns the same values as the per-call path.
        assert_eq!(s.percentiles_us(&[0.50, 0.99, 0.999]), vec![500, 990, 999]);
        // On a tiny window every quantile is an observed sample.
        let mut t = LatencyStats::new();
        t.record_us(7);
        assert_eq!(t.p50_us(), 7);
        assert_eq!(t.p999_us(), 7);
        assert!(s.summary().contains("p999="));
    }

    #[test]
    fn sub_microsecond_samples_are_not_truncated_to_zero() {
        // The old `as_micros()` path recorded these as 0, biasing the
        // mean down by up to 1µs on tiny models.
        let mut s = LatencyStats::new();
        for _ in 0..100 {
            s.record(std::time::Duration::from_nanos(500));
        }
        assert!((s.mean_us() - 0.5).abs() < 1e-9, "mean {}us", s.mean_us());
        // Half-up rounding: 500ns reads back as 1µs, not 0.
        assert_eq!(s.p50_us(), 1);
        let mut t = LatencyStats::new();
        t.record(std::time::Duration::from_nanos(499));
        assert_eq!(t.p50_us(), 0);
        assert!((t.mean_us() - 0.499).abs() < 1e-9);
    }

    #[test]
    fn empty_stats_safe() {
        let s = LatencyStats::new();
        assert_eq!(s.mean_us(), 0.0);
        assert_eq!(s.p99_us(), 0);
        assert_eq!(s.percentiles_us(&[0.5, 0.99]), vec![0, 0]);
    }

    #[test]
    fn merge_combines() {
        let mut a = LatencyStats::new();
        a.record_us(10);
        let mut b = LatencyStats::new();
        b.record_us(20);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert!((a.mean_us() - 15.0).abs() < 1e-9);
    }

    #[test]
    fn latency_window_bounds_memory_but_count_exact() {
        let mut s = LatencyStats::new();
        let n = LATENCY_WINDOW * 3;
        for i in 0..n {
            s.record_us(i as u64);
        }
        assert_eq!(s.count(), n);
        assert!(s.window.len() <= LATENCY_WINDOW);
        // Mean stays exact over the full run.
        let expect = (0..n as u64).sum::<u64>() as f64 / n as f64;
        assert!((s.mean_us() - expect).abs() < 1e-6);
        // Percentiles reflect the recent window (all ≥ n − window).
        assert!(s.percentile_us(0.0) >= (n - LATENCY_WINDOW) as u64);
    }

    #[test]
    fn merge_of_full_windows_represents_both_sources() {
        let mut a = LatencyStats::new();
        let mut b = LatencyStats::new();
        for _ in 0..LATENCY_WINDOW {
            a.record_us(10);
            b.record_us(1000);
        }
        a.merge(&b);
        assert_eq!(a.count(), 2 * LATENCY_WINDOW);
        // Percentile window must still see both populations, not just the
        // last-merged one.
        assert_eq!(a.percentile_us(0.0), 10);
        assert_eq!(a.percentile_us(1.0), 1000);
        assert_eq!(a.percentile_us(0.25), 10);
        assert_eq!(a.percentile_us(0.75), 1000);
        assert!(a.window.len() <= LATENCY_WINDOW);
    }

    #[test]
    fn post_merge_records_overwrite_oldest_not_newest() {
        // The merge cursor bug: merging used to reset `next = 0` over a
        // non-chronological window, so later records clobbered an
        // arbitrary blend point. Now the merged window is chronological
        // and a full window of fresh samples replaces the blend exactly.
        let mut a = LatencyStats::new();
        let mut b = LatencyStats::new();
        for i in 0..LATENCY_WINDOW {
            // Drive `a` past the window so its ring cursor is mid-stream.
            a.record_us(10);
            a.record_us(10 + (i % 3) as u64);
            b.record_us(1000);
        }
        a.merge(&b);
        // Fresh samples after the merge displace the OLDEST blended
        // entries first: after exactly LATENCY_WINDOW fresh records the
        // window holds only fresh samples.
        for _ in 0..LATENCY_WINDOW {
            a.record_us(77);
        }
        assert_eq!(a.percentile_us(0.0), 77);
        assert_eq!(a.percentile_us(1.0), 77);
        // And after HALF a window of fresh samples, both populations are
        // present — the blend was overwritten from the oldest end, not
        // wiped wholesale.
        let mut c = LatencyStats::new();
        let mut d = LatencyStats::new();
        for _ in 0..LATENCY_WINDOW {
            c.record_us(10);
            d.record_us(1000);
        }
        c.merge(&d);
        for _ in 0..LATENCY_WINDOW / 2 {
            c.record_us(77);
        }
        assert_eq!(c.percentile_us(1.0), 1000, "newest blended samples must survive");
        assert_eq!(c.percentile_us(0.0), 10, "not-yet-overwritten blend must survive");
    }

    #[test]
    fn batch_stats_bounded_and_exact() {
        let mut b = BatchStats::new();
        for i in 0..(BATCH_WINDOW * 4) {
            b.record(1 + i % 7);
        }
        assert_eq!(b.count(), (BATCH_WINDOW * 4) as u64);
        assert!(b.recent().len() <= BATCH_WINDOW);
        let sum: u64 = (0..(BATCH_WINDOW * 4) as u64).map(|i| 1 + i % 7).sum();
        assert!((b.mean() - sum as f64 / (BATCH_WINDOW * 4) as f64).abs() < 1e-9);
        assert!(b.max_recent() <= 7);
    }

    #[test]
    fn empty_batch_stats_safe() {
        let b = BatchStats::new();
        assert_eq!(b.mean(), 0.0);
        assert_eq!(b.max_recent(), 0);
    }

    #[test]
    fn shard_stats_summary_renders() {
        let mut s = ShardStats::default();
        s.batches.record(4);
        s.groups.record(2);
        s.groups.record(2);
        s.stolen_groups = 1;
        s.stolen_requests = 2;
        let out = s.summary();
        assert!(out.contains("mean_group=2.00"), "{out}");
        assert!(out.contains("stolen_groups=1"), "{out}");
    }

    #[test]
    fn variant_stats_summary_renders() {
        let mut v = VariantStats::default();
        v.requests = 3;
        v.total.record_us(100);
        v.batches.record(3);
        let s = v.summary();
        assert!(s.contains("requests=3"));
        assert!(s.contains("mean_group=3.00"));
    }
}
