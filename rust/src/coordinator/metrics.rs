//! Latency/throughput accounting for the serving router and the perf pass.
//!
//! Every accumulator here is bounded: long-running serves must hold
//! constant memory, so counts and sums are tracked exactly (u64 running
//! totals) while percentile-bearing samples live in fixed-capacity rings
//! covering the most recent window.

/// Samples retained for percentile estimation; counts/means stay exact
/// beyond this window.
pub const LATENCY_WINDOW: usize = 4096;

/// Recent batch sizes retained by [`BatchStats`].
pub const BATCH_WINDOW: usize = 1024;

#[derive(Clone, Debug, Default)]
pub struct LatencyStats {
    /// Ring of the most recent samples (percentiles window).
    window: Vec<u64>,
    /// Next ring slot once the window is full.
    next: usize,
    /// Exact totals over the whole run.
    count: u64,
    sum_us: u64,
}

impl LatencyStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_us(&mut self, us: u64) {
        self.count += 1;
        self.sum_us += us;
        self.push_window(us);
    }

    fn push_window(&mut self, us: u64) {
        if self.window.len() < LATENCY_WINDOW {
            self.window.push(us);
        } else {
            self.window[self.next] = us;
            self.next = (self.next + 1) % LATENCY_WINDOW;
        }
    }

    pub fn record(&mut self, d: std::time::Duration) {
        self.record_us(d.as_micros() as u64);
    }

    /// Merge another accumulator. Counts and sums add exactly; when the
    /// combined percentile windows exceed capacity, an evenly-spaced
    /// subsample keeps BOTH sources proportionally represented (naively
    /// pushing `other`'s window would overwrite this one's entirely).
    pub fn merge(&mut self, other: &LatencyStats) {
        self.count += other.count;
        self.sum_us += other.sum_us;
        let mut all = Vec::with_capacity(self.window.len() + other.window.len());
        all.extend_from_slice(&self.window);
        all.extend_from_slice(&other.window);
        if all.len() > LATENCY_WINDOW {
            let step = all.len() as f64 / LATENCY_WINDOW as f64;
            self.window = (0..LATENCY_WINDOW).map(|i| all[(i as f64 * step) as usize]).collect();
        } else {
            self.window = all;
        }
        self.next = 0;
    }

    /// Exact number of samples ever recorded (not capped by the window).
    pub fn count(&self) -> usize {
        self.count as usize
    }

    /// Exact mean over every sample ever recorded.
    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum_us as f64 / self.count as f64
    }

    /// Nearest-rank percentile over the retained window (the most recent
    /// [`LATENCY_WINDOW`] samples): the smallest sample with at least
    /// `p·n` samples ≤ it, so high quantiles (p99.9) report an observed
    /// value instead of an interpolated one.
    pub fn percentile_us(&self, p: f64) -> u64 {
        if self.window.is_empty() {
            return 0;
        }
        let mut v = self.window.clone();
        v.sort_unstable();
        let rank = (p.clamp(0.0, 1.0) * v.len() as f64).ceil() as usize;
        v[rank.clamp(1, v.len()) - 1]
    }

    pub fn p50_us(&self) -> u64 {
        self.percentile_us(0.50)
    }

    pub fn p99_us(&self) -> u64 {
        self.percentile_us(0.99)
    }

    pub fn p999_us(&self) -> u64 {
        self.percentile_us(0.999)
    }

    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.1}us p50={}us p99={}us p999={}us",
            self.count(),
            self.mean_us(),
            self.p50_us(),
            self.p99_us(),
            self.p999_us()
        )
    }
}

/// Batch-size accounting with bounded memory: exact running count/sum plus
/// a fixed-capacity ring of the most recent sizes (replaces the unbounded
/// `Vec<usize>` the server used to grow per batch).
#[derive(Clone, Debug, Default)]
pub struct BatchStats {
    recent: Vec<usize>,
    next: usize,
    count: u64,
    sum: u64,
}

impl BatchStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, size: usize) {
        self.count += 1;
        self.sum += size as u64;
        if self.recent.len() < BATCH_WINDOW {
            self.recent.push(size);
        } else {
            self.recent[self.next] = size;
            self.next = (self.next + 1) % BATCH_WINDOW;
        }
    }

    /// Batches ever dispatched (exact).
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Requests ever dispatched (exact).
    pub fn requests(&self) -> u64 {
        self.sum
    }

    /// Exact mean batch size over the whole run.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum as f64 / self.count as f64
    }

    /// Largest batch in the retained window.
    pub fn max_recent(&self) -> usize {
        self.recent.iter().copied().max().unwrap_or(0)
    }

    /// The retained window of recent batch sizes (unordered ring).
    pub fn recent(&self) -> &[usize] {
        &self.recent
    }
}

/// Per-variant serving metrics: end-to-end latency with its queue/compute
/// split, request count, and deadline misses.
#[derive(Clone, Debug, Default)]
pub struct VariantStats {
    /// submit → response (queue + compute).
    pub total: LatencyStats,
    /// submit → batch dispatch.
    pub queue: LatencyStats,
    /// Batch compute wall time attributed to each request.
    pub compute: LatencyStats,
    pub requests: u64,
    pub deadline_misses: u64,
    /// Requests shed at submit by deadline-aware admission control
    /// (never queued; disjoint from `deadline_misses`, which are triaged
    /// at dispatch).
    pub admission_sheds: u64,
}

impl VariantStats {
    pub fn summary(&self) -> String {
        format!(
            "requests={} misses={} sheds={} total[{}] queue[{}] compute[{}]",
            self.requests,
            self.deadline_misses,
            self.admission_sheds,
            self.total.summary(),
            self.queue.summary(),
            self.compute.summary()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut s = LatencyStats::new();
        for i in 1..=100 {
            s.record_us(i);
        }
        assert_eq!(s.count(), 100);
        assert!((s.mean_us() - 50.5).abs() < 1e-9);
        assert!(s.p50_us() <= s.p99_us());
        assert_eq!(s.percentile_us(0.0), 1);
        assert_eq!(s.percentile_us(1.0), 100);
    }

    #[test]
    fn nearest_rank_percentiles() {
        // 1000 samples 1..=1000: nearest-rank p is exactly sample ⌈p·n⌉.
        let mut s = LatencyStats::new();
        for i in 1..=1000 {
            s.record_us(i);
        }
        assert_eq!(s.p50_us(), 500);
        assert_eq!(s.p99_us(), 990);
        assert_eq!(s.p999_us(), 999);
        assert_eq!(s.percentile_us(1.0), 1000);
        assert_eq!(s.percentile_us(0.0), 1);
        // On a tiny window every quantile is an observed sample.
        let mut t = LatencyStats::new();
        t.record_us(7);
        assert_eq!(t.p50_us(), 7);
        assert_eq!(t.p999_us(), 7);
        assert!(s.summary().contains("p999="));
    }

    #[test]
    fn empty_stats_safe() {
        let s = LatencyStats::new();
        assert_eq!(s.mean_us(), 0.0);
        assert_eq!(s.p99_us(), 0);
    }

    #[test]
    fn merge_combines() {
        let mut a = LatencyStats::new();
        a.record_us(10);
        let mut b = LatencyStats::new();
        b.record_us(20);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert!((a.mean_us() - 15.0).abs() < 1e-9);
    }

    #[test]
    fn latency_window_bounds_memory_but_count_exact() {
        let mut s = LatencyStats::new();
        let n = LATENCY_WINDOW * 3;
        for i in 0..n {
            s.record_us(i as u64);
        }
        assert_eq!(s.count(), n);
        assert!(s.window.len() <= LATENCY_WINDOW);
        // Mean stays exact over the full run.
        let expect = (0..n as u64).sum::<u64>() as f64 / n as f64;
        assert!((s.mean_us() - expect).abs() < 1e-6);
        // Percentiles reflect the recent window (all ≥ n − window).
        assert!(s.percentile_us(0.0) >= (n - LATENCY_WINDOW) as u64);
    }

    #[test]
    fn merge_of_full_windows_represents_both_sources() {
        let mut a = LatencyStats::new();
        let mut b = LatencyStats::new();
        for _ in 0..LATENCY_WINDOW {
            a.record_us(10);
            b.record_us(1000);
        }
        a.merge(&b);
        assert_eq!(a.count(), 2 * LATENCY_WINDOW);
        // Percentile window must still see both populations, not just the
        // last-merged one.
        assert_eq!(a.percentile_us(0.0), 10);
        assert_eq!(a.percentile_us(1.0), 1000);
        assert_eq!(a.percentile_us(0.25), 10);
        assert_eq!(a.percentile_us(0.75), 1000);
        assert!(a.window.len() <= LATENCY_WINDOW);
    }

    #[test]
    fn batch_stats_bounded_and_exact() {
        let mut b = BatchStats::new();
        for i in 0..(BATCH_WINDOW * 4) {
            b.record(1 + i % 7);
        }
        assert_eq!(b.count(), (BATCH_WINDOW * 4) as u64);
        assert!(b.recent().len() <= BATCH_WINDOW);
        let sum: u64 = (0..(BATCH_WINDOW * 4) as u64).map(|i| 1 + i % 7).sum();
        assert!((b.mean() - sum as f64 / (BATCH_WINDOW * 4) as f64).abs() < 1e-9);
        assert!(b.max_recent() <= 7);
    }

    #[test]
    fn empty_batch_stats_safe() {
        let b = BatchStats::new();
        assert_eq!(b.mean(), 0.0);
        assert_eq!(b.max_recent(), 0);
    }

    #[test]
    fn variant_stats_summary_renders() {
        let mut v = VariantStats::default();
        v.requests = 3;
        v.total.record_us(100);
        let s = v.summary();
        assert!(s.contains("requests=3"));
    }
}
