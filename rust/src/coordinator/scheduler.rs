//! Layer-parallel PTQ scheduler: quantizes every (selected) layer of a
//! MiniVLA across worker threads — each layer is an independent pure job
//! (W, CalibData) → Ŵ, so the schedule is a simple dynamic work queue.

use std::collections::HashMap;

use crate::methods::traits::{Binarizer, CalibData, Component};
use crate::model::MiniVla;
use crate::quant::group::QuantStats;
use crate::util::threadpool::parallel_map;

/// Per-run report: layer errors, aggregate bit width, wall time.
#[derive(Clone, Debug)]
pub struct QuantJobReport {
    pub method: String,
    pub layers: Vec<(String, f64)>,
    pub stats: QuantStats,
    pub mean_rel_err: f64,
    pub wall_secs: f64,
}

impl QuantJobReport {
    pub fn bits_per_weight(&self) -> f64 {
        self.stats.bits_per_weight()
    }
}

/// Quantize `components` of `model` with `method`, layer-parallel over
/// `threads` workers. Returns the quantized model and the job report.
pub fn quantize_model(
    model: &MiniVla,
    calib: &HashMap<String, CalibData>,
    method: &dyn Binarizer,
    components: &[Component],
    threads: usize,
) -> (MiniVla, QuantJobReport) {
    let start = std::time::Instant::now();
    let names = model.store.quantizable_layers(Some(components));
    let results = parallel_map(names.len(), threads, |i| {
        let name = &names[i];
        let w = model.store.get(name);
        let cd = calib
            .get(name)
            .cloned()
            .unwrap_or_else(|| CalibData::identity(w.cols, model.store.component_of(name)));
        let q = method.quantize(w, &cd);
        (name.clone(), q)
    });
    let mut out = model.clone();
    let mut stats = QuantStats::default();
    let mut layers = Vec::with_capacity(results.len());
    let mut err_sum = 0.0;
    for (name, q) in results {
        stats.add(&q.stats);
        err_sum += q.rel_frob_err;
        layers.push((name.clone(), q.rel_frob_err));
        out.store.set(&name, q.w_hat);
    }
    let n = layers.len().max(1) as f64;
    let report = QuantJobReport {
        method: method.name().to_string(),
        layers,
        stats,
        mean_rel_err: err_sum / n,
        wall_secs: start.elapsed().as_secs_f64(),
    };
    (out, report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::methods::Rtn;
    use crate::model::{HeadKind, VlaConfig};

    #[test]
    fn parallel_matches_serial() {
        let model = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
        let calib = HashMap::new();
        let comps = [Component::Vision, Component::Language];
        let (q1, r1) = quantize_model(&model, &calib, &Rtn::new(), &comps, 1);
        let (q4, r4) = quantize_model(&model, &calib, &Rtn::new(), &comps, 4);
        assert_eq!(r1.layers.len(), r4.layers.len());
        for name in model.store.quantizable_layers(Some(&comps)) {
            assert!(q1.store.get(&name).dist_sq(q4.store.get(&name)) < 1e-12, "{name}");
        }
        assert!((r1.mean_rel_err - r4.mean_rel_err).abs() < 1e-12);
    }

    #[test]
    fn untouched_components_stay_fp() {
        let model = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
        let calib = HashMap::new();
        let (q, _) = quantize_model(&model, &calib, &Rtn::new(), &[Component::Vision], 2);
        for name in model.store.quantizable_layers(Some(&[Component::Language])) {
            assert_eq!(q.store.get(&name), model.store.get(&name), "{name}");
        }
        // Vision actually changed.
        let vis = model.store.quantizable_layers(Some(&[Component::Vision]));
        assert!(vis.iter().any(|n| q.store.get(n) != model.store.get(n)));
    }

    #[test]
    fn report_has_bits_and_errors() {
        let model = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
        let calib = HashMap::new();
        let comps = [Component::Language];
        let (_, r) = quantize_model(&model, &calib, &Rtn::new(), &comps, 2);
        assert!(r.bits_per_weight() > 1.0);
        assert!(r.mean_rel_err > 0.0);
        assert!(!r.layers.is_empty());
    }
}
