//! Layer-parallel PTQ scheduler: quantizes every (selected) layer of a
//! MiniVLA across worker threads — each layer is an independent pure job
//! (W, CalibData) → Ŵ, so the schedule is a simple dynamic work queue.
//!
//! Commitment: when a method returns a packed deploy form
//! ([`crate::methods::traits::QuantizedLayer::packed`]), the scheduler
//! stores it as [`crate::model::params::WeightRepr::Packed`] — the served
//! model then executes on the 1-bit kernels directly. Methods without a
//! packed form (the FP passthrough) commit dense reconstructions.

use std::collections::HashMap;
use std::sync::Arc;

use crate::coordinator::registry::{ModelRegistry, RegistryError};
use crate::methods::traits::{Binarizer, CalibData, Component};
use crate::model::{ActPrecision, MiniVla};
use crate::quant::group::QuantStats;
use crate::util::threadpool::parallel_map;

/// Per-run report: layer errors, aggregate bit width, realized memory,
/// wall time.
#[derive(Clone, Debug)]
pub struct QuantJobReport {
    pub method: String,
    pub layers: Vec<(String, f64)>,
    pub stats: QuantStats,
    pub mean_rel_err: f64,
    /// Mean relative Frobenius error of the *deployed* weights (packed
    /// dequantization where committed packed, else Ŵ) against W. Equals
    /// `mean_rel_err` up to the deploy-packing tolerance.
    pub mean_deploy_rel_err: f64,
    /// Layers committed as 1-bit representations (repacked OR
    /// transform-exact).
    pub packed_layers: usize,
    /// Subset of `packed_layers` committed in the transform-domain exact
    /// representation ([`crate::model::params::WeightRepr::TransformPacked`]).
    pub transform_layers: usize,
    /// Bytes the quantized store actually keeps resident (whole model,
    /// FP layers included at f32).
    pub resident_bytes: usize,
    /// Bytes the same store holds all-dense (the FP baseline).
    pub dense_bytes: usize,
    pub wall_secs: f64,
}

impl QuantJobReport {
    pub fn bits_per_weight(&self) -> f64 {
        self.stats.bits_per_weight()
    }

    /// Realized whole-model compression (resident vs all-dense f32).
    pub fn realized_compression(&self) -> f64 {
        self.dense_bytes as f64 / self.resident_bytes.max(1) as f64
    }
}

/// Quantize `components` of `model` with `method`, layer-parallel over
/// `threads` workers. Returns the quantized model (packed layers
/// committed as [`crate::model::params::WeightRepr::Packed`]) and the job
/// report.
pub fn quantize_model(
    model: &MiniVla,
    calib: &HashMap<String, CalibData>,
    method: &dyn Binarizer,
    components: &[Component],
    threads: usize,
) -> (MiniVla, QuantJobReport) {
    let start = std::time::Instant::now();
    let names = model.store.quantizable_layers(Some(components));
    let results = parallel_map(names.len(), threads, |i| {
        let name = &names[i];
        let w = model.store.get(name);
        let cd = calib
            .get(name)
            .cloned()
            .unwrap_or_else(|| CalibData::identity(w.cols, model.store.component_of(name)));
        let q = method.quantize(w, &cd);
        // Deployed-weight error (deployed-form dequantization vs W),
        // computed here so the dense materialization stays inside the
        // worker. The deploy precedence mirrors the commit below: packed,
        // else transform-exact, else dense Ŵ.
        let denom = w.frob_norm_sq().max(1e-30);
        let deploy_err = match (&q.packed, &q.transform_packed) {
            (Some(p), _) => w.dist_sq(&p.dequantize()) / denom,
            (None, Some(t)) => w.dist_sq(&t.dequantize()) / denom,
            (None, None) => q.rel_frob_err,
        };
        (name.clone(), q, deploy_err)
    });
    let mut out = model.clone();
    let mut stats = QuantStats::default();
    let mut layers = Vec::with_capacity(results.len());
    let mut err_sum = 0.0;
    let mut deploy_err_sum = 0.0;
    let mut packed_layers = 0usize;
    let mut transform_layers = 0usize;
    for (name, q, deploy_err) in results {
        stats.add(&q.stats);
        err_sum += q.rel_frob_err;
        deploy_err_sum += deploy_err;
        layers.push((name.clone(), q.rel_frob_err));
        match (q.packed, q.transform_packed) {
            (Some(p), _) => {
                out.store.set_packed(&name, p);
                packed_layers += 1;
            }
            // A method committing ONLY a transform-exact form is still a
            // 1-bit commit the store executes — never silently dropped to
            // the dense reconstruction.
            (None, Some(t)) => {
                out.store.set_transform_packed(&name, t);
                packed_layers += 1;
                transform_layers += 1;
            }
            (None, None) => out.store.set(&name, q.w_hat),
        }
    }
    let n = layers.len().max(1) as f64;
    let report = QuantJobReport {
        method: method.name().to_string(),
        layers,
        stats,
        mean_rel_err: err_sum / n,
        mean_deploy_rel_err: deploy_err_sum / n,
        packed_layers,
        transform_layers,
        resident_bytes: out.store.resident_weight_bytes(),
        dense_bytes: out.store.dense_weight_bytes(),
        wall_secs: start.elapsed().as_secs_f64(),
    };
    (out, report)
}

/// Quantize `components` of `model` with `method` and commit the
/// **transform-domain exact** deploy form of every layer: the committed
/// Haar-domain bitplane serves as
/// [`crate::model::params::WeightRepr::TransformPacked`] (zero residual
/// planes — see `quant::transform`). `variant` names the target variant
/// for error reporting. A quantizable layer for which the method committed
/// a packed form but NO transform form is a typed
/// [`RegistryError::UnsupportedRepr`] — requesting exact serving from a
/// direct-domain method must fail loudly, never silently fall back to the
/// approximate repack. Layers the method leaves dense (the FP passthrough)
/// commit dense: dense f32 is trivially exact.
pub fn quantize_model_exact(
    model: &MiniVla,
    calib: &HashMap<String, CalibData>,
    method: &dyn Binarizer,
    components: &[Component],
    threads: usize,
    variant: &str,
) -> Result<(MiniVla, QuantJobReport), RegistryError> {
    let start = std::time::Instant::now();
    let names = model.store.quantizable_layers(Some(components));
    let results = parallel_map(names.len(), threads, |i| {
        let name = &names[i];
        let w = model.store.get(name);
        let cd = calib
            .get(name)
            .cloned()
            .unwrap_or_else(|| CalibData::identity(w.cols, model.store.component_of(name)));
        let q = method.quantize(w, &cd);
        let denom = w.frob_norm_sq().max(1e-30);
        let deploy_err = match &q.transform_packed {
            Some(t) => w.dist_sq(&t.dequantize()) / denom,
            None => q.rel_frob_err,
        };
        (name.clone(), q, deploy_err)
    });
    let mut out = model.clone();
    let mut stats = QuantStats::default();
    let mut layers = Vec::with_capacity(results.len());
    let mut err_sum = 0.0;
    let mut deploy_err_sum = 0.0;
    let mut transform_layers = 0usize;
    for (name, q, deploy_err) in results {
        stats.add(&q.stats);
        err_sum += q.rel_frob_err;
        deploy_err_sum += deploy_err;
        layers.push((name.clone(), q.rel_frob_err));
        match q.transform_packed {
            Some(t) => {
                out.store.set_transform_packed(&name, t);
                transform_layers += 1;
            }
            None if q.packed.is_some() => {
                return Err(RegistryError::UnsupportedRepr {
                    variant: variant.to_string(),
                    layer: name,
                    wanted: "transform-exact",
                });
            }
            None => out.store.set(&name, q.w_hat),
        }
    }
    out.cfg.deploy_repr = crate::model::DeployRepr::TransformExact;
    let n = layers.len().max(1) as f64;
    let report = QuantJobReport {
        method: method.name().to_string(),
        layers,
        stats,
        mean_rel_err: err_sum / n,
        mean_deploy_rel_err: deploy_err_sum / n,
        packed_layers: transform_layers,
        transform_layers,
        resident_bytes: out.store.resident_weight_bytes(),
        dense_bytes: out.store.dense_weight_bytes(),
        wall_secs: start.elapsed().as_secs_f64(),
    };
    Ok((out, report))
}

/// The `quantize → register → serve` flow in one call: quantize `model`
/// with `method`, commit the packed layers, and publish the result to
/// `registry` under `variant` so a live
/// [`crate::coordinator::server::PolicyServer`] can route requests to it
/// by name. Returns the job report.
pub fn quantize_into_registry(
    registry: &ModelRegistry,
    variant: &str,
    model: &MiniVla,
    calib: &HashMap<String, CalibData>,
    method: &dyn Binarizer,
    components: &[Component],
    threads: usize,
) -> Result<QuantJobReport, RegistryError> {
    let (qm, report) = quantize_model(model, calib, method, components, threads);
    registry.register(variant, Arc::new(qm))?;
    Ok(report)
}

/// The transform-exact `quantize → register → serve` flow: quantize with
/// [`quantize_model_exact`] (typed [`RegistryError::UnsupportedRepr`] if
/// the method commits no transform-domain form) and publish the result
/// under `variant` — the registry's `*-exact` twin of a `*-packed`
/// variant, serving the committed Haar-domain bitplanes with zero residual
/// planes.
pub fn quantize_exact_into_registry(
    registry: &ModelRegistry,
    variant: &str,
    model: &MiniVla,
    calib: &HashMap<String, CalibData>,
    method: &dyn Binarizer,
    components: &[Component],
    threads: usize,
) -> Result<QuantJobReport, RegistryError> {
    let (qm, report) = quantize_model_exact(model, calib, method, components, threads, variant)?;
    registry.register(variant, Arc::new(qm))?;
    Ok(report)
}

/// Register the W1A8 twin of an already-registered packed variant under
/// `"{base_variant}-a8"`: same weights with the activation precision
/// switched to [`ActPrecision::Int8`], so the serving router's batched
/// forward runs the integer packed kernels for requests naming the twin.
/// The twin is a store *copy* (no repack — and packed layers are ~32×
/// smaller than dense, so the duplicate is small next to one dense
/// checkpoint; sharing the store behind one `Arc` with per-entry
/// precision is a noted follow-on if twin counts grow). Returns the
/// twin's name.
pub fn register_a8_variant(
    registry: &ModelRegistry,
    base_variant: &str,
) -> Result<String, RegistryError> {
    let base = registry
        .get(base_variant)
        .ok_or_else(|| RegistryError::UnknownVariant { variant: base_variant.to_string() })?;
    let name = format!("{base_variant}-a8");
    let twin = (*base).clone().with_act_precision(ActPrecision::Int8);
    registry.register(&name, Arc::new(twin))?;
    Ok(name)
}

/// Register the calibrated-static-scale twin of an already-registered
/// variant under `"{base_variant}-static"`: the base model is cloned,
/// `calib::scales` sweeps the demo stream once to pin per-layer static
/// activation scales (max|x| — or max|z| for transform-exact layers —
/// over the stream, /127), and the twin serves with
/// [`crate::model::ActScaleMode::Static`] + [`ActPrecision::Int8`] so
/// the W1A8 hot path skips the per-token max sweeps. Returns (twin name,
/// calibrated layer count). Layers the sweep never saw stay on the
/// per-token fallback.
pub fn register_static_scale_variant(
    registry: &ModelRegistry,
    base_variant: &str,
    demos: &[Vec<crate::sim::episode::DemoStep>],
    max_steps: usize,
) -> Result<(String, usize), RegistryError> {
    register_static_scale_variant_clip(
        registry,
        base_variant,
        demos,
        max_steps,
        crate::calib::ScaleClip::Max,
    )
}

/// [`register_static_scale_variant`] with an explicit
/// [`crate::calib::ScaleClip`] policy. The max-clip twin keeps the
/// historical `"{base}-static"` name (bit-identical to the old flow);
/// the percentile twin registers as `"{base}-static-p999"` so both can
/// serve side by side for the tokens/s ↔ action-MSE comparison the perf
/// baseline records.
pub fn register_static_scale_variant_clip(
    registry: &ModelRegistry,
    base_variant: &str,
    demos: &[Vec<crate::sim::episode::DemoStep>],
    max_steps: usize,
    clip: crate::calib::ScaleClip,
) -> Result<(String, usize), RegistryError> {
    let base = registry
        .get(base_variant)
        .ok_or_else(|| RegistryError::UnknownVariant { variant: base_variant.to_string() })?;
    let name = match clip {
        crate::calib::ScaleClip::Max => format!("{base_variant}-static"),
        crate::calib::ScaleClip::Percentile => format!("{base_variant}-static-p999"),
    };
    let mut twin = (*base).clone().with_act_precision(ActPrecision::Int8);
    let layers =
        crate::calib::scales::calibrate_static_scales_clip(&mut twin, demos, max_steps, clip);
    registry.register(&name, Arc::new(twin))?;
    Ok((name, layers))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::methods::{HbVla, Rtn};
    use crate::model::{HeadKind, VlaConfig};

    #[test]
    fn parallel_matches_serial() {
        let model = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
        let calib = HashMap::new();
        let comps = [Component::Vision, Component::Language];
        let (q1, r1) = quantize_model(&model, &calib, &Rtn::new(), &comps, 1);
        let (q4, r4) = quantize_model(&model, &calib, &Rtn::new(), &comps, 4);
        assert_eq!(r1.layers.len(), r4.layers.len());
        for name in model.store.quantizable_layers(Some(&comps)) {
            let d1 = q1.store.dense_view(&name);
            let d4 = q4.store.dense_view(&name);
            assert!(d1.dist_sq(&d4) < 1e-12, "{name}");
        }
        assert!((r1.mean_rel_err - r4.mean_rel_err).abs() < 1e-12);
    }

    #[test]
    fn untouched_components_stay_fp() {
        let model = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
        let calib = HashMap::new();
        let (q, _) = quantize_model(&model, &calib, &Rtn::new(), &[Component::Vision], 2);
        for name in model.store.quantizable_layers(Some(&[Component::Language])) {
            assert!(!q.store.is_packed(&name), "{name}");
            assert_eq!(q.store.get(&name), model.store.get(&name), "{name}");
        }
        // Vision actually changed — committed as packed 1-bit layers.
        let vis = model.store.quantizable_layers(Some(&[Component::Vision]));
        assert!(vis.iter().all(|n| q.store.is_packed(n)));
    }

    #[test]
    fn commits_packed_and_accounts_memory() {
        let model = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
        let calib = HashMap::new();
        let comps = [Component::Vision, Component::Language];
        let (qm, rep) = quantize_model(&model, &calib, &Rtn::new(), &comps, 2);
        assert_eq!(rep.packed_layers, rep.layers.len());
        assert!(rep.resident_bytes < rep.dense_bytes, "{rep:?}");
        assert!(rep.realized_compression() > 1.0);
        // RTN's packed commit is exact: deploy error equals the method's.
        assert!((rep.mean_deploy_rel_err - rep.mean_rel_err).abs() < 1e-6, "{rep:?}");
        // The committed model still runs a forward pass (on the packed
        // kernels) and stays finite.
        let mut rng = crate::util::rng::Rng::new(9);
        let v =
            crate::tensor::matrix::Matrix::gauss(qm.cfg.d_vis_in, qm.cfg.n_visual, 1.0, &mut rng);
        let p: Vec<f32> = (0..qm.cfg.d_proprio).map(|_| rng.gauss() as f32).collect();
        let feat = qm.features(&v, 3, &p, &mut None);
        assert!(feat.iter().all(|f| f.is_finite()));
    }

    #[test]
    fn transform_method_deploy_error_close_to_method_error() {
        let model = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
        let calib = HashMap::new();
        let (_, rep) = quantize_model(&model, &calib, &HbVla::new(), &[Component::Language], 2);
        assert!(rep.packed_layers > 0);
        // Residual-bitplane packing adds a bounded overhead on top of the
        // method's own reconstruction error; the deployed weights must
        // stay far below the plain 1-bit Gaussian floor (≈0.36) or the
        // method advantage would be lost in serving.
        assert!(rep.mean_deploy_rel_err > 0.0, "{rep:?}");
        assert!(
            rep.mean_deploy_rel_err < 0.25,
            "deploy packing destroyed the reconstruction: {} (method {})",
            rep.mean_deploy_rel_err,
            rep.mean_rel_err
        );
    }

    #[test]
    fn exact_commit_registers_transform_layers() {
        let model = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
        let registry = ModelRegistry::new();
        let calib = HashMap::new();
        let comps = [Component::Language];
        let rep = quantize_exact_into_registry(
            &registry,
            "hbvla-exact",
            &model,
            &calib,
            &HbVla::new(),
            &comps,
            2,
        )
        .unwrap();
        assert!(rep.transform_layers > 0);
        assert_eq!(rep.transform_layers, rep.packed_layers);
        let served = registry.get("hbvla-exact").unwrap();
        assert_eq!(served.cfg.deploy_repr, crate::model::DeployRepr::TransformExact);
        assert_eq!(served.store.transform_packed_layer_count(), rep.transform_layers);
        // Exact serving is exact: deploy error equals the error of the
        // transform reconstruction itself, and it stays in the structured
        // regime (below the 1-bit Gaussian floor).
        assert!(rep.mean_deploy_rel_err < 0.25, "{rep:?}");
        // The exact commit drops the residual-plane memory the repacked
        // commit pays for the same method.
        let (repacked, _) = quantize_model(&model, &calib, &HbVla::new(), &comps, 2);
        assert!(
            served.store.resident_weight_bytes() < repacked.store.resident_weight_bytes(),
            "exact {} !< repacked {}",
            served.store.resident_weight_bytes(),
            repacked.store.resident_weight_bytes()
        );
    }

    #[test]
    fn exact_commit_from_direct_domain_method_is_typed_error() {
        // RTN commits a packed form but no transform-domain form:
        // requesting exact serving must surface UnsupportedRepr — not
        // silently register the approximate repack.
        let model = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
        let registry = ModelRegistry::new();
        let calib = HashMap::new();
        let err = quantize_exact_into_registry(
            &registry,
            "rtn-exact",
            &model,
            &calib,
            &Rtn::new(),
            &[Component::Language],
            2,
        )
        .unwrap_err();
        match err {
            RegistryError::UnsupportedRepr { variant, wanted, .. } => {
                assert_eq!(variant, "rtn-exact");
                assert_eq!(wanted, "transform-exact");
            }
            other => panic!("expected UnsupportedRepr, got {other:?}"),
        }
        assert!(registry.get("rtn-exact").is_none(), "failed flow must not register");
    }

    #[test]
    fn a8_twin_registers_with_int8_precision() {
        let model = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
        let registry = ModelRegistry::new();
        let calib = HashMap::new();
        quantize_into_registry(
            &registry,
            "rtn-packed",
            &model,
            &calib,
            &Rtn::new(),
            &[Component::Vision, Component::Language],
            2,
        )
        .unwrap();
        let name = register_a8_variant(&registry, "rtn-packed").unwrap();
        assert_eq!(name, "rtn-packed-a8");
        let twin = registry.get("rtn-packed-a8").unwrap();
        assert_eq!(twin.store.act_precision(), ActPrecision::Int8);
        assert_eq!(twin.cfg.act_precision, ActPrecision::Int8);
        // The base variant keeps its f32 activations.
        let base = registry.get("rtn-packed").unwrap();
        assert_eq!(base.store.act_precision(), ActPrecision::F32);
        // Unknown base is a typed error, not a panic.
        let err = register_a8_variant(&registry, "missing").unwrap_err();
        assert_eq!(err, RegistryError::UnknownVariant { variant: "missing".to_string() });
    }

    #[test]
    fn static_scale_twin_registers_calibrated_and_serves_same_interface() {
        let model = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
        let registry = ModelRegistry::new();
        let calib = HashMap::new();
        quantize_into_registry(
            &registry,
            "rtn-packed",
            &model,
            &calib,
            &Rtn::new(),
            &[Component::Vision, Component::Language],
            2,
        )
        .unwrap();
        let tasks = crate::sim::tasks::libero_suite("object");
        let demos = crate::calib::demos::collect_demos(&model, &tasks, 1, 5);
        let (name, layers) =
            register_static_scale_variant(&registry, "rtn-packed", &demos, 4).unwrap();
        assert_eq!(name, "rtn-packed-static");
        assert!(layers > 0, "no layers calibrated");
        let twin = registry.get(&name).unwrap();
        assert_eq!(twin.store.act_precision(), ActPrecision::Int8);
        assert_eq!(twin.store.act_scale_mode(), crate::model::ActScaleMode::Static);
        assert_eq!(twin.store.static_scale_count(), layers);
        // The base keeps per-token scales and F32 activations.
        let base = registry.get("rtn-packed").unwrap();
        assert_eq!(base.store.act_scale_mode(), crate::model::ActScaleMode::PerToken);
        assert_eq!(base.store.static_scale_count(), 0);
        // Unknown base is a typed error.
        let err = register_static_scale_variant(&registry, "missing", &demos, 4).unwrap_err();
        assert_eq!(err, RegistryError::UnknownVariant { variant: "missing".to_string() });
    }

    #[test]
    fn percentile_clip_twin_registers_under_suffixed_name() {
        let model = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
        let registry = ModelRegistry::new();
        let calib = HashMap::new();
        quantize_into_registry(
            &registry,
            "rtn-packed",
            &model,
            &calib,
            &Rtn::new(),
            &[Component::Vision, Component::Language],
            2,
        )
        .unwrap();
        let tasks = crate::sim::tasks::libero_suite("object");
        let demos = crate::calib::demos::collect_demos(&model, &tasks, 1, 5);
        let (name, layers) = register_static_scale_variant_clip(
            &registry,
            "rtn-packed",
            &demos,
            4,
            crate::calib::ScaleClip::Percentile,
        )
        .unwrap();
        assert_eq!(name, "rtn-packed-static-p999");
        assert!(layers > 0);
        let twin = registry.get(&name).unwrap();
        assert_eq!(twin.store.act_scale_mode(), crate::model::ActScaleMode::Static);
        assert_eq!(twin.store.static_scale_count(), layers);
        // It coexists with the max-clip twin under the historical name.
        let (mname, _) = register_static_scale_variant(&registry, "rtn-packed", &demos, 4).unwrap();
        assert_eq!(mname, "rtn-packed-static");
        assert!(registry.get("rtn-packed-static").is_some());
    }

    #[test]
    fn report_has_bits_and_errors() {
        let model = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
        let calib = HashMap::new();
        let comps = [Component::Language];
        let (_, r) = quantize_model(&model, &calib, &Rtn::new(), &comps, 2);
        assert!(r.bits_per_weight() > 1.0);
        assert!(r.mean_rel_err > 0.0);
        assert!(!r.layers.is_empty());
    }
}
