//! Parallel closed-loop evaluation: episodes are distributed across a
//! thread pool; results aggregate per task and per suite.

use std::collections::BTreeMap;

use crate::model::MiniVla;
use crate::sim::episode::run_policy_episode;
use crate::sim::observe::ObsParams;
use crate::sim::tasks::Task;
use crate::util::rng::Rng;
use crate::util::threadpool::parallel_map;

/// Which observation model episodes sample (SimplerEnv settings).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ObsMode {
    VisualMatching,
    VariantAggregation,
}

#[derive(Clone, Debug)]
pub struct RolloutConfig {
    pub episodes_per_task: usize,
    pub mode: ObsMode,
    pub seed: u64,
    pub threads: usize,
}

impl Default for RolloutConfig {
    fn default() -> Self {
        RolloutConfig {
            episodes_per_task: 50,
            mode: ObsMode::VisualMatching,
            seed: 2026,
            threads: crate::util::threadpool::default_threads(),
        }
    }
}

/// Per-task and aggregate success rates.
#[derive(Clone, Debug)]
pub struct SuiteResult {
    pub per_task: BTreeMap<String, f64>,
    pub successes: usize,
    pub episodes: usize,
}

impl SuiteResult {
    pub fn success_rate(&self) -> f64 {
        if self.episodes == 0 {
            0.0
        } else {
            self.successes as f64 / self.episodes as f64
        }
    }
}

/// Evaluate `model` over `tasks`, `episodes_per_task` each, in parallel.
/// Episode seeds are deterministic functions of (cfg.seed, task, episode),
/// so different methods are compared on identical episode draws.
pub fn eval_tasks(model: &MiniVla, tasks: &[Task], cfg: &RolloutConfig) -> SuiteResult {
    let jobs: Vec<(usize, usize)> = (0..tasks.len())
        .flat_map(|t| (0..cfg.episodes_per_task).map(move |e| (t, e)))
        .collect();
    let outcomes = parallel_map(jobs.len(), cfg.threads, |j| {
        let (t, e) = jobs[j];
        let task = &tasks[t];
        let ep_seed = cfg
            .seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add((t as u64) << 32)
            .wrapping_add(e as u64);
        let params = match cfg.mode {
            ObsMode::VisualMatching => ObsParams::visual_matching(),
            ObsMode::VariantAggregation => {
                let mut r = Rng::with_stream(ep_seed, 0x5A);
                ObsParams::variant_aggregation(&mut r)
            }
        };
        (t, run_policy_episode(model, task, &params, ep_seed).success)
    });
    let mut per_task_succ: BTreeMap<String, (usize, usize)> = BTreeMap::new();
    let mut successes = 0;
    for (t, ok) in &outcomes {
        let e = per_task_succ.entry(tasks[*t].name.clone()).or_insert((0, 0));
        e.1 += 1;
        if *ok {
            e.0 += 1;
            successes += 1;
        }
    }
    SuiteResult {
        per_task: per_task_succ
            .into_iter()
            .map(|(k, (s, n))| (k, s as f64 / n as f64))
            .collect(),
        successes,
        episodes: outcomes.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{HeadKind, VlaConfig};
    use crate::sim::tasks::libero_suite;

    #[test]
    fn deterministic_across_thread_counts() {
        let model = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
        let tasks = libero_suite("object");
        let mk = |threads| RolloutConfig { episodes_per_task: 2, threads, ..Default::default() };
        let a = eval_tasks(&model, &tasks, &mk(1));
        let b = eval_tasks(&model, &tasks, &mk(4));
        assert_eq!(a.successes, b.successes);
        assert_eq!(a.per_task, b.per_task);
    }

    #[test]
    fn counts_episodes() {
        let model = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
        let tasks = libero_suite("object");
        let cfg = RolloutConfig { episodes_per_task: 3, threads: 2, ..Default::default() };
        let r = eval_tasks(&model, &tasks, &cfg);
        assert_eq!(r.episodes, 3 * tasks.len());
        assert_eq!(r.per_task.len(), tasks.len());
    }
}
