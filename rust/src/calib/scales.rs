//! Static activation-scale calibration (the QuantVLA-style follow-on to
//! per-token W1A8 scales).
//!
//! The per-token path sweeps max|x| on every token of every layer at
//! serve time. This pass streams the calibration demos through the model
//! ONCE, records the maximum absolute activation each quantized layer
//! ever sees, and pins s_layer = max|·|/127 on the
//! [`crate::model::params::ParamStore`] (serialized with the checkpoint,
//! format v4). Under [`crate::quant::packed::ActScaleMode::Static`] the
//! kernels then skip the max sweep and run the single fused
//! quantize+group-sum+bit-slice pass; out-of-range activations at serve
//! time saturate at ±127.
//!
//! Domain correctness: the scale must cover the values the kernel
//! actually quantizes. For [`crate::model::params::WeightRepr::Packed`]
//! layers that is the layer input x; for
//! [`crate::model::params::WeightRepr::TransformPacked`] layers
//! (`hbvla-exact`) it is the TRANSFORMED z = B·Pᵀx, so the pass runs the
//! same fused gather+Haar sweep the serving path uses and records max|z|.
//! Dense (FP) layers never quantize activations and are skipped.

use std::collections::HashMap;

use crate::model::params::WeightRepr;

/// Seed stream for calibration-demo collection — ONE constant so
/// `serve --act-scale static` and the perf baseline's act-scale rows
/// calibrate on the same stream for a given `--seed`.
pub const CALIB_SEED_STREAM: u64 = 0x5CA1E;

/// The canonical calibration budget shared by the serve flow and the
/// bench baseline: (TOTAL demo trajectories — `collect_demos` cycles
/// them across the task suite — and capture steps). Non-smoke collects
/// enough trajectories to cover every task of the standard suites, so a
/// layer whose activation range peaks on a later task still calibrates
/// a covering scale. Keeping the recipe in one place means the archived
/// `BENCH_*.json` act-scale rows always describe the same calibration
/// serving actually uses.
pub fn calib_recipe(smoke: bool) -> (usize, usize) {
    if smoke {
        (1, 6)
    } else {
        (6, 48)
    }
}
use crate::model::{ActScaleMode, MiniVla};
use crate::sim::episode::DemoStep;
use crate::tensor::matrix::Matrix;

/// How the calibrated static scale clips the observed activation range.
/// `Max` (the QuantVLA-style default) covers the single largest |·| the
/// stream ever produced — robust, but one outlier token inflates the
/// scale (and thus the round-off) for every other token of the layer.
/// `Percentile` pins s = p99.9(|·|)/127 instead: the 0.1% outlier tail
/// saturates at ±127 while the bulk quantizes on a tighter grid. The
/// perf baseline's act-scale table sweeps both so the tokens/s ↔
/// action-MSE trade is recorded, and `serve --act-clip` picks at run
/// time.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ScaleClip {
    /// s = max|·|/127 — no calibration-set saturation.
    #[default]
    Max,
    /// s = p99.9(|·|)/127 — clip the outlier tail, tighten the grid.
    Percentile,
}

impl ScaleClip {
    pub fn label(&self) -> &'static str {
        match self {
            ScaleClip::Max => "max",
            ScaleClip::Percentile => "p999",
        }
    }

    /// Parse a CLI spelling (`serve --act-clip ...`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "max" => Some(ScaleClip::Max),
            "p999" | "percentile" | "p99.9" => Some(ScaleClip::Percentile),
            _ => None,
        }
    }
}

/// Per-layer calibration accumulator: the running max is always kept
/// (percentile clipping falls back to it when the tail is degenerate);
/// the raw |·| samples are only collected under [`ScaleClip::Percentile`]
/// so the default path stays allocation-light and bit-identical to the
/// historical max-only sweep.
#[derive(Default)]
struct CalibAcc {
    maxabs: f32,
    samples: Vec<f32>,
}

/// Track one token against a layer's quantization domain: plain |x| for
/// direct packed layers, |z| through the fused transform sweep for
/// transform-exact layers, nothing for dense (FP) layers.
fn track_token(
    accs: &mut HashMap<String, CalibAcc>,
    store: &crate::model::ParamStore,
    name: &str,
    token: &[f32],
    clip: ScaleClip,
) {
    match store.repr(name) {
        WeightRepr::Packed(_) => {
            let acc = accs.entry(name.to_string()).or_default();
            for v in token {
                acc.maxabs = acc.maxabs.max(v.abs());
            }
            if clip == ScaleClip::Percentile {
                acc.samples.extend(token.iter().map(|v| v.abs()));
            }
        }
        WeightRepr::TransformPacked(t) => {
            let (z, mx) = t.transform_act_with_max(token);
            let acc = accs.entry(name.to_string()).or_default();
            acc.maxabs = acc.maxabs.max(mx);
            if clip == ScaleClip::Percentile {
                acc.samples.extend(z.iter().map(|v| v.abs()));
            }
        }
        WeightRepr::Dense(_) => {}
    }
}

/// Nearest-rank 99.9th percentile of the collected |·| samples; falls
/// back to the running max when the percentile is degenerate (≤ 0, e.g.
/// a mostly-zero layer where the tail IS the signal).
fn clip_point(acc: &CalibAcc, clip: ScaleClip) -> f32 {
    match clip {
        ScaleClip::Max => acc.maxabs,
        ScaleClip::Percentile => {
            let n = acc.samples.len();
            if n == 0 {
                return acc.maxabs;
            }
            let mut s = acc.samples.clone();
            s.sort_unstable_by(f32::total_cmp);
            let idx = ((n as f64 * 0.999).ceil() as usize).saturating_sub(1).min(n - 1);
            let p = s[idx];
            if p > 0.0 {
                p
            } else {
                acc.maxabs
            }
        }
    }
}

/// Sweep the calibration stream (up to `max_steps` demo steps) and
/// return per-layer static scales s = max|·|/127 for every layer whose
/// representation quantizes activations. The trunk layers are captured
/// through the forward hook; the action-head layers sit behind
/// `decode()` (no hook), so the deterministic ones are covered directly
/// — `head.expand` sees the trunk features, `head.main` sees the
/// expanded+standardized head features. The diffusion head's per-step
/// inputs depend on the sampling noise, so `head.diff.*` layers keep
/// the per-token fallback (Static mode falls back per layer). Layers
/// that only ever saw zero activations are likewise omitted (a zero
/// scale would zero the layer output).
pub fn calibrate_act_scales(
    model: &MiniVla,
    demos: &[Vec<DemoStep>],
    max_steps: usize,
) -> HashMap<String, f32> {
    calibrate_act_scales_clip(model, demos, max_steps, ScaleClip::Max)
}

/// [`calibrate_act_scales`] with an explicit clip policy: `Max` is the
/// historical (bit-identical) max-covering sweep; `Percentile` collects
/// the full |·| sample stream per layer and pins the 99.9th-percentile
/// clip point instead (outlier tokens saturate at serve time).
pub fn calibrate_act_scales_clip(
    model: &MiniVla,
    demos: &[Vec<DemoStep>],
    max_steps: usize,
    clip: ScaleClip,
) -> HashMap<String, f32> {
    let mut accs: HashMap<String, CalibAcc> = HashMap::new();
    // Spread the step budget across the collected trajectories instead
    // of letting the first (task-0) demo exhaust it: every task the
    // stream covers must contribute, or a layer whose activation range
    // peaks on a later task calibrates a too-small scale.
    let per_demo = max_steps.div_ceil(demos.len().max(1));
    let mut steps = 0usize;
    'outer: for demo in demos {
        for step in demo.iter().take(per_demo) {
            if steps >= max_steps {
                break 'outer;
            }
            let feat = {
                // One domain rule (track_token) for every layer the
                // hook sees; the Dense early-out skips the per-token
                // column copies for FP layers.
                let mut hook_fn = |name: &str, x: &Matrix| {
                    if matches!(model.store.repr(name), WeightRepr::Dense(_)) {
                        return;
                    }
                    for tok in 0..x.cols {
                        track_token(&mut accs, &model.store, name, &x.col(tok), clip);
                    }
                };
                let mut hook: Option<crate::model::layers::Hook> = Some(&mut hook_fn);
                model.features(
                    &step.obs.visual_raw,
                    step.obs.instr_id,
                    &step.obs.proprio,
                    &mut hook,
                )
            };
            // Deterministic head layers (see doc above).
            if model.store.contains("head.expand") {
                track_token(&mut accs, &model.store, "head.expand", &feat, clip);
                if model.store.contains("head.main") {
                    let hf = model.head_features(&feat);
                    track_token(&mut accs, &model.store, "head.main", &hf, clip);
                }
            }
            steps += 1;
        }
    }
    accs.into_iter()
        .filter(|(_, a)| a.maxabs > 0.0 && a.maxabs.is_finite())
        .map(|(name, a)| {
            let m = clip_point(&a, clip);
            (name, m / 127.0)
        })
        .collect()
}

/// Write calibrated scales into the model's store. Returns how many
/// layers were pinned. Does NOT flip the mode — callers decide when the
/// static path goes live ([`calibrate_static_scales`] does both).
pub fn apply_act_scales(model: &mut MiniVla, scales: &HashMap<String, f32>) -> usize {
    let mut n = 0;
    for (name, &s) in scales {
        if s > 0.0 && s.is_finite() && model.store.contains(name) {
            model.store.set_static_act_scale(name, s);
            n += 1;
        }
    }
    n
}

/// The one-call flow: calibrate over `demos`, pin the scales, and switch
/// the model to [`ActScaleMode::Static`]. Returns the number of
/// calibrated layers.
pub fn calibrate_static_scales(
    model: &mut MiniVla,
    demos: &[Vec<DemoStep>],
    max_steps: usize,
) -> usize {
    calibrate_static_scales_clip(model, demos, max_steps, ScaleClip::Max)
}

/// [`calibrate_static_scales`] with an explicit [`ScaleClip`] policy.
pub fn calibrate_static_scales_clip(
    model: &mut MiniVla,
    demos: &[Vec<DemoStep>],
    max_steps: usize,
    clip: ScaleClip,
) -> usize {
    let scales = calibrate_act_scales_clip(model, demos, max_steps, clip);
    let n = apply_act_scales(model, &scales);
    model.cfg.act_scale_mode = ActScaleMode::Static;
    model.store.set_act_scale_mode(ActScaleMode::Static);
    n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::demos::collect_demos;
    use crate::model::{ActPrecision, HeadKind, VlaConfig};
    use crate::sim::tasks::libero_suite;

    fn packed_model_with_demos() -> (MiniVla, Vec<Vec<DemoStep>>) {
        let fp = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
        let tasks = libero_suite("object");
        let demos = collect_demos(&fp, &tasks, 2, 11);
        let mut m = fp;
        m.store.pack_quantizable(32);
        (m, demos)
    }

    #[test]
    fn calibration_covers_packed_layers_with_positive_scales() {
        let (model, demos) = packed_model_with_demos();
        let scales = calibrate_act_scales(&model, &demos, 8);
        // Every packed layer the hook sees (vis/lm blocks + proj) gets a
        // positive finite scale.
        assert!(!scales.is_empty());
        for (name, s) in &scales {
            assert!(*s > 0.0 && s.is_finite(), "{name}: {s}");
            assert!(model.store.is_packed(name), "{name} not packed");
        }
        for prefix in ["vis.0.wq", "lm.0.wq", "proj"] {
            assert!(scales.contains_key(prefix), "missing {prefix}");
        }
        // The deterministic action-head layers sit behind decode() (no
        // hook) and must still be covered.
        assert!(scales.contains_key("head.expand"), "missing head.expand");
        assert!(scales.contains_key("head.main"), "missing head.main");
    }

    #[test]
    fn static_mode_forward_finite_and_close_to_per_token() {
        let (model, demos) = packed_model_with_demos();
        let mut stat = model.clone().with_act_precision(ActPrecision::Int8);
        let n = calibrate_static_scales(&mut stat, &demos, 8);
        assert!(n > 0);
        assert_eq!(stat.store.act_scale_mode(), ActScaleMode::Static);
        assert_eq!(stat.cfg.act_scale_mode, ActScaleMode::Static);
        assert_eq!(stat.store.static_scale_count(), n);
        let dyn_m = model.with_act_precision(ActPrecision::Int8);
        // On a calibration observation the static forward must stay close
        // to the per-token forward: scales were pinned at the stream max,
        // so a calibration-set input quantizes with AT MOST the same
        // round-off granularity class (no saturation on these inputs).
        let obs = &demos[0][0].obs;
        let f_dyn = dyn_m.features(&obs.visual_raw, obs.instr_id, &obs.proprio, &mut None);
        let f_stat = stat.features(&obs.visual_raw, obs.instr_id, &obs.proprio, &mut None);
        assert!(f_stat.iter().all(|v| v.is_finite()));
        let num: f32 = f_dyn.iter().zip(&f_stat).map(|(a, b)| (a - b) * (a - b)).sum();
        let den: f32 = f_dyn.iter().map(|v| v * v).sum::<f32>().max(1e-6);
        assert!(
            num / den < 0.05,
            "static-scale forward drifted: rel err {}",
            num / den
        );
    }

    #[test]
    fn clip_labels_and_parse_round_trip() {
        assert_eq!(ScaleClip::default(), ScaleClip::Max);
        for c in [ScaleClip::Max, ScaleClip::Percentile] {
            assert_eq!(ScaleClip::parse(c.label()), Some(c));
        }
        assert_eq!(ScaleClip::parse("percentile"), Some(ScaleClip::Percentile));
        assert_eq!(ScaleClip::parse("p99.9"), Some(ScaleClip::Percentile));
        assert_eq!(ScaleClip::parse("bogus"), None);
    }

    #[test]
    fn percentile_clip_tightens_without_degenerating() {
        let (model, demos) = packed_model_with_demos();
        let smax = calibrate_act_scales_clip(&model, &demos, 8, ScaleClip::Max);
        let sp = calibrate_act_scales_clip(&model, &demos, 8, ScaleClip::Percentile);
        // Same layer coverage, and the Max path is bit-identical to the
        // historical API.
        let legacy = calibrate_act_scales(&model, &demos, 8);
        assert_eq!(smax, legacy);
        assert_eq!(smax.len(), sp.len());
        for (name, &m) in &smax {
            let p = sp[name];
            assert!(p > 0.0 && p.is_finite(), "{name}: p999 scale {p}");
            // Nearest-rank p99.9 can never exceed the max.
            assert!(p <= m * 1.0001, "{name}: p999 {p} above max {m}");
        }
        // A static model calibrated under the percentile clip still
        // serves finite features on the calibration stream.
        let mut stat = model.clone().with_act_precision(ActPrecision::Int8);
        let n = calibrate_static_scales_clip(&mut stat, &demos, 8, ScaleClip::Percentile);
        assert!(n > 0);
        let obs = &demos[0][0].obs;
        let f = stat.features(&obs.visual_raw, obs.instr_id, &obs.proprio, &mut None);
        assert!(f.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn transform_layers_calibrate_in_z_domain() {
        // A model with transform-packed language layers: the calibrated
        // scale must cover max|z| (which a direct max|x| sweep would
        // underestimate whenever the pairwise sums a+b exceed max|x|).
        let fp = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
        let tasks = libero_suite("object");
        let demos = collect_demos(&fp, &tasks, 2, 13);
        let calib = std::collections::HashMap::new();
        let (model, _) = crate::coordinator::scheduler::quantize_model_exact(
            &fp,
            &calib,
            &crate::methods::HbVla::new(),
            &[crate::methods::Component::Language],
            2,
            "test-exact",
        )
        .unwrap();
        let scales = calibrate_act_scales(&model, &demos, 6);
        let mut checked = 0;
        for (name, s) in &scales {
            if let WeightRepr::TransformPacked(t) = model.store.repr(name) {
                // Re-measure max|z| on one step; it must be ≤ 127·s.
                let obs = &demos[0][0].obs;
                let mut zmax = 0.0f32;
                let mut hook_fn = |n2: &str, x: &Matrix| {
                    if n2 == name.as_str() {
                        for tok in 0..x.cols {
                            let (_, mx) = t.transform_act_with_max(&x.col(tok));
                            zmax = zmax.max(mx);
                        }
                    }
                };
                let mut hook: Option<crate::model::layers::Hook> = Some(&mut hook_fn);
                let _ = model.features(&obs.visual_raw, obs.instr_id, &obs.proprio, &mut hook);
                assert!(zmax <= s * 127.0 * 1.0001, "{name}: z {zmax} vs scale {s}");
                checked += 1;
            }
        }
        assert!(checked > 0, "no transform layers calibrated");
    }
}
