//! Demonstration collection: expert rollouts across a task list, used both
//! as the behavioural-cloning corpus and as the calibration set (the paper
//! samples 256 trajectories from the benchmark's training distribution).

use crate::model::MiniVla;
use crate::sim::episode::DemoStep;
use crate::sim::observe::ObsParams;
use crate::sim::tasks::Task;
use crate::util::rng::Rng;

/// DART noise level used for the BC corpus (executed = expert + noise,
/// label = expert) — covers the drift states the cloned policy visits.
pub const DEMO_NOISE: f64 = 0.2;

/// Collect `n_traj` expert trajectories, cycling through `tasks`. Only
/// successful expert episodes are kept (the expert solves every task even
/// under injection noise; the filter guards demo quality).
pub fn collect_demos(
    model: &MiniVla,
    tasks: &[Task],
    n_traj: usize,
    seed: u64,
) -> Vec<Vec<DemoStep>> {
    collect_demos_noisy(model, tasks, n_traj, seed, DEMO_NOISE)
}

pub fn collect_demos_noisy(
    model: &MiniVla,
    tasks: &[Task],
    n_traj: usize,
    seed: u64,
    noise: f64,
) -> Vec<Vec<DemoStep>> {
    let mut rng = Rng::with_stream(seed, 0xDE30);
    let mut demos = Vec::with_capacity(n_traj);
    let mut attempt = 0u64;
    while demos.len() < n_traj {
        let task = &tasks[(attempt as usize) % tasks.len()];
        let ep_seed = rng.next_u64() ^ attempt;
        let (res, steps) =
            crate::sim::episode::run_expert_episode_noisy(model, task, &ObsParams::clean(), ep_seed, noise);
        attempt += 1;
        if res.success && !steps.is_empty() {
            demos.push(steps);
        }
        assert!(
            attempt < 8 * n_traj as u64 + 64,
            "expert failing too often — task suite broken"
        );
    }
    demos
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{HeadKind, VlaConfig};
    use crate::sim::tasks::libero_suite;

    #[test]
    fn collects_requested_count() {
        let model = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
        let tasks = libero_suite("object");
        let demos = collect_demos(&model, &tasks, 6, 7);
        assert_eq!(demos.len(), 6);
        for d in &demos {
            assert!(!d.is_empty());
        }
    }

    #[test]
    fn demos_deterministic() {
        let model = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
        let tasks = libero_suite("object");
        let a = collect_demos(&model, &tasks, 3, 9);
        let b = collect_demos(&model, &tasks, 3, 9);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.len(), y.len());
            assert_eq!(x[0].action, y[0].action);
        }
    }
}
