//! Calibration pipeline: demonstration collection, activation capture,
//! and construction of per-layer [`CalibData`] (standard + policy-aware
//! rectified Hessians).

pub mod capture;
pub mod demos;

pub use capture::{capture_calibration, CaptureConfig};
pub use demos::collect_demos;
