//! Calibration pipeline: demonstration collection, activation capture,
//! and construction of per-layer [`CalibData`] (standard + policy-aware
//! rectified Hessians).

pub mod capture;
pub mod demos;
pub mod scales;

pub use capture::{capture_calibration, CaptureConfig};
pub use demos::collect_demos;
pub use scales::{
    apply_act_scales, calibrate_act_scales, calibrate_act_scales_clip, calibrate_static_scales,
    calibrate_static_scales_clip, ScaleClip,
};
