//! Activation capture → per-layer calibration Hessians.
//!
//! Pass A: stream every demo step through the FP model with a hook that
//! accumulates the standard Hessian H = XXᵀ per quantizable layer, while
//! caching layer inputs at a step subsample for the probe pass.
//!
//! Pass B (policy-aware): for each LM block, run the block-wise gradient
//! probe (FP vs provisionally binarized block) on the cached block inputs
//! to get per-token importance (Eqs. 4–9), then accumulate the rectified
//! Hessian H̃ = XSXᵀ over the cached inputs. Vision-side layers use the
//! visual-token slice of block 0's mean importance (the probe is defined
//! on the action pathway; this extension is documented in DESIGN.md).

use std::collections::HashMap;

use crate::methods::traits::CalibData;
use crate::model::MiniVla;
use crate::quant::group::{quantize_matrix, GroupSpec};
use crate::quant::hessian::HessianAccum;
use crate::quant::probe::{probe_token_importance_focused, AttnBlock, TokenImportance};
use crate::sim::episode::DemoStep;
use crate::tensor::matrix::Matrix;

#[derive(Clone, Debug)]
pub struct CaptureConfig {
    /// Cache layer inputs every `subsample`-th step for the probe pass.
    pub subsample: usize,
    /// Maximum cached steps (bounds memory).
    pub max_cached: usize,
    /// Compute the policy-aware rectified Hessians.
    pub policy_aware: bool,
    /// Rectification strength β: S = (1−β)·1 + β·S_probe. Full β=1 lets a
    /// single dominant token crush every other column's statistics; the
    /// default softening keeps the instruction-conditioned boost while
    /// preserving usable energy estimates for the rest of the layer.
    pub beta: f32,
}

impl Default for CaptureConfig {
    fn default() -> Self {
        CaptureConfig { subsample: 4, max_cached: 192, policy_aware: true, beta: 0.5 }
    }
}

/// Provisional binarization of an attention block for the probe (RTN —
/// the probe only needs a representative quantization noise pattern).
fn provisional_block(model: &MiniVla, prefix: &str) -> (AttnBlock, AttnBlock) {
    let spec = GroupSpec { group_size: 128, shared_mean: false, adaptive_split: false };
    let get = |w: &str| model.store.get(&format!("{prefix}.{w}")).clone();
    let fp = AttnBlock { wq: get("wq"), wk: get("wk"), wv: get("wv"), wo: get("wo"), heads: model.cfg.heads };
    let q = AttnBlock {
        wq: quantize_matrix(&fp.wq, &spec).0,
        wk: quantize_matrix(&fp.wk, &spec).0,
        wv: quantize_matrix(&fp.wv, &spec).0,
        wo: quantize_matrix(&fp.wo, &spec).0,
        heads: fp.heads,
    };
    (fp, q)
}

/// Run capture over a demonstration corpus. Returns per-layer
/// [`CalibData`] keyed by parameter name, with rectified Hessians attached
/// when `cfg.policy_aware`.
pub fn capture_calibration(
    model: &MiniVla,
    demos: &[Vec<DemoStep>],
    cfg: &CaptureConfig,
) -> HashMap<String, CalibData> {
    let layer_names = model.store.quantizable_layers(None);
    let mut std_acc: HashMap<String, HessianAccum> = HashMap::new();
    let mut cached: HashMap<String, Vec<Matrix>> = HashMap::new();
    for name in &layer_names {
        let dim = model.store.get(name).cols;
        std_acc.insert(name.clone(), HessianAccum::new(dim));
    }

    // ---- Pass A: standard Hessians + input cache ----
    let mut step_idx = 0usize;
    let mut n_cached = 0usize;
    for demo in demos {
        for step in demo {
            let cache_this = step_idx % cfg.subsample == 0 && n_cached < cfg.max_cached;
            {
                let mut hook_fn = |name: &str, x: &Matrix| {
                    if let Some(acc) = std_acc.get_mut(name) {
                        acc.add(x);
                        if cache_this {
                            cached.entry(name.to_string()).or_default().push(x.clone());
                        }
                    }
                };
                let mut hook: Option<crate::model::layers::Hook> = Some(&mut hook_fn);
                let _ = model.features(&step.obs.visual_raw, step.obs.instr_id, &step.obs.proprio, &mut hook);
            }
            if cache_this {
                n_cached += 1;
            }
            step_idx += 1;
        }
    }

    // ---- Pass B: probe → rectified Hessians ----
    let mut rect_acc: HashMap<String, HessianAccum> = HashMap::new();
    if cfg.policy_aware {
        // Per-LM-block token importance, averaged over cached inputs.
        let mut block_importance: Vec<TokenImportance> = Vec::new();
        for b in 0..model.cfg.lm_blocks {
            let prefix = format!("lm.{b}");
            let (fp, q) = provisional_block(model, &prefix);
            let inputs = cached.get(&format!("{prefix}.wq")).cloned().unwrap_or_default();
            let n = model.cfg.seq_len();
            let mut avg = TokenImportance {
                q: vec![0.0; n],
                k: vec![0.0; n],
                v: vec![0.0; n],
                o: vec![0.0; n],
                mean: vec![0.0; n],
            };
            let m = inputs.len().max(1) as f32;
            for x in &inputs {
                // Focus the block loss on the readout (instruction) token —
                // the action pathway (see probe docs re dual dominance).
                let imp = probe_token_importance_focused(&fp, &q, x, Some(model.cfg.n_visual));
                for t in 0..n {
                    avg.q[t] += imp.q[t] / m;
                    avg.k[t] += imp.k[t] / m;
                    avg.v[t] += imp.v[t] / m;
                    avg.o[t] += imp.o[t] / m;
                    avg.mean[t] += imp.mean[t] / m;
                }
            }
            if inputs.is_empty() {
                for t in 0..n {
                    avg.mean[t] = 1.0;
                    avg.q[t] = 1.0;
                    avg.k[t] = 1.0;
                    avg.v[t] = 1.0;
                    avg.o[t] = 1.0;
                }
            }
            block_importance.push(avg);
        }

        // Importance vector applicable to a given layer's token axis.
        let importance_for = |name: &str, tokens: usize| -> Vec<f32> {
            if let Some(rest) = name.strip_prefix("lm.") {
                let mut it = rest.splitn(2, '.');
                let b: usize = it.next().unwrap().parse().unwrap();
                let proj = it.next().unwrap_or("");
                let imp = &block_importance[b];
                let v = match proj {
                    "wq" => &imp.q,
                    "wk" => &imp.k,
                    "wv" => &imp.v,
                    "wo" => &imp.o,
                    _ => &imp.mean,
                };
                return v[..tokens.min(v.len())].to_vec();
            }
            // Vision / projector layers: visual-token slice of block 0's
            // mean importance (these positions map 1:1 to visual tokens).
            let imp = &block_importance[0].mean;
            if tokens <= model.cfg.n_visual {
                imp[..tokens].to_vec()
            } else {
                vec![1.0; tokens]
            }
        };

        for name in &layer_names {
            let dim = model.store.get(name).cols;
            let mut acc = HessianAccum::new(dim);
            if let Some(inputs) = cached.get(name) {
                for x in inputs {
                    let mut s = importance_for(name, x.cols);
                    for v in s.iter_mut() {
                        *v = (1.0 - cfg.beta) + cfg.beta * *v;
                    }
                    if s.len() == x.cols {
                        acc.add_weighted(x, &s);
                    } else {
                        acc.add(x);
                    }
                }
            }
            rect_acc.insert(name.clone(), acc);
        }
    }

    // ---- Assemble CalibData ----
    let mut out = HashMap::new();
    for name in &layer_names {
        let comp = model.store.component_of(name);
        let std_h = std_acc[name].finalize();
        let mut cd = CalibData::from_hessian(std_h, comp);
        if cfg.policy_aware {
            let r = &rect_acc[name];
            if r.tokens > 0 {
                cd = cd.with_rectified(r.finalize());
            }
        }
        out.insert(name.clone(), cd);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::demos::collect_demos;
    use crate::model::{HeadKind, MiniVla, VlaConfig};
    use crate::sim::tasks::libero_suite;

    fn quick_calib(policy_aware: bool) -> (MiniVla, HashMap<String, CalibData>) {
        let model = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
        let tasks = libero_suite("object");
        let demos = collect_demos(&model, &tasks, 2, 3);
        let cfg = CaptureConfig { subsample: 8, max_cached: 16, policy_aware, beta: 0.5 };
        let calib = capture_calibration(&model, &demos, &cfg);
        (model, calib)
    }

    #[test]
    fn covers_every_quantizable_layer() {
        let (model, calib) = quick_calib(false);
        for name in model.store.quantizable_layers(None) {
            let cd = calib.get(&name).expect("missing layer");
            assert_eq!(cd.hessian.rows, model.store.get(&name).cols, "{name}");
            assert!(cd.hessian.is_finite(), "{name}");
        }
    }

    #[test]
    fn rectified_present_when_policy_aware() {
        let (model, calib) = quick_calib(true);
        let mut with_rect = 0;
        for name in model.store.quantizable_layers(None) {
            if calib[&name].hessian_rect.is_some() {
                with_rect += 1;
            }
        }
        // All trunk layers that see tokens should have a rectified Hessian.
        assert!(with_rect > model.cfg.lm_blocks * 4, "only {with_rect} rectified");
    }

    #[test]
    fn hessians_are_psd_diagonal_nonneg() {
        let (_, calib) = quick_calib(true);
        for (name, cd) in &calib {
            for (i, &d) in cd.hessian.diag().iter().enumerate() {
                assert!(d >= -1e-4, "{name} diag[{i}]={d}");
            }
        }
    }

    #[test]
    fn rectified_differs_from_standard() {
        let (model, calib) = quick_calib(true);
        // On at least some LM layers the rectified Hessian must actually
        // rebalance token contributions.
        let mut any_diff = false;
        for name in model.store.quantizable_layers(None) {
            if let Some(hr) = &calib[&name].hessian_rect {
                if hr.dist_sq(&calib[&name].hessian) > 1e-8 {
                    any_diff = true;
                }
            }
        }
        assert!(any_diff);
    }
}
