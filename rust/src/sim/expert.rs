//! Scripted expert controller: the demonstration source for behavioural
//! cloning and the calibration-set generator (256 trajectories, matching
//! the paper's setup).

use crate::sim::scene::{dist, ObjKind, Scene};
use crate::sim::tasks::{Goal, Task};

/// Proportional servo gain (action units per world unit). Deliberately
/// low enough that the expert's actions are *linear* in the state over
/// most of the workspace (saturation only beyond ~0.4 world units) — a
/// behavioural-cloning-friendly expert, standard practice for BC corpora.
const KP: f32 = 2.4;
/// Grip-ramp sharpness (action units per world unit of distance).
const KG: f32 = 12.0;

/// Proportional steer toward a point, expressed in action units.
fn steer(from: [f32; 2], to: [f32; 2], _max_step: f32) -> [f32; 2] {
    [
        (KP * (to[0] - from[0])).clamp(-1.0, 1.0),
        (KP * (to[1] - from[1])).clamp(-1.0, 1.0),
    ]
}

/// Expert action for the current scene under `task`.
/// Returns `[dx, dy, grip]` in [−1, 1]³.
pub fn expert_action(scene: &Scene, task: &Task) -> [f32; 3] {
    let Some(si) = task.active_stage(scene) else {
        return [0.0, 0.0, -1.0]; // done: stay put, open gripper
    };
    let stage = &task.stages[si];
    let p = scene.params;
    let Some(tidx) = scene.find_idx(stage.target_id) else {
        return [0.0, 0.0, -1.0];
    };
    let target_pos = scene.objects[tidx].pos;
    let holding_target = scene.held == Some(tidx);
    let holding_other = scene.held.is_some() && !holding_target;

    if holding_other {
        // Drop whatever we're wrongly holding.
        return [0.0, 0.0, -1.0];
    }

    match stage.goal {
        Goal::DrawerOpen(_) | Goal::DrawerClosed => {
            debug_assert_eq!(scene.objects[tidx].kind, ObjKind::Drawer);
            if holding_target {
                let dir = if matches!(stage.goal, Goal::DrawerOpen(_)) { 0.8 } else { -0.8 };
                [dir, (KP * (target_pos[1] - scene.ee[1])).clamp(-1.0, 1.0), 1.0]
            } else {
                let d = dist(scene.ee, target_pos);
                let [dx, dy] = steer(scene.ee, target_pos, p.max_step);
                // Smooth grip ramp: closes exactly at the grasp threshold —
                // linear in the proximity-sensor feature.
                let grip = (KG * (p.grasp_radius * 0.7 - d)).clamp(-1.0, 1.0);
                [dx, dy, grip]
            }
        }
        Goal::Point(_) | Goal::Obj(_) => {
            if holding_target {
                let goal = stage.goal_point(scene);
                let d = dist(scene.ee, goal);
                let [dx, dy] = steer(scene.ee, goal, p.max_step);
                // Stay closed while far from the goal, open at the release
                // threshold — again a linear ramp in distance.
                let grip = (KG * (d - stage.radius * 0.55)).clamp(-1.0, 1.0);
                [dx, dy, grip]
            } else {
                let d = dist(scene.ee, target_pos);
                let [dx, dy] = steer(scene.ee, target_pos, p.max_step);
                let grip = (KG * (p.grasp_radius * 0.7 - d)).clamp(-1.0, 1.0);
                [dx, dy, grip]
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::tasks::{aloha_suite, libero_suite, simpler_suite};
    use crate::util::rng::Rng;

    /// The expert must solve every task in every suite from jittered
    /// starts — otherwise BC has no clean signal.
    #[test]
    fn expert_solves_all_suites() {
        let mut all: Vec<_> = Vec::new();
        for which in ["spatial", "object", "goal", "long"] {
            all.extend(libero_suite(which));
        }
        all.extend(simpler_suite());
        all.extend(aloha_suite());
        let mut rng = Rng::new(201);
        for task in &all {
            let mut ok = 0;
            let trials = 5;
            for _ in 0..trials {
                let mut scene = task.instantiate(&mut rng);
                for _ in 0..task.horizon {
                    if task.success(&scene) {
                        break;
                    }
                    let a = expert_action(&scene, task);
                    scene.step(&a);
                }
                if task.success(&scene) {
                    ok += 1;
                }
            }
            assert_eq!(ok, trials, "expert failed task {}", task.name);
        }
    }

    #[test]
    fn expert_idles_when_done() {
        let task = &libero_suite("object")[0];
        let mut scene = task.template.clone();
        let bucket = scene.find(crate::sim::scene::ids::BUCKET).unwrap().pos;
        let tid = scene.find_idx(task.stages[0].target_id).unwrap();
        scene.objects[tid].pos = bucket;
        let a = expert_action(&scene, task);
        assert_eq!(a, [0.0, 0.0, -1.0]);
    }

    #[test]
    fn expert_actions_bounded() {
        let mut rng = Rng::new(202);
        for task in simpler_suite() {
            let mut scene = task.instantiate(&mut rng);
            for _ in 0..30 {
                let a = expert_action(&scene, &task);
                assert!(a.iter().all(|v| (-1.0..=1.0).contains(v)), "{a:?}");
                scene.step(&a);
            }
        }
    }
}
