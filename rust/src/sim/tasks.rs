//! Task definitions and the three benchmark suites (LIBERO-like,
//! SimplerEnv-like, Mobile-ALOHA-like). Tasks are staged pick/place/slide
//! goals over the tabletop scene; multi-stage tasks receive the current
//! stage's instruction (the benchmark supplies sequenced sub-instructions,
//! as in Mobile-ALOHA's "Sequenced Instruction" suite).

use crate::model::instr_index;
use crate::sim::scene::{dist, ids, Object, Scene};
use crate::util::rng::Rng;

/// Where the stage's target object must end up.
#[derive(Clone, Copy, Debug)]
pub enum Goal {
    /// Within `radius` of a fixed point.
    Point([f32; 2]),
    /// Within `radius` of another object (by content id).
    Obj(usize),
    /// Drawer openness ≥ threshold.
    DrawerOpen(f32),
    /// Drawer openness ≤ 0.15.
    DrawerClosed,
}

#[derive(Clone, Debug)]
pub struct Stage {
    /// Content id of the object to manipulate.
    pub target_id: usize,
    pub goal: Goal,
    pub radius: f32,
}

impl Stage {
    /// Instruction id the policy receives while this stage is active.
    /// Open vs close drawer get distinct goal codes so the instruction
    /// disambiguates the direction ("open the drawer" / "close the
    /// drawer" are different sentences).
    pub fn instr(&self) -> usize {
        let goal_id = match self.goal {
            Goal::Point(_) => ids::MARKER,
            Goal::Obj(id) => id,
            Goal::DrawerOpen(_) => ids::DRAWER,
            Goal::DrawerClosed => ids::BUCKET,
        };
        instr_index(self.target_id, goal_id)
    }

    pub fn satisfied(&self, scene: &Scene) -> bool {
        let Some(idx) = scene.find_idx(self.target_id) else {
            return false;
        };
        let obj = &scene.objects[idx];
        let held = scene.held == Some(idx);
        match self.goal {
            Goal::Point(p) => !held && dist(obj.pos, p) <= self.radius,
            Goal::Obj(gid) => {
                let Some(g) = scene.find(gid) else { return false };
                !held && dist(obj.pos, g.pos) <= self.radius
            }
            Goal::DrawerOpen(th) => obj.openness() >= th,
            Goal::DrawerClosed => obj.openness() <= 0.15,
        }
    }

    /// World point the expert steers the held object toward.
    pub fn goal_point(&self, scene: &Scene) -> [f32; 2] {
        match self.goal {
            Goal::Point(p) => p,
            Goal::Obj(gid) => scene.find(gid).map(|o| o.pos).unwrap_or([0.5, 0.5]),
            Goal::DrawerOpen(_) | Goal::DrawerClosed => {
                scene.find(self.target_id).map(|o| o.pos).unwrap_or([0.5, 0.5])
            }
        }
    }
}

#[derive(Clone, Debug)]
pub struct Task {
    pub name: String,
    pub suite: String,
    pub stages: Vec<Stage>,
    pub horizon: usize,
    /// Initial scene template; per-episode jitter applied on instantiate.
    pub template: Scene,
    pub jitter: f32,
}

impl Task {
    /// Instantiate a per-episode scene: jitter object positions and the
    /// end-effector start, deterministically from `rng`.
    pub fn instantiate(&self, rng: &mut Rng) -> Scene {
        let mut s = self.template.clone();
        for o in &mut s.objects {
            if matches!(o.kind, crate::sim::scene::ObjKind::Drawer) {
                continue; // drawers stay anchored
            }
            o.pos[0] = (o.pos[0] + self.jitter * rng.gauss() as f32).clamp(0.05, 0.95);
            o.pos[1] = (o.pos[1] + self.jitter * rng.gauss() as f32).clamp(0.05, 0.95);
        }
        s.ee[0] = (s.ee[0] + self.jitter * rng.gauss() as f32).clamp(0.05, 0.95);
        s.ee[1] = (s.ee[1] + self.jitter * rng.gauss() as f32).clamp(0.05, 0.95);
        s
    }

    /// First unsatisfied stage index (None = task complete).
    pub fn active_stage(&self, scene: &Scene) -> Option<usize> {
        (0..self.stages.len()).find(|&i| !self.stages[i].satisfied(scene))
    }

    pub fn success(&self, scene: &Scene) -> bool {
        self.stages.iter().all(|st| st.satisfied(scene))
    }
}

const R: f32 = 0.10; // default placement radius

fn pick_place_task(
    name: &str,
    suite: &str,
    target: usize,
    goal: Goal,
    extra: Vec<Object>,
    horizon: usize,
    radius: f32,
) -> Task {
    let mut objects = vec![Object::rigid(target, [0.3, 0.35])];
    objects.extend(extra);
    Task {
        name: name.to_string(),
        suite: suite.to_string(),
        stages: vec![Stage { target_id: target, goal, radius }],
        horizon,
        template: Scene::new(objects, [0.15, 0.15]),
        jitter: 0.06,
    }
}

/// LIBERO-like suites: Spatial / Object / Goal / Long.
pub fn libero_suite(which: &str) -> Vec<Task> {
    match which {
        "spatial" => {
            // Place the object at a marked point among distractors.
            let layouts: [( [f32;2], [f32;2] ); 5] = [
                ([0.7, 0.7], [0.3, 0.7]),
                ([0.75, 0.3], [0.5, 0.8]),
                ([0.25, 0.75], [0.8, 0.5]),
                ([0.6, 0.2], [0.2, 0.5]),
                ([0.8, 0.8], [0.45, 0.3]),
            ];
            layouts
                .iter()
                .enumerate()
                .map(|(i, (mpos, dpos))| {
                    let mut t = pick_place_task(
                        &format!("spatial_{i}"),
                        "libero_spatial",
                        ids::APPLE,
                        Goal::Point(*mpos),
                        vec![
                            Object::fixed(ids::MARKER, *mpos),
                            Object::rigid(ids::BANANA, *dpos),
                            Object::rigid(ids::PEPPER, [dpos[1], dpos[0]]),
                        ],
                        110,
                        R,
                    );
                    t.jitter = 0.05;
                    t
                })
                .collect()
        }
        "object" => [ids::COKE, ids::APPLE, ids::BANANA, ids::PEPPER, ids::EGGPLANT]
            .iter()
            .enumerate()
            .map(|(i, &target)| {
                let distractors: Vec<Object> = [ids::COKE, ids::APPLE, ids::BANANA, ids::PEPPER, ids::EGGPLANT]
                    .iter()
                    .filter(|&&d| d != target)
                    .take(3)
                    .enumerate()
                    .map(|(k, &d)| Object::rigid(d, [0.25 + 0.18 * k as f32, 0.65]))
                    .collect();
                let mut extra = vec![Object::fixed(ids::BUCKET, [0.75, 0.25])];
                extra.extend(distractors);
                pick_place_task(
                    &format!("object_{i}"),
                    "libero_object",
                    target,
                    Goal::Obj(ids::BUCKET),
                    extra,
                    110,
                    R,
                )
            })
            .collect(),
        "goal" => {
            // Fixed target object, varying goal landmark.
            let goals: [(usize, [f32; 2]); 4] = [
                (ids::BUCKET, [0.8, 0.3]),
                (ids::MARKER, [0.25, 0.8]),
                (ids::BANANA, [0.7, 0.75]),
                (ids::PEPPER, [0.4, 0.2]),
            ];
            goals
                .iter()
                .enumerate()
                .map(|(i, &(gid, gpos))| {
                    let gobj = if gid == ids::BANANA || gid == ids::PEPPER {
                        Object::rigid(gid, gpos)
                    } else {
                        Object::fixed(gid, gpos)
                    };
                    pick_place_task(
                        &format!("goal_{i}"),
                        "libero_goal",
                        ids::APPLE,
                        Goal::Obj(gid),
                        vec![gobj, Object::rigid(ids::EGGPLANT, [0.55, 0.55])],
                        110,
                        0.12,
                    )
                })
                .collect()
        }
        "long" => {
            // Two-stage tasks: X → bucket, then Y → marker.
            let pairs = [
                (ids::APPLE, ids::BANANA),
                (ids::COKE, ids::PEPPER),
                (ids::EGGPLANT, ids::APPLE),
                (ids::BANANA, ids::COKE),
            ];
            pairs
                .iter()
                .enumerate()
                .map(|(i, &(a, b))| Task {
                    name: format!("long_{i}"),
                    suite: "libero_long".to_string(),
                    stages: vec![
                        Stage { target_id: a, goal: Goal::Obj(ids::BUCKET), radius: R },
                        Stage { target_id: b, goal: Goal::Obj(ids::MARKER), radius: R },
                    ],
                    horizon: 240,
                    template: Scene::new(
                        vec![
                            Object::rigid(a, [0.3, 0.3]),
                            Object::rigid(b, [0.3, 0.7]),
                            Object::fixed(ids::BUCKET, [0.8, 0.35]),
                            Object::fixed(ids::MARKER, [0.75, 0.75]),
                        ],
                        [0.15, 0.5],
                    ),
                    jitter: 0.05,
                })
                .collect()
        }
        _ => panic!("unknown LIBERO suite '{which}'"),
    }
}

/// SimplerEnv-like tasks: Pick Coke / Move Near / Open+Close Drawer /
/// Place Apple (open drawer then put the apple in).
pub fn simpler_suite() -> Vec<Task> {
    let mut tasks = Vec::new();
    tasks.push(pick_place_task(
        "pick_coke",
        "simpler",
        ids::COKE,
        Goal::Point([0.8, 0.75]),
        vec![Object::fixed(ids::MARKER, [0.8, 0.75]), Object::rigid(ids::PEPPER, [0.55, 0.3])],
        110,
        0.11,
    ));
    tasks.push(pick_place_task(
        "move_near",
        "simpler",
        ids::BANANA,
        Goal::Obj(ids::PEPPER),
        vec![Object::rigid(ids::PEPPER, [0.7, 0.6]), Object::rigid(ids::EGGPLANT, [0.5, 0.8])],
        110,
        0.13,
    ));
    tasks.push(Task {
        name: "open_drawer".to_string(),
        suite: "simpler".to_string(),
        stages: vec![Stage { target_id: ids::DRAWER, goal: Goal::DrawerOpen(0.85), radius: R }],
        horizon: 110,
        template: Scene::new(vec![Object::drawer([0.45, 0.6])], [0.25, 0.35]),
        jitter: 0.04,
    });
    tasks.push(Task {
        name: "close_drawer".to_string(),
        suite: "simpler".to_string(),
        stages: vec![Stage { target_id: ids::DRAWER, goal: Goal::DrawerClosed, radius: R }],
        horizon: 110,
        template: {
            let mut drawer = Object::drawer([0.45, 0.6]);
            drawer.pos[0] = drawer.base_x + crate::sim::scene::DRAWER_TRAVEL; // start open
            Scene::new(vec![drawer], [0.3, 0.4])
        },
        jitter: 0.04,
    });
    tasks.push(Task {
        name: "place_apple".to_string(),
        suite: "simpler".to_string(),
        stages: vec![
            Stage { target_id: ids::DRAWER, goal: Goal::DrawerOpen(0.7), radius: R },
            Stage { target_id: ids::APPLE, goal: Goal::Obj(ids::DRAWER), radius: 0.11 },
        ],
        horizon: 240,
        template: Scene::new(
            vec![Object::drawer([0.45, 0.65]), Object::rigid(ids::APPLE, [0.25, 0.3])],
            [0.2, 0.45],
        ),
        jitter: 0.04,
    });
    tasks
}

/// Mobile-ALOHA-like real-robot suite: Pick&Place (3 objects), Sequenced
/// Instruction (tower of hanoi), Flexible Folding (3-stage).
pub fn aloha_suite() -> Vec<Task> {
    let mut tasks = Vec::new();
    for (i, &obj) in [ids::BANANA, ids::PEPPER, ids::EGGPLANT].iter().enumerate() {
        let distractors: Vec<Object> = [ids::BANANA, ids::PEPPER, ids::EGGPLANT]
            .iter()
            .filter(|&&d| d != obj)
            .enumerate()
            .map(|(k, &d)| Object::rigid(d, [0.3 + 0.15 * k as f32, 0.7]))
            .collect();
        let mut extra = vec![Object::fixed(ids::BUCKET, [0.5, 0.45])];
        extra.extend(distractors);
        tasks.push(pick_place_task(
            &format!("pick_place_{i}"),
            "aloha_pick_place",
            obj,
            Goal::Obj(ids::BUCKET),
            extra,
            130,
            0.08,
        ));
    }
    tasks.push(Task {
        name: "tower_of_hanoi".to_string(),
        suite: "aloha_sequenced".to_string(),
        stages: vec![
            Stage { target_id: ids::TOWER_M, goal: Goal::Obj(ids::TOWER_L), radius: 0.09 },
            Stage { target_id: ids::TOWER_S, goal: Goal::Obj(ids::TOWER_M), radius: 0.09 },
        ],
        horizon: 260,
        template: Scene::new(
            vec![
                Object::rigid(ids::TOWER_S, [0.25, 0.3]),
                Object::rigid(ids::TOWER_M, [0.5, 0.25]),
                Object::rigid(ids::TOWER_L, [0.75, 0.55]),
            ],
            [0.2, 0.6],
        ),
        jitter: 0.04,
    });
    tasks.push(Task {
        name: "fold_towel".to_string(),
        suite: "aloha_folding".to_string(),
        stages: vec![
            Stage { target_id: ids::TOWEL_CORNER, goal: Goal::Point([0.5, 0.5]), radius: 0.08 },
            Stage { target_id: ids::PEPPER, goal: Goal::Point([0.5, 0.42]), radius: 0.08 },
            Stage { target_id: ids::COKE, goal: Goal::Point([0.42, 0.5]), radius: 0.08 },
        ],
        horizon: 300,
        template: Scene::new(
            vec![
                // Towel corners cast as distinct content ids (abstract sim).
                Object::rigid(ids::TOWEL_CORNER, [0.3, 0.72]),
                Object::rigid(ids::PEPPER, [0.72, 0.3]),
                Object::rigid(ids::COKE, [0.28, 0.3]),
                Object::fixed(ids::MARKER, [0.5, 0.5]),
            ],
            [0.5, 0.75],
        ),
        jitter: 0.03,
    });
    tasks
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suites_nonempty_and_tagged() {
        for which in ["spatial", "object", "goal", "long"] {
            let suite = libero_suite(which);
            assert!(!suite.is_empty());
            for t in &suite {
                assert!(t.suite.starts_with("libero_"));
                assert!(!t.stages.is_empty());
                assert!(t.horizon > 0);
            }
        }
        assert_eq!(simpler_suite().len(), 5);
        assert_eq!(aloha_suite().len(), 5);
    }

    #[test]
    fn instantiate_jitters_deterministically() {
        let t = &libero_suite("spatial")[0];
        let mut r1 = Rng::new(7);
        let mut r2 = Rng::new(7);
        let s1 = t.instantiate(&mut r1);
        let s2 = t.instantiate(&mut r2);
        assert_eq!(s1.objects[0].pos, s2.objects[0].pos);
        let mut r3 = Rng::new(8);
        let s3 = t.instantiate(&mut r3);
        assert_ne!(s1.objects[0].pos, s3.objects[0].pos);
    }

    #[test]
    fn stage_satisfaction_pick_place() {
        let t = &libero_suite("object")[0];
        let mut scene = t.template.clone();
        assert!(!t.success(&scene));
        assert_eq!(t.active_stage(&scene), Some(0));
        // Teleport the target onto the bucket.
        let bucket_pos = scene.find(ids::BUCKET).unwrap().pos;
        let tid = scene.find_idx(t.stages[0].target_id).unwrap();
        scene.objects[tid].pos = bucket_pos;
        assert!(t.success(&scene));
        assert_eq!(t.active_stage(&scene), None);
    }

    #[test]
    fn drawer_stages() {
        let tasks = simpler_suite();
        let open = tasks.iter().find(|t| t.name == "open_drawer").unwrap();
        let mut scene = open.template.clone();
        assert!(!open.success(&scene));
        scene.objects[0].pos[0] = scene.objects[0].base_x + crate::sim::scene::DRAWER_TRAVEL;
        assert!(open.success(&scene));
        let close = tasks.iter().find(|t| t.name == "close_drawer").unwrap();
        assert!(!close.success(&close.template.clone()));
    }

    #[test]
    fn held_object_does_not_satisfy_place() {
        let t = &libero_suite("object")[0];
        let mut scene = t.template.clone();
        let bucket_pos = scene.find(ids::BUCKET).unwrap().pos;
        let tid = scene.find_idx(t.stages[0].target_id).unwrap();
        scene.objects[tid].pos = bucket_pos;
        scene.held = Some(tid);
        assert!(!t.stages[0].satisfied(&scene), "held object must not count as placed");
    }

    #[test]
    fn stage_instructions_are_groundable() {
        for t in simpler_suite().iter().chain(aloha_suite().iter()) {
            for st in &t.stages {
                assert!(st.instr() < 64, "instr out of vocab for {}", t.name);
            }
        }
    }
}
