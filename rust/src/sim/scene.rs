//! Tabletop scene state and kinematic dynamics.
//!
//! A deliberately simple but *closed-loop* manipulation world: a planar
//! end-effector with a gripper, rigid objects that can be grasped and
//! carried, and sliding drawers. The property the paper's evaluation needs
//! — small per-step action errors compounding over long horizons into
//! grasp/placement failures — comes from the closed loop itself, not from
//! contact-physics fidelity (DESIGN.md §1).

/// Global content ids (shared with the model's content-code table).
pub mod ids {
    pub const COKE: usize = 0;
    pub const APPLE: usize = 1;
    pub const BANANA: usize = 2;
    pub const PEPPER: usize = 3;
    pub const EGGPLANT: usize = 4;
    pub const DRAWER: usize = 5;
    pub const BUCKET: usize = 6;
    pub const MARKER: usize = 7;
    // Aliases for suite-local casts (≤ 8 ids active per task).
    pub const TOWER_S: usize = 1;
    pub const TOWER_M: usize = 2;
    pub const TOWER_L: usize = 3;
    pub const TOWEL_CORNER: usize = 4;
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ObjKind {
    /// Grasp-and-carry rigid object.
    Rigid,
    /// Drawer handle: slides along +x within [base_x, base_x + travel].
    Drawer,
    /// Fixed landmark (bucket, goal marker): cannot be grasped.
    Fixed,
}

#[derive(Clone, Debug)]
pub struct Object {
    /// Content id (indexes the model's content-code table).
    pub id: usize,
    pub kind: ObjKind,
    pub pos: [f32; 2],
    /// Drawer: closed-position x; unused otherwise.
    pub base_x: f32,
}

impl Object {
    pub fn rigid(id: usize, pos: [f32; 2]) -> Self {
        Object { id, kind: ObjKind::Rigid, pos, base_x: 0.0 }
    }

    pub fn fixed(id: usize, pos: [f32; 2]) -> Self {
        Object { id, kind: ObjKind::Fixed, pos, base_x: 0.0 }
    }

    pub fn drawer(pos: [f32; 2]) -> Self {
        Object { id: ids::DRAWER, kind: ObjKind::Drawer, pos, base_x: pos[0] }
    }

    /// Drawer openness in [0, 1].
    pub fn openness(&self) -> f32 {
        ((self.pos[0] - self.base_x) / DRAWER_TRAVEL).clamp(0.0, 1.0)
    }
}

pub const DRAWER_TRAVEL: f32 = 0.18;

/// Physical/action constants.
#[derive(Clone, Copy, Debug)]
pub struct SimParams {
    /// Max end-effector displacement per step (action unit → world).
    pub max_step: f32,
    /// Grasp succeeds within this distance of an object.
    pub grasp_radius: f32,
}

impl Default for SimParams {
    fn default() -> Self {
        SimParams { max_step: 0.05, grasp_radius: 0.09 }
    }
}

#[derive(Clone, Debug)]
pub struct Scene {
    pub objects: Vec<Object>,
    pub ee: [f32; 2],
    /// 1.0 = closed.
    pub grip: f32,
    /// Index into `objects` of the held object.
    pub held: Option<usize>,
    pub t: usize,
    pub params: SimParams,
}

impl Scene {
    pub fn new(objects: Vec<Object>, ee: [f32; 2]) -> Self {
        Scene { objects, ee, grip: 0.0, held: None, t: 0, params: SimParams::default() }
    }

    pub fn find(&self, id: usize) -> Option<&Object> {
        self.objects.iter().find(|o| o.id == id)
    }

    pub fn find_idx(&self, id: usize) -> Option<usize> {
        self.objects.iter().position(|o| o.id == id)
    }

    pub fn dist_ee(&self, p: [f32; 2]) -> f32 {
        dist(self.ee, p)
    }

    /// Advance one step with action [dx, dy, grip_cmd] ∈ [−1,1]³.
    pub fn step(&mut self, action: &[f32]) {
        let p = self.params;
        let dx = action[0].clamp(-1.0, 1.0) * p.max_step;
        let dy = action[1].clamp(-1.0, 1.0) * p.max_step;
        self.ee[0] = (self.ee[0] + dx).clamp(0.0, 1.0);
        self.ee[1] = (self.ee[1] + dy).clamp(0.0, 1.0);
        let close_cmd = action[2] > 0.0;

        match (close_cmd, self.held) {
            (true, None) => {
                // Try to grasp the nearest graspable object.
                let mut best: Option<(usize, f32)> = None;
                for (i, o) in self.objects.iter().enumerate() {
                    if matches!(o.kind, ObjKind::Fixed) {
                        continue;
                    }
                    let d = dist(self.ee, o.pos);
                    if d < p.grasp_radius && best.map(|(_, bd)| d < bd).unwrap_or(true) {
                        best = Some((i, d));
                    }
                }
                if let Some((i, _)) = best {
                    self.held = Some(i);
                }
                self.grip = 1.0;
            }
            (false, Some(_)) => {
                self.held = None;
                self.grip = 0.0;
            }
            (true, Some(_)) | (false, None) => {
                self.grip = if close_cmd { 1.0 } else { 0.0 };
            }
        }

        // Carried object follows the end-effector (drawers slide in x only,
        // within their travel range).
        if let Some(i) = self.held {
            let (kind, base_x) = (self.objects[i].kind, self.objects[i].base_x);
            match kind {
                ObjKind::Drawer => {
                    let o = &mut self.objects[i];
                    o.pos[0] = self.ee[0].clamp(base_x, base_x + DRAWER_TRAVEL);
                }
                ObjKind::Rigid => {
                    let o = &mut self.objects[i];
                    o.pos = self.ee;
                }
                ObjKind::Fixed => unreachable!("fixed objects cannot be held"),
            }
        }
        self.t += 1;
    }
}

#[inline]
pub fn dist(a: [f32; 2], b: [f32; 2]) -> f32 {
    let dx = a[0] - b[0];
    let dy = a[1] - b[1];
    (dx * dx + dy * dy).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scene_one_obj() -> Scene {
        Scene::new(vec![Object::rigid(ids::APPLE, [0.5, 0.5])], [0.2, 0.2])
    }

    #[test]
    fn ee_moves_and_clamps() {
        let mut s = scene_one_obj();
        s.step(&[1.0, 0.0, -1.0]);
        assert!((s.ee[0] - 0.25).abs() < 1e-6);
        for _ in 0..100 {
            s.step(&[1.0, 1.0, -1.0]);
        }
        assert_eq!(s.ee, [1.0, 1.0]);
    }

    #[test]
    fn grasp_within_radius_only() {
        let mut s = scene_one_obj();
        s.step(&[0.0, 0.0, 1.0]); // far away: no grasp
        assert!(s.held.is_none());
        s.ee = [0.48, 0.5];
        s.step(&[0.0, 0.0, 1.0]);
        assert_eq!(s.held, Some(0));
    }

    #[test]
    fn carried_object_follows_and_releases() {
        let mut s = scene_one_obj();
        s.ee = [0.5, 0.5];
        s.step(&[0.0, 0.0, 1.0]);
        assert!(s.held.is_some());
        s.step(&[1.0, 0.0, 1.0]);
        assert_eq!(s.objects[0].pos, s.ee);
        let drop = s.objects[0].pos;
        s.step(&[0.0, 0.0, -1.0]);
        assert!(s.held.is_none());
        s.step(&[-1.0, 0.0, -1.0]);
        assert_eq!(s.objects[0].pos, drop, "released object stays put");
    }

    #[test]
    fn fixed_objects_ungraspable() {
        let mut s = Scene::new(vec![Object::fixed(ids::BUCKET, [0.3, 0.3])], [0.3, 0.3]);
        s.step(&[0.0, 0.0, 1.0]);
        assert!(s.held.is_none());
    }

    #[test]
    fn drawer_slides_within_travel() {
        let mut s = Scene::new(vec![Object::drawer([0.4, 0.6])], [0.4, 0.6]);
        s.step(&[0.0, 0.0, 1.0]);
        assert_eq!(s.held, Some(0));
        for _ in 0..20 {
            s.step(&[1.0, 0.0, 1.0]);
        }
        let o = &s.objects[0];
        assert!((o.openness() - 1.0).abs() < 1e-5, "openness={}", o.openness());
        assert!((o.pos[0] - (0.4 + DRAWER_TRAVEL)).abs() < 1e-5);
        // Sliding back closes it.
        for _ in 0..20 {
            s.step(&[-1.0, 0.0, 1.0]);
        }
        assert!(s.objects[0].openness() < 1e-5);
    }

    #[test]
    fn time_advances() {
        let mut s = scene_one_obj();
        s.step(&[0.0, 0.0, 0.0]);
        s.step(&[0.0, 0.0, 0.0]);
        assert_eq!(s.t, 2);
    }
}
