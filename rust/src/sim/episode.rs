//! Closed-loop episode runners: policy evaluation and expert
//! demonstration collection.
//!
//! The closed loop itself lives in [`EpisodeCursor`], an incremental
//! state machine that separates *environment stepping* (local, cheap)
//! from *policy decoding* (wherever the caller gets actions from: an
//! in-process model here, a remote [`crate::coordinator::server::
//! PolicyServer`] in the fleet harness). [`run_policy_episode`] is the
//! cursor driven by a local model — byte-for-byte the same rng
//! consumption order as always, so episode outcomes are unchanged.

use crate::model::layers::Hook;
use crate::model::MiniVla;
use crate::sim::expert::expert_action;
use crate::sim::observe::{observe, Observation, ObsParams};
use crate::sim::scene::Scene;
use crate::sim::tasks::Task;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct EpisodeResult {
    pub success: bool,
    pub steps: usize,
}

/// What an [`EpisodeCursor`] needs next.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CursorState {
    /// The action queue is empty: build an observation with
    /// [`EpisodeCursor::observation`], decode a chunk (locally or via a
    /// server), hand it back through [`EpisodeCursor::push_chunk`].
    NeedsDecode,
    /// The episode ended; [`EpisodeCursor::outcome`] is `Some`.
    Done,
}

/// Incremental closed-loop episode: owns the scene, the per-episode rng
/// stream, and the pending action queue, but *not* the policy — the
/// caller supplies decoded chunks, so the same state machine drives a
/// local model, a serving router, or a replay. The rng consumption order
/// (instantiate → per-decode observe → per-decode stochastic head) is
/// identical to the classic inline loop, which is what makes a served
/// episode bit-comparable to a local reference run of the same seed.
#[derive(Clone, Debug)]
pub struct EpisodeCursor {
    task: Task,
    scene: Scene,
    rng: Rng,
    /// Pending actions, reversed so `pop` yields them in decode order.
    queue: Vec<Vec<f32>>,
    step: usize,
    /// Effective horizon (the task's, optionally capped by the caller).
    horizon: usize,
    outcome: Option<EpisodeResult>,
}

impl EpisodeCursor {
    /// Start an episode. `horizon_cap` truncates long tasks (the fleet
    /// harness bounds wall time with it); `None` runs the task's own
    /// horizon, matching [`run_policy_episode`] exactly.
    pub fn new(task: Task, seed: u64, horizon_cap: Option<usize>) -> Self {
        let mut rng = Rng::with_stream(seed, 0xE9);
        let scene = task.instantiate(&mut rng);
        let horizon = horizon_cap.map_or(task.horizon, |h| h.min(task.horizon)).max(1);
        EpisodeCursor { task, scene, rng, queue: Vec::new(), step: 0, horizon, outcome: None }
    }

    /// Execute queued actions until the episode ends or the queue runs
    /// dry. `on_action` sees every *executed* action with its step index
    /// (the divergence tracker hangs off this).
    pub fn advance(&mut self, mut on_action: impl FnMut(usize, &[f32])) -> CursorState {
        loop {
            if self.outcome.is_some() {
                return CursorState::Done;
            }
            if self.step >= self.horizon {
                self.outcome = Some(EpisodeResult {
                    success: self.task.success(&self.scene),
                    steps: self.horizon,
                });
                return CursorState::Done;
            }
            if self.task.success(&self.scene) {
                self.outcome = Some(EpisodeResult { success: true, steps: self.step });
                return CursorState::Done;
            }
            let Some(action) = self.queue.pop() else {
                return CursorState::NeedsDecode;
            };
            on_action(self.step, &action);
            self.scene.step(&action);
            self.step += 1;
        }
    }

    /// The observation for the pending decode: the active stage's
    /// instruction over the current scene. Consumes this episode's rng
    /// (observation noise), exactly once per decode — callers must not
    /// rebuild it on a retry (cache the returned value instead), or the
    /// episode leaves the reference trajectory's noise stream.
    pub fn observation(&mut self, model: &MiniVla, params: &ObsParams) -> Observation {
        let stage = self.task.active_stage(&self.scene).unwrap_or(0);
        let instr = self.task.stages[stage].instr();
        observe(&self.scene, instr, self.task.horizon, model, params, &mut self.rng)
    }

    /// Hand a decoded action chunk to the episode (decode order; the
    /// cursor reverses internally for `pop`).
    pub fn push_chunk(&mut self, mut actions: Vec<Vec<f32>>) {
        actions.reverse();
        self.queue = actions;
    }

    /// The episode rng, positioned for a stochastic local decode — the
    /// slot the classic loop consumed between observe and step.
    pub fn decode_rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    pub fn task(&self) -> &Task {
        &self.task
    }

    /// Steps executed so far.
    pub fn step_index(&self) -> usize {
        self.step
    }

    pub fn finished(&self) -> bool {
        self.outcome.is_some()
    }

    pub fn outcome(&self) -> Option<EpisodeResult> {
        self.outcome.clone()
    }
}

/// Run the policy closed-loop on one episode. The observation parameters
/// are sampled once per episode by `obs_params` (Visual Matching vs
/// Variant Aggregation differ exactly here). Decoding happens every
/// `model.chunk_len()` steps; multi-stage tasks re-issue the active
/// stage's instruction at each decode (sequenced sub-instructions).
pub fn run_policy_episode(
    model: &MiniVla,
    task: &Task,
    obs_params: &ObsParams,
    seed: u64,
) -> EpisodeResult {
    run_policy_episode_hooked(model, task, obs_params, seed, &mut None)
}

/// Same as [`run_policy_episode`] but with an activation hook (used by the
/// calibration capture pass, which runs the *policy* distribution).
pub fn run_policy_episode_hooked(
    model: &MiniVla,
    task: &Task,
    obs_params: &ObsParams,
    seed: u64,
    hook: &mut Option<Hook>,
) -> EpisodeResult {
    let mut cursor = EpisodeCursor::new(task.clone(), seed, None);
    loop {
        match cursor.advance(|_, _| {}) {
            CursorState::Done => return cursor.outcome().expect("Done implies outcome"),
            CursorState::NeedsDecode => {
                let obs = cursor.observation(model, obs_params);
                let feat = model.features(&obs.visual_raw, obs.instr_id, &obs.proprio, hook);
                let chunk = model.decode(&feat, cursor.decode_rng());
                cursor.push_chunk(chunk);
            }
        }
    }
}

/// One demonstration step: the observation the policy would have seen and
/// the expert's action.
#[derive(Clone, Debug)]
pub struct DemoStep {
    pub obs: Observation,
    pub action: [f32; 3],
}

/// Roll out the scripted expert, recording (observation, action) pairs.
///
/// `noise` enables DART-style noise injection: the *executed* action is
/// the expert's plus exploration noise, while the recorded label stays
/// the expert's corrective action — widening the state coverage so the
/// cloned policy learns to recover from its own drift.
pub fn run_expert_episode_noisy(
    model: &MiniVla,
    task: &Task,
    obs_params: &ObsParams,
    seed: u64,
    noise: f64,
) -> (EpisodeResult, Vec<DemoStep>) {
    let mut rng = Rng::with_stream(seed, 0xDE);
    let mut scene = task.instantiate(&mut rng);
    let mut steps = Vec::new();
    for step in 0..task.horizon {
        if task.success(&scene) {
            return (EpisodeResult { success: true, steps: step }, steps);
        }
        let stage = task.active_stage(&scene).unwrap_or(0);
        let instr = task.stages[stage].instr();
        let obs = observe(&scene, instr, task.horizon, model, obs_params, &mut rng);
        let action = expert_action(&scene, task);
        steps.push(DemoStep { obs, action });
        let executed = [
            (action[0] + (noise * rng.gauss()) as f32).clamp(-1.0, 1.0),
            (action[1] + (noise * rng.gauss()) as f32).clamp(-1.0, 1.0),
            action[2],
        ];
        scene.step(&executed);
    }
    (EpisodeResult { success: task.success(&scene), steps: task.horizon }, steps)
}

/// Noise-free expert rollout (calibration capture uses this).
pub fn run_expert_episode(
    model: &MiniVla,
    task: &Task,
    obs_params: &ObsParams,
    seed: u64,
) -> (EpisodeResult, Vec<DemoStep>) {
    run_expert_episode_noisy(model, task, obs_params, seed, 0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{HeadKind, VlaConfig};
    use crate::sim::tasks::libero_suite;

    #[test]
    fn expert_episode_succeeds_and_records() {
        let model = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
        let task = &libero_suite("object")[0];
        let (res, demo) = run_expert_episode(&model, task, &ObsParams::clean(), 42);
        assert!(res.success);
        assert!(!demo.is_empty());
        assert!(demo.len() <= task.horizon);
        assert_eq!(demo[0].obs.proprio.len(), model.cfg.d_proprio);
    }

    #[test]
    fn untrained_policy_fails_gracefully() {
        // Zero-initialized heads → zero actions → no success, full horizon.
        let model = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
        let task = &libero_suite("object")[0];
        let res = run_policy_episode(&model, task, &ObsParams::clean(), 1);
        assert!(!res.success);
        assert_eq!(res.steps, task.horizon);
    }

    #[test]
    fn episodes_are_deterministic_given_seed() {
        let model = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
        let task = &libero_suite("spatial")[0];
        let a = run_policy_episode(&model, task, &ObsParams::clean(), 9);
        let b = run_policy_episode(&model, task, &ObsParams::clean(), 9);
        assert_eq!(a.success, b.success);
        assert_eq!(a.steps, b.steps);
    }

    /// The cursor must consume rng in exactly the order the classic
    /// inline loop did (instantiate → observe → decode, per chunk) —
    /// this pins the refactor against the pre-cursor implementation.
    #[test]
    fn cursor_matches_legacy_inline_loop_bit_exactly() {
        let model = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
        for (task, seed) in
            [(&libero_suite("object")[1], 5u64), (&libero_suite("spatial")[2], 23u64)]
        {
            // Legacy loop, verbatim, recording executed actions.
            let mut rng = Rng::with_stream(seed, 0xE9);
            let mut scene = task.instantiate(&mut rng);
            let mut queue: Vec<Vec<f32>> = Vec::new();
            let mut legacy_actions: Vec<Vec<f32>> = Vec::new();
            let mut legacy = None;
            for step in 0..task.horizon {
                if task.success(&scene) {
                    legacy = Some(EpisodeResult { success: true, steps: step });
                    break;
                }
                if queue.is_empty() {
                    let stage = task.active_stage(&scene).unwrap_or(0);
                    let instr = task.stages[stage].instr();
                    let obs =
                        observe(&scene, instr, task.horizon, &model, &ObsParams::clean(), &mut rng);
                    let feat =
                        model.features(&obs.visual_raw, obs.instr_id, &obs.proprio, &mut None);
                    queue = model.decode(&feat, &mut rng);
                    queue.reverse();
                }
                let action = queue.pop().unwrap();
                legacy_actions.push(action.clone());
                scene.step(&action);
            }
            let legacy = legacy.unwrap_or(EpisodeResult {
                success: task.success(&scene),
                steps: task.horizon,
            });

            // Cursor-driven run of the same seed.
            let mut cursor = EpisodeCursor::new(task.clone(), seed, None);
            let mut cursor_actions: Vec<Vec<f32>> = Vec::new();
            loop {
                match cursor.advance(|_, a| cursor_actions.push(a.to_vec())) {
                    CursorState::Done => break,
                    CursorState::NeedsDecode => {
                        let obs = cursor.observation(&model, &ObsParams::clean());
                        let feat =
                            model.features(&obs.visual_raw, obs.instr_id, &obs.proprio, &mut None);
                        let chunk = model.decode(&feat, cursor.decode_rng());
                        cursor.push_chunk(chunk);
                    }
                }
            }
            let got = cursor.outcome().unwrap();
            assert_eq!(got.success, legacy.success, "{}", task.name);
            assert_eq!(got.steps, legacy.steps, "{}", task.name);
            assert_eq!(cursor_actions, legacy_actions, "{}: executed actions", task.name);
        }
    }

    #[test]
    fn cursor_horizon_cap_truncates() {
        let model = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
        let task = libero_suite("object")[0].clone();
        // Zero-init policy never succeeds, so the cap is always what ends
        // the episode.
        let mut cursor = EpisodeCursor::new(task, 3, Some(7));
        let mut executed = 0usize;
        loop {
            match cursor.advance(|_, _| executed += 1) {
                CursorState::Done => break,
                CursorState::NeedsDecode => {
                    let obs = cursor.observation(&model, &ObsParams::clean());
                    let feat =
                        model.features(&obs.visual_raw, obs.instr_id, &obs.proprio, &mut None);
                    let chunk = model.decode(&feat, cursor.decode_rng());
                    cursor.push_chunk(chunk);
                }
            }
        }
        let out = cursor.outcome().unwrap();
        assert_eq!(out.steps, 7);
        assert_eq!(executed, 7);
        assert!(!out.success);
        assert!(cursor.finished());
        assert_eq!(cursor.step_index(), 7);
    }
}
