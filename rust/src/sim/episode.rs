//! Closed-loop episode runners: policy evaluation and expert
//! demonstration collection.

use crate::model::layers::Hook;
use crate::model::MiniVla;
use crate::sim::expert::expert_action;
use crate::sim::observe::{observe, Observation, ObsParams};
use crate::sim::tasks::Task;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct EpisodeResult {
    pub success: bool,
    pub steps: usize,
}

/// Run the policy closed-loop on one episode. The observation parameters
/// are sampled once per episode by `obs_params` (Visual Matching vs
/// Variant Aggregation differ exactly here). Decoding happens every
/// `model.chunk_len()` steps; multi-stage tasks re-issue the active
/// stage's instruction at each decode (sequenced sub-instructions).
pub fn run_policy_episode(
    model: &MiniVla,
    task: &Task,
    obs_params: &ObsParams,
    seed: u64,
) -> EpisodeResult {
    run_policy_episode_hooked(model, task, obs_params, seed, &mut None)
}

/// Same as [`run_policy_episode`] but with an activation hook (used by the
/// calibration capture pass, which runs the *policy* distribution).
pub fn run_policy_episode_hooked(
    model: &MiniVla,
    task: &Task,
    obs_params: &ObsParams,
    seed: u64,
    hook: &mut Option<Hook>,
) -> EpisodeResult {
    let mut rng = Rng::with_stream(seed, 0xE9);
    let mut scene = task.instantiate(&mut rng);
    let mut queue: Vec<Vec<f32>> = Vec::new();
    for step in 0..task.horizon {
        if task.success(&scene) {
            return EpisodeResult { success: true, steps: step };
        }
        if queue.is_empty() {
            let stage = task.active_stage(&scene).unwrap_or(0);
            let instr = task.stages[stage].instr();
            let obs = observe(&scene, instr, task.horizon, model, obs_params, &mut rng);
            let feat = model.features(&obs.visual_raw, obs.instr_id, &obs.proprio, hook);
            queue = model.decode(&feat, &mut rng);
            queue.reverse(); // pop from the back
        }
        let action = queue.pop().unwrap();
        scene.step(&action);
    }
    EpisodeResult { success: task.success(&scene), steps: task.horizon }
}

/// One demonstration step: the observation the policy would have seen and
/// the expert's action.
#[derive(Clone, Debug)]
pub struct DemoStep {
    pub obs: Observation,
    pub action: [f32; 3],
}

/// Roll out the scripted expert, recording (observation, action) pairs.
///
/// `noise` enables DART-style noise injection: the *executed* action is
/// the expert's plus exploration noise, while the recorded label stays
/// the expert's corrective action — widening the state coverage so the
/// cloned policy learns to recover from its own drift.
pub fn run_expert_episode_noisy(
    model: &MiniVla,
    task: &Task,
    obs_params: &ObsParams,
    seed: u64,
    noise: f64,
) -> (EpisodeResult, Vec<DemoStep>) {
    let mut rng = Rng::with_stream(seed, 0xDE);
    let mut scene = task.instantiate(&mut rng);
    let mut steps = Vec::new();
    for step in 0..task.horizon {
        if task.success(&scene) {
            return (EpisodeResult { success: true, steps: step }, steps);
        }
        let stage = task.active_stage(&scene).unwrap_or(0);
        let instr = task.stages[stage].instr();
        let obs = observe(&scene, instr, task.horizon, model, obs_params, &mut rng);
        let action = expert_action(&scene, task);
        steps.push(DemoStep { obs, action });
        let executed = [
            (action[0] + (noise * rng.gauss()) as f32).clamp(-1.0, 1.0),
            (action[1] + (noise * rng.gauss()) as f32).clamp(-1.0, 1.0),
            action[2],
        ];
        scene.step(&executed);
    }
    (EpisodeResult { success: task.success(&scene), steps: task.horizon }, steps)
}

/// Noise-free expert rollout (calibration capture uses this).
pub fn run_expert_episode(
    model: &MiniVla,
    task: &Task,
    obs_params: &ObsParams,
    seed: u64,
) -> (EpisodeResult, Vec<DemoStep>) {
    run_expert_episode_noisy(model, task, obs_params, seed, 0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{HeadKind, VlaConfig};
    use crate::sim::tasks::libero_suite;

    #[test]
    fn expert_episode_succeeds_and_records() {
        let model = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
        let task = &libero_suite("object")[0];
        let (res, demo) = run_expert_episode(&model, task, &ObsParams::clean(), 42);
        assert!(res.success);
        assert!(!demo.is_empty());
        assert!(demo.len() <= task.horizon);
        assert_eq!(demo[0].obs.proprio.len(), model.cfg.d_proprio);
    }

    #[test]
    fn untrained_policy_fails_gracefully() {
        // Zero-initialized heads → zero actions → no success, full horizon.
        let model = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
        let task = &libero_suite("object")[0];
        let res = run_policy_episode(&model, task, &ObsParams::clean(), 1);
        assert!(!res.success);
        assert_eq!(res.steps, task.horizon);
    }

    #[test]
    fn episodes_are_deterministic_given_seed() {
        let model = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
        let task = &libero_suite("spatial")[0];
        let a = run_policy_episode(&model, task, &ObsParams::clean(), 9);
        let b = run_policy_episode(&model, task, &ObsParams::clean(), 9);
        assert_eq!(a.success, b.success);
        assert_eq!(a.steps, b.steps);
    }
}
