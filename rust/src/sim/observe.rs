//! Observation featurizer: scene → visual tokens + proprio.
//!
//! This is where the paper's **dual dominance** phenomenon (Figure 1) is
//! generated explicitly: clutter tokens carry appearance features with
//! occasional extreme magnitudes (the "Val=106.5" background artifact),
//! and visual tokens vastly outnumber the single instruction token —
//! exactly the statistics that skew the uniform Hessian and that the
//! policy-aware rectification must overcome.

use crate::model::params::channels;
use crate::model::{content_codes, MiniVla};
use crate::sim::scene::Scene;
use crate::tensor::matrix::Matrix;
use crate::util::rng::Rng;

/// Per-episode observation-model parameters. `visual_matching()` mirrors
/// SimplerEnv's clean setting; `variant_aggregation(rng)` randomizes
/// lighting, clutter density and outlier magnitude.
#[derive(Clone, Debug)]
pub struct ObsParams {
    /// Multiplies appearance features (SimplerEnv lighting variation).
    pub lighting_gain: f32,
    /// Number of clutter (background) tokens.
    pub n_clutter: usize,
    /// Magnitude of clutter outlier activations.
    pub outlier_mag: f32,
    /// Probability a clutter token is an extreme outlier.
    pub outlier_prob: f64,
    /// Std of position observation noise.
    pub pos_noise: f32,
    /// Std of generic feature noise.
    pub feat_noise: f32,
}

impl ObsParams {
    pub fn clean() -> Self {
        ObsParams {
            lighting_gain: 1.0,
            n_clutter: 2,
            outlier_mag: 30.0,
            outlier_prob: 0.15,
            pos_noise: 0.004,
            feat_noise: 0.02,
        }
    }

    /// SimplerEnv "Visual Matching": minimal discrepancy.
    pub fn visual_matching() -> Self {
        Self::clean()
    }

    /// SimplerEnv "Variant Aggregation": randomized lighting, backgrounds
    /// and distractors per episode.
    pub fn variant_aggregation(rng: &mut Rng) -> Self {
        ObsParams {
            lighting_gain: rng.range(0.6, 1.7) as f32,
            n_clutter: 2 + rng.below(3),
            outlier_mag: rng.range(40.0, 110.0) as f32,
            outlier_prob: 0.35,
            pos_noise: 0.008,
            feat_noise: 0.05,
        }
    }
}

/// A full policy observation.
#[derive(Clone, Debug)]
pub struct Observation {
    /// d_vis_in × n_visual raw visual tokens.
    pub visual_raw: Matrix,
    pub instr_id: usize,
    pub proprio: Vec<f32>,
}

/// Appearance pattern per content id (deterministic), scaled by lighting.
fn appearance_pattern(id: usize, dim: usize) -> Vec<f32> {
    let mut rng = Rng::with_stream(0xA99EA5, id as u64);
    (0..dim).map(|_| rng.gauss() as f32).collect()
}

/// Featurize a scene for a given model config and instruction.
/// Token layout: one token per scene object (slot order = object order),
/// then clutter tokens, then zero padding up to `n_visual`.
pub fn observe(
    scene: &Scene,
    instr_id: usize,
    horizon: usize,
    model: &MiniVla,
    params: &ObsParams,
    rng: &mut Rng,
) -> Observation {
    let cfg = &model.cfg;
    let d = cfg.d_vis_in;
    let n = cfg.n_visual;
    let codes = content_codes();
    let appear_dim = d - channels::RAW_APPEAR_START;
    let mut v = Matrix::zeros(d, n);

    let mut slot = 0usize;
    for o in &scene.objects {
        if slot >= n {
            break;
        }
        // Content code.
        for (k, ch) in channels::RAW_CONTENT.enumerate() {
            v.set(ch, slot, codes.at(o.id, k));
        }
        // Noisy position.
        v.set(channels::RAW_POS.start, slot, o.pos[0] + params.pos_noise * rng.gauss() as f32);
        v.set(channels::RAW_POS.start + 1, slot, o.pos[1] + params.pos_noise * rng.gauss() as f32);
        // Extra geometry: drawer openness, held-by-gripper flag.
        v.set(channels::RAW_EXTRA.start, slot, o.openness());
        let held = scene.held.map(|h| std::ptr::eq(&scene.objects[h], o)).unwrap_or(false);
        v.set(channels::RAW_EXTRA.start + 1, slot, held as u8 as f32);
        // Appearance, lighting-scaled.
        let pat = appearance_pattern(o.id, appear_dim);
        for (k, &p) in pat.iter().enumerate() {
            v.set(
                channels::RAW_APPEAR_START + k,
                slot,
                params.lighting_gain * (p + params.feat_noise * rng.gauss() as f32),
            );
        }
        slot += 1;
    }

    // Clutter tokens: background junk with occasional extreme outliers —
    // the dual-dominance generator.
    for _ in 0..params.n_clutter {
        if slot >= n {
            break;
        }
        let mag = if rng.flip(params.outlier_prob) {
            params.outlier_mag
        } else {
            params.lighting_gain
        };
        v.set(channels::RAW_POS.start, slot, rng.uniform() as f32);
        v.set(channels::RAW_POS.start + 1, slot, rng.uniform() as f32);
        for k in channels::RAW_APPEAR_START..d {
            v.set(k, slot, mag * rng.gauss() as f32);
        }
        slot += 1;
    }

    // Remaining slots: silent padding with tiny noise.
    for s in slot..n {
        for k in 0..d {
            v.set(k, s, 0.01 * rng.gauss() as f32);
        }
    }

    // Gripper proximity sensors (real rigs expose these): distance to the
    // nearest graspable non-held object and to the nearest fixed landmark.
    // They make grasp/release thresholds linearly decodable.
    let mut s_grasp = 1.5f32;
    let mut s_landmark = 1.5f32;
    for (i, o) in scene.objects.iter().enumerate() {
        let d = crate::sim::scene::dist(scene.ee, o.pos);
        match o.kind {
            crate::sim::scene::ObjKind::Fixed => s_landmark = s_landmark.min(d),
            _ => {
                if scene.held != Some(i) {
                    s_grasp = s_grasp.min(d);
                }
            }
        }
    }
    let held = scene.held.is_some() as u8 as f32;
    let proprio = vec![
        scene.ee[0],
        scene.ee[1],
        scene.grip,
        held,
        scene.ee[0] * held,
        scene.ee[1] * held,
        s_grasp,
        s_landmark,
        s_grasp * held,
        s_landmark * held,
        scene.t as f32 / horizon.max(1) as f32,
        1.0,
    ];

    Observation { visual_raw: v, instr_id, proprio }
}

/// Figure-1 diagnostics: activation-magnitude statistics over an
/// observation batch — max |appearance| value, excess kurtosis, and the
/// visual-to-instruction token ratio.
pub struct DualDominanceStats {
    pub max_abs: f32,
    pub kurtosis: f32,
    pub visual_token_ratio: f32,
}

pub fn dual_dominance_stats(obs: &[Observation], cfg_n_visual: usize) -> DualDominanceStats {
    let mut vals = Vec::new();
    for o in obs {
        for t in 0..o.visual_raw.cols {
            for k in channels::RAW_APPEAR_START..o.visual_raw.rows {
                vals.push(o.visual_raw.at(k, t));
            }
        }
    }
    let max_abs = vals.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    DualDominanceStats {
        max_abs,
        kurtosis: crate::tensor::stats::excess_kurtosis(&vals),
        visual_token_ratio: cfg_n_visual as f32 / 1.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{HeadKind, VlaConfig};
    use crate::sim::scene::{ids, Object, Scene};

    fn setup() -> (MiniVla, Scene) {
        let model = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
        let scene = Scene::new(
            vec![Object::rigid(ids::APPLE, [0.3, 0.4]), Object::fixed(ids::BUCKET, [0.7, 0.7])],
            [0.1, 0.1],
        );
        (model, scene)
    }

    #[test]
    fn observation_shapes_match_config() {
        let (model, scene) = setup();
        let mut rng = Rng::new(191);
        let o = observe(&scene, 3, 100, &model, &ObsParams::clean(), &mut rng);
        assert_eq!(o.visual_raw.rows, model.cfg.d_vis_in);
        assert_eq!(o.visual_raw.cols, model.cfg.n_visual);
        assert_eq!(o.proprio.len(), model.cfg.d_proprio);
    }

    #[test]
    fn content_codes_present_in_slots() {
        let (model, scene) = setup();
        let mut rng = Rng::new(192);
        let o = observe(&scene, 0, 100, &model, &ObsParams::clean(), &mut rng);
        let codes = content_codes();
        for k in 0..8 {
            assert!((o.visual_raw.at(k, 0) - codes.at(ids::APPLE, k)).abs() < 1e-6);
            assert!((o.visual_raw.at(k, 1) - codes.at(ids::BUCKET, k)).abs() < 1e-6);
        }
    }

    #[test]
    fn positions_observed_with_small_noise() {
        let (model, scene) = setup();
        let mut rng = Rng::new(193);
        let o = observe(&scene, 0, 100, &model, &ObsParams::clean(), &mut rng);
        assert!((o.visual_raw.at(8, 0) - 0.3).abs() < 0.03);
        assert!((o.visual_raw.at(9, 0) - 0.4).abs() < 0.03);
    }

    #[test]
    fn variant_aggregation_produces_outliers() {
        let (model, scene) = setup();
        let mut rng = Rng::new(194);
        let mut obs = Vec::new();
        for _ in 0..40 {
            let p = ObsParams::variant_aggregation(&mut rng);
            obs.push(observe(&scene, 0, 100, &model, &p, &mut rng));
        }
        let stats = dual_dominance_stats(&obs, model.cfg.n_visual);
        // Extreme background activations, like Figure 1's Val=106.5.
        assert!(stats.max_abs > 30.0, "max_abs={}", stats.max_abs);
        assert!(stats.kurtosis > 5.0, "kurtosis={}", stats.kurtosis);
    }

    #[test]
    fn proprio_encodes_held_gate() {
        let (model, mut scene) = setup();
        let mut rng = Rng::new(195);
        scene.ee = [0.3, 0.4];
        scene.step(&[0.0, 0.0, 1.0]);
        assert!(scene.held.is_some());
        let o = observe(&scene, 0, 100, &model, &ObsParams::clean(), &mut rng);
        assert_eq!(o.proprio[3], 1.0);
        assert!((o.proprio[4] - scene.ee[0]).abs() < 1e-6);
    }
}
