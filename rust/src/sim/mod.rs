//! Closed-loop manipulation benchmarks: the tabletop world ([`scene`]),
//! the observation featurizer with dual-dominance statistics ([`observe`]),
//! staged tasks for the LIBERO / SimplerEnv / Mobile-ALOHA analogues
//! ([`tasks`]), the scripted expert ([`expert`]) and episode runners
//! ([`episode`]).

pub mod episode;
pub mod expert;
pub mod observe;
pub mod scene;
pub mod tasks;

pub use episode::{run_expert_episode, run_policy_episode, DemoStep, EpisodeResult};
pub use observe::{observe, ObsParams, Observation};
pub use scene::{Object, Scene};
pub use tasks::{aloha_suite, libero_suite, simpler_suite, Goal, Stage, Task};
