//! Matrix kernels: blocked, multi-threaded GEMM and friends.
//!
//! This is the hot path of both the PTQ pipeline (Hessian products, Haar
//! transforms, OBQ updates) and closed-loop policy inference, so the GEMM is
//! written to auto-vectorize: the inner loop is a saxpy over contiguous
//! rows (ikj order) on a zero-initialized accumulator panel.

use super::matrix::Matrix;
use crate::util::threadpool::parallel_for;

/// C = A · B  (A: m×k, B: k×n)
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows, "matmul shape mismatch: {}x{} @ {}x{}", a.rows, a.cols, b.rows, b.cols);
    let mut c = Matrix::zeros(a.rows, b.cols);
    matmul_into(a, b, &mut c);
    c
}

/// C = A · B, writing into a preallocated output (C is overwritten).
pub fn matmul_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols, b.rows);
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, b.cols);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    c.data.iter_mut().for_each(|v| *v = 0.0);
    // ikj loop: for each row of A, accumulate scaled rows of B. The j-loop
    // is contiguous over both B and C, so LLVM vectorizes it.
    for i in 0..m {
        let arow = a.row(i);
        let crow = &mut c.data[i * n..(i + 1) * n];
        for p in 0..k {
            let av = arow[p];
            if av == 0.0 {
                continue;
            }
            let brow = &b.data[p * n..(p + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
}

/// Threaded GEMM: rows of A are distributed over `threads` workers of
/// the persistent pool. Falls back to single-thread for small problems
/// (threshold retuned down from 2e7 when pooled dispatch replaced
/// per-call thread spawning).
pub fn matmul_mt(a: &Matrix, b: &Matrix, threads: usize) -> Matrix {
    assert_eq!(a.cols, b.rows);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let flops = 2.0 * m as f64 * k as f64 * n as f64;
    if threads <= 1 || flops < 4.0e6 {
        return matmul(a, b);
    }
    let mut c = Matrix::zeros(m, n);
    let cptr = SendPtr(c.data.as_mut_ptr());
    let rows_per = m.div_ceil(threads);
    let chunks = m.div_ceil(rows_per);
    parallel_for(chunks, threads, |ci| {
        // Capture the wrapper (not the raw field) so Send/Sync apply under
        // edition-2021 disjoint closure capture.
        let cptr = &cptr;
        let r0 = ci * rows_per;
        let r1 = ((ci + 1) * rows_per).min(m);
        for i in r0..r1 {
            let arow = a.row(i);
            // SAFETY: each worker writes a disjoint row range of C.
            let crow = unsafe { std::slice::from_raw_parts_mut(cptr.0.add(i * n), n) };
            for p in 0..k {
                let av = arow[p];
                if av == 0.0 {
                    continue;
                }
                let brow = &b.data[p * n..(p + 1) * n];
                for j in 0..n {
                    crow[j] += av * brow[j];
                }
            }
        }
    });
    c
}

struct SendPtr(*mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// y = A · x  (A: m×k, x: k) — GEMV used on the policy hot path.
pub fn matvec(a: &Matrix, x: &[f32]) -> Vec<f32> {
    assert_eq!(a.cols, x.len());
    let mut y = vec![0.0f32; a.rows];
    matvec_into(a, x, &mut y);
    y
}

pub fn matvec_into(a: &Matrix, x: &[f32], y: &mut [f32]) {
    assert_eq!(a.cols, x.len());
    assert_eq!(a.rows, y.len());
    for i in 0..a.rows {
        let row = a.row(i);
        let mut acc0 = 0.0f32;
        let mut acc1 = 0.0f32;
        let mut acc2 = 0.0f32;
        let mut acc3 = 0.0f32;
        let mut j = 0;
        while j + 4 <= row.len() {
            acc0 += row[j] * x[j];
            acc1 += row[j + 1] * x[j + 1];
            acc2 += row[j + 2] * x[j + 2];
            acc3 += row[j + 3] * x[j + 3];
            j += 4;
        }
        let mut acc = acc0 + acc1 + acc2 + acc3;
        while j < row.len() {
            acc += row[j] * x[j];
            j += 1;
        }
        y[i] = acc;
    }
}

/// Symmetric per-token INT8 activation scale: s = max|x| / 127, so
/// x ≈ s · q with q ∈ [−127, 127] (0 for an all-zero token — the
/// quantized vector is then exactly zero too). The W1A8 packed kernels
/// ([`crate::quant::packed::PackedBits::matvec_i8`]) and every test
/// reference share this one definition.
pub fn act_scale_i8(x: &[f32]) -> f32 {
    let mx = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    mx / 127.0
}

/// Quantize one activation value given the *reciprocal* scale (multiply,
/// round half-away-from-zero, clamp to ±127 — the symmetric range that
/// avoids the −128 asymmetry). Deterministic, so the GEMV and GEMM paths
/// produce bit-identical q from the same token.
#[inline]
pub fn quantize_i8(v: f32, inv_scale: f32) -> i8 {
    (v * inv_scale).round().clamp(-127.0, 127.0) as i8
}

/// i8·i8 dot product with i32 accumulation — the inner loop of the INT8
/// attention core (scores and context GEMM). |a·b| ≤ 127² per term, so
/// i32 holds sums over > 10⁵ terms: far past any head dim or segment.
#[inline]
pub fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x as i32 * y as i32).sum()
}

/// Reference form: quantize a whole activation vector to (q, scale).
/// Elementwise round-trip error is ≤ scale/2 by construction (pinned in
/// `tests/proptests.rs`).
pub fn quantize_vec_i8(x: &[f32]) -> (Vec<i8>, f32) {
    let s = act_scale_i8(x);
    if s == 0.0 {
        return (vec![0i8; x.len()], 0.0);
    }
    let inv = 1.0 / s;
    (x.iter().map(|&v| quantize_i8(v, inv)).collect(), s)
}

/// Dequantize an i8 activation vector (test/diagnostic path only — the
/// packed kernels never materialize this).
pub fn dequantize_vec_i8(q: &[i8], scale: f32) -> Vec<f32> {
    q.iter().map(|&v| v as f32 * scale).collect()
}

/// A · Aᵀ without forming the transpose (used for Hessians H = X Xᵀ with X
/// stored as rows = features, cols = tokens: call with X directly).
pub fn gram(a: &Matrix) -> Matrix {
    let n = a.rows;
    let mut g = Matrix::zeros(n, n);
    for i in 0..n {
        let ri = a.row(i);
        for j in i..n {
            let rj = a.row(j);
            let mut acc = 0.0f32;
            for p in 0..a.cols {
                acc += ri[p] * rj[p];
            }
            g.set(i, j, acc);
            g.set(j, i, acc);
        }
    }
    g
}

/// Weighted Gram: A · Diag(w) · Aᵀ — the policy-aware Hessian (Eq. 3).
pub fn gram_weighted(a: &Matrix, w: &[f32]) -> Matrix {
    assert_eq!(a.cols, w.len());
    let n = a.rows;
    let mut g = Matrix::zeros(n, n);
    for i in 0..n {
        let ri = a.row(i);
        for j in i..n {
            let rj = a.row(j);
            let mut acc = 0.0f32;
            for p in 0..a.cols {
                acc += w[p] * ri[p] * rj[p];
            }
            g.set(i, j, acc);
            g.set(j, i, acc);
        }
    }
    g
}

/// Softmax over each row, in place.
pub fn softmax_rows(m: &mut Matrix) {
    for i in 0..m.rows {
        let row = m.row_mut(i);
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// LayerNorm over each row (no affine), eps = 1e-5.
pub fn layernorm_rows(m: &mut Matrix) {
    for i in 0..m.rows {
        let row = m.row_mut(i);
        layernorm_vec(row);
    }
}

pub fn layernorm_vec(row: &mut [f32]) {
    let n = row.len() as f32;
    let mean = row.iter().sum::<f32>() / n;
    let mut var = 0.0f32;
    for v in row.iter() {
        let d = v - mean;
        var += d * d;
    }
    var /= n;
    let inv = 1.0 / (var + 1e-5).sqrt();
    for v in row.iter_mut() {
        *v = (*v - mean) * inv;
    }
}

/// GELU (tanh approximation), elementwise in place.
pub fn gelu(m: &mut [f32]) {
    for v in m.iter_mut() {
        let x = *v;
        let c = 0.797_884_6_f32; // sqrt(2/pi)
        let t = (c * (x + 0.044715 * x * x * x)).tanh();
        *v = 0.5 * x * (1.0 + t);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut acc = 0.0f64;
                for p in 0..a.cols {
                    acc += a.at(i, p) as f64 * b.at(p, j) as f64;
                }
                c.set(i, j, acc as f32);
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(1);
        for &(m, k, n) in &[(3, 4, 5), (16, 16, 16), (7, 33, 9), (1, 8, 1)] {
            let a = Matrix::gauss(m, k, 1.0, &mut rng);
            let b = Matrix::gauss(k, n, 1.0, &mut rng);
            let c = matmul(&a, &b);
            let c0 = naive_matmul(&a, &b);
            assert!(c.dist_sq(&c0) < 1e-6, "({m},{k},{n})");
        }
    }

    #[test]
    fn matmul_mt_matches_st() {
        let mut rng = Rng::new(2);
        let a = Matrix::gauss(128, 200, 1.0, &mut rng);
        let b = Matrix::gauss(200, 96, 1.0, &mut rng);
        let c1 = matmul(&a, &b);
        let c2 = matmul_mt(&a, &b, 8);
        assert!(c1.dist_sq(&c2) < 1e-8);
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Rng::new(3);
        let a = Matrix::gauss(31, 47, 1.0, &mut rng);
        let x = Matrix::gauss(47, 1, 1.0, &mut rng);
        let y1 = matvec(&a, &x.data);
        let y2 = matmul(&a, &x);
        for i in 0..31 {
            assert!((y1[i] - y2.at(i, 0)).abs() < 1e-4);
        }
    }

    #[test]
    fn gram_matches_explicit() {
        let mut rng = Rng::new(4);
        let x = Matrix::gauss(12, 40, 1.0, &mut rng);
        let g1 = gram(&x);
        let g2 = matmul(&x, &x.transpose());
        assert!(g1.dist_sq(&g2) < 1e-5);
    }

    #[test]
    fn gram_weighted_uniform_equals_gram() {
        let mut rng = Rng::new(5);
        let x = Matrix::gauss(10, 25, 1.0, &mut rng);
        let g1 = gram(&x);
        let g2 = gram_weighted(&x, &vec![1.0; 25]);
        assert!(g1.dist_sq(&g2) < 1e-6);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut rng = Rng::new(6);
        let mut m = Matrix::gauss(5, 9, 3.0, &mut rng);
        softmax_rows(&mut m);
        for i in 0..5 {
            let s: f32 = m.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(m.row(i).iter().all(|&v| v >= 0.0));
        }
    }

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let mut rng = Rng::new(7);
        let mut m = Matrix::gauss(4, 64, 5.0, &mut rng);
        layernorm_rows(&mut m);
        for i in 0..4 {
            let row = m.row(i);
            let mean: f32 = row.iter().sum::<f32>() / 64.0;
            let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 64.0;
            assert!(mean.abs() < 1e-4);
            assert!((var - 1.0).abs() < 1e-2);
        }
    }

    #[test]
    fn i8_quantize_roundtrip_within_half_scale() {
        let mut rng = Rng::new(8);
        for _ in 0..20 {
            let x: Vec<f32> = (0..97).map(|_| 3.0 * rng.gauss() as f32).collect();
            let (q, s) = quantize_vec_i8(&x);
            assert!(s > 0.0);
            let back = dequantize_vec_i8(&q, s);
            for (a, b) in x.iter().zip(&back) {
                // s/2 in exact arithmetic; the 1e-4 relative slack covers
                // f32 rounding of 1/s and of the scaled product.
                assert!((a - b).abs() <= s * 0.50005 + 1e-12, "{a} vs {b} (s={s})");
            }
        }
        // All-zero token: zero scale, exactly-zero quantization.
        let (q0, s0) = quantize_vec_i8(&[0.0; 16]);
        assert_eq!(s0, 0.0);
        assert!(q0.iter().all(|&v| v == 0));
    }

    #[test]
    fn i8_quantize_saturates_symmetric() {
        let x = [1.0f32, -1.0, 0.5, -0.5, 0.0];
        let (q, s) = quantize_vec_i8(&x);
        assert_eq!(q[0], 127);
        assert_eq!(q[1], -127);
        assert!((s - 1.0 / 127.0).abs() < 1e-9);
    }

    #[test]
    fn gelu_known_values() {
        let mut v = [0.0f32, 1.0, -1.0, 3.0];
        gelu(&mut v);
        assert!((v[0] - 0.0).abs() < 1e-6);
        assert!((v[1] - 0.8412).abs() < 1e-3);
        assert!((v[2] + 0.1588).abs() < 1e-3);
        assert!((v[3] - 2.9964).abs() < 1e-3);
    }
}
