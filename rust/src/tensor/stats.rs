//! Small statistics helpers used by saliency scoring, grouping and reports.

/// Mean of a slice.
pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f32>() / xs.len() as f32
}

/// Population variance.
pub fn variance(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|&x| (x - m) * (x - m)).sum::<f32>() / xs.len() as f32
}

pub fn std_dev(xs: &[f32]) -> f32 {
    variance(xs).sqrt()
}

/// Mean absolute deviation from `center` — the optimal 1-bit scale α for a
/// group binarized as α·sign(u − μ) is exactly mean(|u − μ|).
pub fn mean_abs_dev(xs: &[f32], center: f32) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|&x| (x - center).abs()).sum::<f32>() / xs.len() as f32
}

/// p-th quantile (0..=1) by sorting a copy. Linear interpolation.
pub fn quantile(xs: &[f32], p: f64) -> f32 {
    assert!(!xs.is_empty());
    let mut v: Vec<f32> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = p.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = (pos - lo as f64) as f32;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// Index of the maximum element.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Indices of the k largest elements, descending.
pub fn top_k(xs: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[b].partial_cmp(&xs[a]).unwrap_or(std::cmp::Ordering::Equal));
    idx.truncate(k.min(xs.len()));
    idx
}

/// Kurtosis (excess). Outlier-dominated activations (Figure 1) show large
/// positive excess kurtosis; reported by the dual-dominance analysis.
pub fn excess_kurtosis(xs: &[f32]) -> f32 {
    let n = xs.len();
    if n < 4 {
        return 0.0;
    }
    let m = mean(xs);
    let mut m2 = 0.0f64;
    let mut m4 = 0.0f64;
    for &x in xs {
        let d = (x - m) as f64;
        m2 += d * d;
        m4 += d * d * d * d;
    }
    m2 /= n as f64;
    m4 /= n as f64;
    if m2 < 1e-20 {
        return 0.0;
    }
    (m4 / (m2 * m2) - 3.0) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_var() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-6);
        assert!((variance(&xs) - 1.25).abs() < 1e-6);
    }

    #[test]
    fn mad_optimality() {
        // For 1-bit quantization q = a*sign(x-mu), the MSE-optimal a given mu
        // is mean|x-mu|. Check the analytic value beats perturbations.
        let xs = [0.3f32, -1.2, 2.0, 0.8, -0.1];
        let mu = mean(&xs);
        let a_opt = mean_abs_dev(&xs, mu);
        let err = |a: f32| -> f32 {
            xs.iter().map(|&x| {
                let q = a * (x - mu).signum();
                (x - mu - q) * (x - mu - q)
            }).sum()
        };
        assert!(err(a_opt) <= err(a_opt * 1.1) + 1e-6);
        assert!(err(a_opt) <= err(a_opt * 0.9) + 1e-6);
    }

    #[test]
    fn quantile_basics() {
        let xs = [1.0f32, 2.0, 3.0, 4.0, 5.0];
        assert!((quantile(&xs, 0.0) - 1.0).abs() < 1e-6);
        assert!((quantile(&xs, 1.0) - 5.0).abs() < 1e-6);
        assert!((quantile(&xs, 0.5) - 3.0).abs() < 1e-6);
        assert!((quantile(&xs, 0.25) - 2.0).abs() < 1e-6);
    }

    #[test]
    fn top_k_descending() {
        let xs = [0.1f32, 5.0, 3.0, 4.0];
        assert_eq!(top_k(&xs, 2), vec![1, 3]);
        assert_eq!(top_k(&xs, 10).len(), 4);
    }

    #[test]
    fn kurtosis_of_outliers_positive() {
        let mut xs = vec![0.0f32; 100];
        for (i, v) in xs.iter_mut().enumerate() {
            *v = ((i * 37 % 100) as f32 / 100.0) - 0.5;
        }
        let base = excess_kurtosis(&xs);
        xs[0] = 50.0; // inject an outlier
        assert!(excess_kurtosis(&xs) > base + 10.0);
    }

    #[test]
    fn argmax_finds_peak() {
        assert_eq!(argmax(&[1.0, 9.0, 3.0]), 1);
    }
}
