//! Dense linear algebra: Cholesky factorization, SPD solves, matrix
//! inversion and ridge regression. These back the OBQ/GPTQ compensation
//! (H⁻¹ via Cholesky) and behavioural-cloning fits (normal equations).
//!
//! Internals run in f64 for stability — calibration Hessians of nearly
//! collinear activations are poorly conditioned, and GPTQ error
//! compensation amplifies factorization noise.

use super::matrix::Matrix;

/// Lower-triangular Cholesky factor of an SPD matrix (f64 internally).
/// Returns `None` if the matrix is not positive definite (after the caller's
/// damping — callers should add λI first).
pub fn cholesky(a: &Matrix) -> Option<Vec<f64>> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.at(i, j) as f64;
            for k in 0..j {
                sum -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                l[i * n + i] = sum.sqrt();
            } else {
                l[i * n + j] = sum / l[j * n + j];
            }
        }
    }
    Some(l)
}

/// Solve L y = b (forward substitution), L lower-triangular n×n in f64.
fn forward_sub(l: &[f64], b: &[f64]) -> Vec<f64> {
    let n = b.len();
    let mut y = vec![0.0f64; n];
    for i in 0..n {
        let mut sum = b[i];
        for k in 0..i {
            sum -= l[i * n + k] * y[k];
        }
        y[i] = sum / l[i * n + i];
    }
    y
}

/// Solve Lᵀ x = y (back substitution).
fn backward_sub(l: &[f64], y: &[f64]) -> Vec<f64> {
    let n = y.len();
    let mut x = vec![0.0f64; n];
    for i in (0..n).rev() {
        let mut sum = y[i];
        for k in i + 1..n {
            sum -= l[k * n + i] * x[k];
        }
        x[i] = sum / l[i * n + i];
    }
    x
}

/// Solve A x = b for SPD A with pre-computed Cholesky factor.
pub fn cholesky_solve(l: &[f64], b: &[f32]) -> Vec<f32> {
    let b64: Vec<f64> = b.iter().map(|&v| v as f64).collect();
    let y = forward_sub(l, &b64);
    backward_sub(l, &y).into_iter().map(|v| v as f32).collect()
}

/// Invert an SPD matrix via Cholesky. Adds `damp`·mean(diag)·I first.
/// Used for H⁻¹ in OBQ; damping follows GPTQ's percdamp convention.
pub fn spd_inverse(a: &Matrix, damp: f64) -> Option<Matrix> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mean_diag: f64 = (0..n).map(|i| a.at(i, i) as f64).sum::<f64>() / n as f64;
    let lambda = (damp * mean_diag).max(1e-10);
    let mut ad = a.clone();
    for i in 0..n {
        *ad.at_mut(i, i) += lambda as f32;
    }
    let l = cholesky(&ad)?;
    let mut inv = Matrix::zeros(n, n);
    let mut e = vec![0.0f32; n];
    for j in 0..n {
        e[j] = 1.0;
        let col = cholesky_solve(&l, &e);
        for i in 0..n {
            inv.set(i, j, col[i]);
        }
        e[j] = 0.0;
    }
    Some(inv)
}

/// Ridge regression: solve (XᵀX + λI) W = Xᵀ Y for W (features×targets),
/// X: samples×features, Y: samples×targets. Returns W.
pub fn ridge(x: &Matrix, y: &Matrix, lambda: f64) -> Matrix {
    assert_eq!(x.rows, y.rows, "sample count mismatch");
    let d = x.cols;
    let t = y.cols;
    // Normal equations in f64.
    let mut xtx = vec![0.0f64; d * d];
    for s in 0..x.rows {
        let row = x.row(s);
        for i in 0..d {
            let xi = row[i] as f64;
            if xi == 0.0 {
                continue;
            }
            for j in i..d {
                xtx[i * d + j] += xi * row[j] as f64;
            }
        }
    }
    for i in 0..d {
        for j in 0..i {
            xtx[i * d + j] = xtx[j * d + i];
        }
        xtx[i * d + i] += lambda;
    }
    let mut xty = vec![0.0f64; d * t];
    for s in 0..x.rows {
        let xrow = x.row(s);
        let yrow = y.row(s);
        for i in 0..d {
            let xi = xrow[i] as f64;
            if xi == 0.0 {
                continue;
            }
            for k in 0..t {
                xty[i * t + k] += xi * yrow[k] as f64;
            }
        }
    }
    let xtx_m = Matrix::from_vec(d, d, xtx.iter().map(|&v| v as f32).collect());
    let l = match cholesky(&xtx_m) {
        Some(l) => l,
        None => {
            // Increase damping until PD.
            let mut lam = lambda.max(1e-6);
            loop {
                lam *= 10.0;
                let mut a = xtx_m.clone();
                for i in 0..d {
                    *a.at_mut(i, i) += lam as f32;
                }
                if let Some(l) = cholesky(&a) {
                    break l;
                }
                assert!(lam < 1e12, "ridge: matrix unsalvageable");
            }
        }
    };
    let mut w = Matrix::zeros(d, t);
    let mut rhs = vec![0.0f32; d];
    for k in 0..t {
        for i in 0..d {
            rhs[i] = xty[i * t + k] as f32;
        }
        let col = cholesky_solve(&l, &rhs);
        for i in 0..d {
            w.set(i, k, col[i]);
        }
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::matmul;
    use crate::util::rng::Rng;

    fn random_spd(n: usize, rng: &mut Rng) -> Matrix {
        let a = Matrix::gauss(n, n + 4, 1.0, rng);
        let mut g = matmul(&a, &a.transpose());
        for i in 0..n {
            *g.at_mut(i, i) += 0.5;
        }
        g
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Rng::new(21);
        let a = random_spd(12, &mut rng);
        let l = cholesky(&a).unwrap();
        let n = 12;
        for i in 0..n {
            for j in 0..n {
                let mut v = 0.0f64;
                for k in 0..n {
                    v += l[i * n + k] * l[j * n + k];
                }
                assert!((v - a.at(i, j) as f64).abs() < 1e-3, "({i},{j})");
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn solve_matches_direct() {
        let mut rng = Rng::new(22);
        let a = random_spd(8, &mut rng);
        let l = cholesky(&a).unwrap();
        let x_true = Matrix::gauss(8, 1, 1.0, &mut rng);
        let b = matmul(&a, &x_true);
        let x = cholesky_solve(&l, &b.data);
        for i in 0..8 {
            assert!((x[i] - x_true.data[i]).abs() < 1e-3);
        }
    }

    #[test]
    fn spd_inverse_is_inverse() {
        let mut rng = Rng::new(23);
        let a = random_spd(10, &mut rng);
        let inv = spd_inverse(&a, 0.0).unwrap();
        let prod = matmul(&a, &inv);
        let eye = Matrix::eye(10);
        assert!(prod.dist_sq(&eye) < 1e-4, "dist={}", prod.dist_sq(&eye));
    }

    #[test]
    fn ridge_recovers_linear_map() {
        let mut rng = Rng::new(24);
        let w_true = Matrix::gauss(6, 3, 1.0, &mut rng);
        let x = Matrix::gauss(200, 6, 1.0, &mut rng);
        let y = matmul(&x, &w_true);
        let w = ridge(&x, &y, 1e-6);
        assert!(w.dist_sq(&w_true) < 1e-4, "dist={}", w.dist_sq(&w_true));
    }

    #[test]
    fn ridge_shrinks_with_lambda() {
        let mut rng = Rng::new(25);
        let w_true = Matrix::gauss(5, 1, 1.0, &mut rng);
        let x = Matrix::gauss(100, 5, 1.0, &mut rng);
        let y = matmul(&x, &w_true);
        let w_small = ridge(&x, &y, 1e-6);
        let w_big = ridge(&x, &y, 1e4);
        assert!(w_big.frob_norm() < w_small.frob_norm());
    }

    #[test]
    fn ridge_handles_rank_deficiency() {
        let mut rng = Rng::new(26);
        // Duplicate feature columns => singular XtX; ridge must still solve.
        let base = Matrix::gauss(50, 3, 1.0, &mut rng);
        let x = Matrix::from_fn(50, 6, |i, j| base.at(i, j % 3));
        let y = Matrix::gauss(50, 2, 1.0, &mut rng);
        let w = ridge(&x, &y, 1e-3);
        assert!(w.is_finite());
    }
}
