//! Dense tensor substrate: the f32 matrix container, GEMM/GEMV kernels,
//! SPD linear algebra (Cholesky / ridge) and statistics helpers.

pub mod linalg;
pub mod matrix;
pub mod ops;
pub mod stats;

pub use matrix::Matrix;
