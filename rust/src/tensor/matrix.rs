//! Dense row-major `f32` matrix — the workhorse container of the repo.
//!
//! All model weights, activations, Hessians and quantizer intermediates are
//! `Matrix` values. We deliberately keep a single concrete dtype (f32) and
//! layout (row-major) so kernels in [`crate::tensor::ops`] can be tight.

use crate::util::rng::Rng;

#[derive(Clone, Debug, Default, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn filled(rows: usize, cols: usize, v: f32) -> Self {
        Matrix { rows, cols, data: vec![v; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    /// i.i.d. N(0, std²) entries.
    pub fn gauss(rows: usize, cols: usize, std: f32, rng: &mut Rng) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        rng.fill_gauss(&mut m.data, std);
        m
    }

    /// Near-orthogonal random matrix via Gram–Schmidt on gaussian rows,
    /// scaled by `gain`. For rows > cols, blocks of `cols` rows are
    /// orthogonalized independently.
    pub fn orthogonal(rows: usize, cols: usize, gain: f32, rng: &mut Rng) -> Self {
        let mut m = Matrix::gauss(rows, cols, 1.0, rng);
        let block = cols;
        let mut r0 = 0;
        while r0 < rows {
            let r1 = (r0 + block).min(rows);
            for i in r0..r1 {
                // Orthogonalize row i against rows r0..i.
                for k in r0..i {
                    let mut dot = 0.0f32;
                    for j in 0..cols {
                        dot += m.data[i * cols + j] * m.data[k * cols + j];
                    }
                    for j in 0..cols {
                        m.data[i * cols + j] -= dot * m.data[k * cols + j];
                    }
                }
                let mut n2 = 0.0f32;
                for j in 0..cols {
                    n2 += m.data[i * cols + j] * m.data[i * cols + j];
                }
                let inv = if n2 > 1e-12 { gain / n2.sqrt() } else { 0.0 };
                for j in 0..cols {
                    m.data[i * cols + j] *= inv;
                }
            }
            r0 = r1;
        }
        m
    }

    #[inline(always)]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline(always)]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    #[inline(always)]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    #[inline(always)]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline(always)]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.cols;
        &mut self.data[i * c..(i + 1) * c]
    }

    pub fn col(&self, j: usize) -> Vec<f32> {
        (0..self.rows).map(|i| self.at(i, j)).collect()
    }

    pub fn set_col(&mut self, j: usize, v: &[f32]) {
        assert_eq!(v.len(), self.rows);
        for i in 0..self.rows {
            self.set(i, j, v[i]);
        }
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        self.transpose_into(&mut t);
        t
    }

    /// Transpose into a caller-owned buffer (resized as needed) — the
    /// allocation-free form the packed GEMM scratch reuses across layers
    /// of a coalesced serving batch.
    pub fn transpose_into(&self, t: &mut Matrix) {
        t.rows = self.cols;
        t.cols = self.rows;
        // Resize WITHOUT clearing first: the blocked loop below writes
        // every element, so stale contents of a reused buffer are fine
        // and the full-size zero-fill memset is skipped.
        t.data.resize(self.rows * self.cols, 0.0);
        // Blocked transpose for cache friendliness.
        const B: usize = 32;
        for i0 in (0..self.rows).step_by(B) {
            for j0 in (0..self.cols).step_by(B) {
                for i in i0..(i0 + B).min(self.rows) {
                    for j in j0..(j0 + B).min(self.cols) {
                        t.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
    }

    /// Select columns by index into a new matrix.
    pub fn select_cols(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(self.rows, idx.len());
        for i in 0..self.rows {
            let src = self.row(i);
            let dst = out.row_mut(i);
            for (k, &j) in idx.iter().enumerate() {
                dst[k] = src[j];
            }
        }
        out
    }

    /// Scatter columns of `src` back into `self` at positions `idx`.
    pub fn assign_cols(&mut self, idx: &[usize], src: &Matrix) {
        assert_eq!(src.rows, self.rows);
        assert_eq!(src.cols, idx.len());
        for i in 0..self.rows {
            for (k, &j) in idx.iter().enumerate() {
                self.set(i, j, src.at(i, k));
            }
        }
    }

    /// Vertical slice: copy of columns [c0, c1).
    pub fn slice_cols(&self, c0: usize, c1: usize) -> Matrix {
        assert!(c0 <= c1 && c1 <= self.cols);
        let mut out = Matrix::zeros(self.rows, c1 - c0);
        for i in 0..self.rows {
            out.row_mut(i).copy_from_slice(&self.row(i)[c0..c1]);
        }
        out
    }

    /// Horizontal concatenation [a₀ | a₁ | …]; all parts must share `rows`.
    pub fn hcat(parts: &[&Matrix]) -> Matrix {
        assert!(!parts.is_empty(), "hcat of zero matrices");
        let rows = parts[0].rows;
        let cols = parts
            .iter()
            .map(|m| {
                assert_eq!(m.rows, rows, "hcat row mismatch");
                m.cols
            })
            .sum();
        let mut out = Matrix::zeros(rows, cols);
        for i in 0..rows {
            let dst = out.row_mut(i);
            let mut off = 0;
            for m in parts {
                dst[off..off + m.cols].copy_from_slice(m.row(i));
                off += m.cols;
            }
        }
        out
    }

    /// Horizontal slice rows [r0, r1).
    pub fn slice_rows(&self, r0: usize, r1: usize) -> Matrix {
        assert!(r0 <= r1 && r1 <= self.rows);
        Matrix {
            rows: r1 - r0,
            cols: self.cols,
            data: self.data[r0 * self.cols..r1 * self.cols].to_vec(),
        }
    }

    pub fn frob_norm_sq(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    pub fn frob_norm(&self) -> f64 {
        self.frob_norm_sq().sqrt()
    }

    /// ‖self − other‖²_F
    pub fn dist_sq(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| {
                let d = (a - b) as f64;
                d * d
            })
            .sum()
    }

    pub fn scale(&mut self, s: f32) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    pub fn add_assign(&mut self, other: &Matrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// Column ℓ2 norms.
    pub fn col_norms(&self) -> Vec<f32> {
        let mut n = vec![0.0f32; self.cols];
        for i in 0..self.rows {
            let row = self.row(i);
            for j in 0..self.cols {
                n[j] += row[j] * row[j];
            }
        }
        for v in &mut n {
            *v = v.sqrt();
        }
        n
    }

    /// Column ℓ1 norms.
    pub fn col_norms_l1(&self) -> Vec<f32> {
        let mut n = vec![0.0f32; self.cols];
        for i in 0..self.rows {
            let row = self.row(i);
            for j in 0..self.cols {
                n[j] += row[j].abs();
            }
        }
        n
    }

    pub fn diag(&self) -> Vec<f32> {
        let n = self.rows.min(self.cols);
        (0..n).map(|i| self.at(i, i)).collect()
    }

    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|v| v.is_finite())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(3);
        let m = Matrix::gauss(17, 33, 1.0, &mut rng);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn eye_diag() {
        let i = Matrix::eye(5);
        assert_eq!(i.diag(), vec![1.0; 5]);
        assert_eq!(i.frob_norm_sq(), 5.0);
    }

    #[test]
    fn select_assign_cols_roundtrip() {
        let mut rng = Rng::new(4);
        let m = Matrix::gauss(6, 10, 1.0, &mut rng);
        let idx = vec![1, 4, 7];
        let sub = m.select_cols(&idx);
        let mut m2 = m.clone();
        m2.assign_cols(&idx, &sub);
        assert_eq!(m2, m);
    }

    #[test]
    fn orthogonal_rows_are_orthonormal() {
        let mut rng = Rng::new(5);
        let q = Matrix::orthogonal(8, 16, 1.0, &mut rng);
        for i in 0..8 {
            for k in 0..8 {
                let dot: f32 = (0..16).map(|j| q.at(i, j) * q.at(k, j)).sum();
                let expect = if i == k { 1.0 } else { 0.0 };
                assert!((dot - expect).abs() < 1e-4, "i={i} k={k} dot={dot}");
            }
        }
    }

    #[test]
    fn col_norms_match_manual() {
        let m = Matrix::from_vec(2, 2, vec![3.0, 0.0, 4.0, 1.0]);
        let n = m.col_norms();
        assert!((n[0] - 5.0).abs() < 1e-6);
        assert!((n[1] - 1.0).abs() < 1e-6);
        let n1 = m.col_norms_l1();
        assert!((n1[0] - 7.0).abs() < 1e-6);
        assert!((n1[1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn dist_sq_zero_for_self() {
        let mut rng = Rng::new(6);
        let m = Matrix::gauss(5, 5, 2.0, &mut rng);
        assert_eq!(m.dist_sq(&m), 0.0);
    }
}
