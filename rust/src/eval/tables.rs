//! Drivers for the paper's main tables.
//!
//! - Table 1: SIMPLER (CogACT-mini / Diffusion head), Visual Matching and
//!   Variant Aggregation, methods × 4 tasks;
//! - Table 2: LIBERO (OpenVLA-mini Token head + OpenVLA-OFT-mini Chunk
//!   head), 4 suites × methods.
//!
//! Reported numbers are success rates; Δ is vs the FP row — the *shape*
//! (method ordering, small HBVLA delta, catastrophic BiLLM) is the
//! reproduction target (DESIGN.md §6).

use crate::coordinator::rollout::{eval_tasks, ObsMode, RolloutConfig};
use crate::coordinator::scheduler::quantize_model;
use crate::eval::harness::{build_testbed, paper_components, Testbed};
use crate::methods::paper_methods;
use crate::model::{HeadKind, MiniVla};
use crate::report::Table;
use crate::sim::tasks::{libero_suite, simpler_suite, Task};

/// Evaluation budget knobs (smoke runs shrink these).
#[derive(Clone, Debug)]
pub struct EvalBudget {
    pub episodes_per_task: usize,
    pub n_demos: usize,
    pub seed: u64,
    pub threads: usize,
}

impl Default for EvalBudget {
    fn default() -> Self {
        EvalBudget {
            episodes_per_task: 50,
            n_demos: crate::eval::harness::N_DEMOS,
            seed: 2026,
            threads: crate::util::threadpool::default_threads(),
        }
    }
}

impl EvalBudget {
    pub fn smoke() -> Self {
        EvalBudget { episodes_per_task: 4, n_demos: 24, ..Default::default() }
    }
}

fn rollout_cfg(b: &EvalBudget, mode: ObsMode) -> RolloutConfig {
    RolloutConfig { episodes_per_task: b.episodes_per_task, mode, seed: b.seed, threads: b.threads }
}

/// Evaluate FP + all paper methods on one task set / obs mode; returns
/// per-task columns per method row.
fn method_rows(
    tb: &Testbed,
    tasks: &[Task],
    mode: ObsMode,
    budget: &EvalBudget,
    fp_label: &str,
) -> Vec<(String, Vec<f64>)> {
    let cfg = rollout_cfg(budget, mode);
    let eval_model = |m: &MiniVla| -> Vec<f64> {
        let r = eval_tasks(m, tasks, &cfg);
        tasks.iter().map(|t| r.per_task[&t.name]).collect()
    };
    let mut rows = vec![(fp_label.to_string(), eval_model(&tb.model))];
    for method in paper_methods() {
        let (qm, _) = quantize_model(&tb.model, &tb.calib, method.as_ref(), &paper_components(), budget.threads);
        rows.push((method.name().to_string(), eval_model(&qm)));
    }
    rows
}

/// Merge open/close drawer task columns into one "O/C Drawer" column,
/// matching Table 1's presentation.
fn simpler_columns(tasks: &[Task], cells: &[f64]) -> Vec<f64> {
    let mut pick = 0.0;
    let mut movn = 0.0;
    let mut drawer = Vec::new();
    let mut apple = 0.0;
    for (t, &v) in tasks.iter().zip(cells) {
        match t.name.as_str() {
            "pick_coke" => pick = v,
            "move_near" => movn = v,
            "open_drawer" | "close_drawer" => drawer.push(v),
            "place_apple" => apple = v,
            _ => {}
        }
    }
    let oc = drawer.iter().sum::<f64>() / drawer.len().max(1) as f64;
    vec![pick, movn, oc, apple]
}

/// Table 1: SIMPLER with the CogACT-mini (diffusion) policy.
pub fn table1_simpler(budget: &EvalBudget) -> Vec<Table> {
    let tasks = simpler_suite();
    let tb = build_testbed(HeadKind::Diffusion, tasks.clone(), budget.n_demos, budget.seed);
    let mut tables = Vec::new();
    for (mode, label) in [
        (ObsMode::VisualMatching, "Visual Matching"),
        (ObsMode::VariantAggregation, "Variant Aggregation"),
    ] {
        let rows = method_rows(&tb, &tasks, mode, budget, "CogACT-mini (FP Model)");
        let mut t = Table::new(
            &format!("Table 1 — SIMPLER {label} (success rate, %)"),
            &["Pick Coke", "Move Near", "O/C Drawer", "Place Apple"],
        );
        for (label, cells) in rows {
            t.add_row(&label, simpler_columns(&tasks, &cells));
        }
        tables.push(t);
    }
    tables
}

/// Table 2: LIBERO with OpenVLA-mini (token) and OpenVLA-OFT-mini (chunk).
pub fn table2_libero(budget: &EvalBudget) -> Vec<Table> {
    let suites = ["spatial", "object", "goal", "long"];
    let mut tables = Vec::new();
    for (head, label) in [
        (HeadKind::Token, "OpenVLA-mini"),
        (HeadKind::Chunk, "OpenVLA-OFT-mini"),
    ] {
        // One testbed across all suites (one checkpoint, like the paper).
        let all_tasks: Vec<Task> = suites.iter().flat_map(|s| libero_suite(s)).collect();
        let tb = build_testbed(head, all_tasks, budget.n_demos, budget.seed);
        // Per-suite evaluation columns.
        let cfg = rollout_cfg(budget, ObsMode::VisualMatching);
        let eval_model = |m: &MiniVla| -> Vec<f64> {
            suites
                .iter()
                .map(|s| eval_tasks(m, &libero_suite(s), &cfg).success_rate())
                .collect()
        };
        let mut t = Table::new(
            &format!("Table 2 — LIBERO, {label} (success rate, %)"),
            &["Spatial", "Object", "Goal", "Long"],
        );
        t.add_row(&format!("{label} (FP Model)"), eval_model(&tb.model));
        for method in paper_methods() {
            let (qm, _) =
                quantize_model(&tb.model, &tb.calib, method.as_ref(), &paper_components(), budget.threads);
            t.add_row(method.name(), eval_model(&qm));
        }
        tables.push(t);
    }
    tables
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simpler_columns_merge_drawer() {
        let tasks = simpler_suite();
        let cells = vec![0.8, 0.7, 0.6, 0.4, 0.5]; // pick, move, open, close, apple
        let c = simpler_columns(&tasks, &cells);
        assert_eq!(c.len(), 4);
        assert!((c[2] - 0.5).abs() < 1e-9); // avg(0.6, 0.4)
        assert!((c[3] - 0.5).abs() < 1e-9);
    }

    /// Smoke: the full Table-1 pipeline runs end to end at tiny budget.
    /// (Uses the base-config model — a real but small workload.)
    #[test]
    #[ignore] // several minutes; exercised by `cargo test -- --ignored` and benches
    fn table1_smoke() {
        let tables = table1_simpler(&EvalBudget::smoke());
        assert_eq!(tables.len(), 2);
        assert_eq!(tables[0].rows.len(), 5);
    }
}
