//! Experiment drivers: one module per paper table/figure plus the
//! §Perf measurements. See DESIGN.md §6 for the experiment index.

pub mod ablation;
pub mod figures;
pub mod harness;
pub mod perf;
pub mod tables;

pub use harness::{build_testbed, paper_components, Testbed};
pub use tables::EvalBudget;
