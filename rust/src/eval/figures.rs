//! Drivers for the paper's figures.
//!
//! - Figure 1: dual-dominance activation statistics (outlier magnitude,
//!   kurtosis, visual-token imbalance);
//! - Figure 3: Mobile-ALOHA real-world suite (OpenVLA-OFT-mini), methods
//!   {FP, BiLLM, HBLLM, HBVLA};
//! - Figure 4: component-wise quantization sensitivity (CogACT-mini on
//!   SIMPLER): quantize one component at a time, everything else FP.

use crate::coordinator::rollout::{eval_tasks, ObsMode, RolloutConfig};
use crate::coordinator::scheduler::quantize_model;
use crate::eval::harness::build_testbed;
use crate::eval::tables::EvalBudget;
use crate::methods::{by_name, Component};
use crate::model::HeadKind;
use crate::report::Table;
use crate::sim::observe::{dual_dominance_stats, observe, DualDominanceStats, ObsParams};
use crate::sim::tasks::{aloha_suite, simpler_suite};
use crate::util::rng::Rng;

/// Figure 1: activation statistics over SimplerEnv-style observations.
pub fn fig1_dual_dominance(budget: &EvalBudget) -> DualDominanceStats {
    let tasks = simpler_suite();
    let model = crate::model::MiniVla::new(crate::model::VlaConfig::base(HeadKind::Diffusion));
    let mut rng = Rng::with_stream(budget.seed, 0xF1);
    let mut obs = Vec::new();
    for task in &tasks {
        for _ in 0..8 {
            let p = ObsParams::variant_aggregation(&mut rng);
            let scene = task.instantiate(&mut rng);
            obs.push(observe(&scene, task.stages[0].instr(), task.horizon, &model, &p, &mut rng));
        }
    }
    dual_dominance_stats(&obs, model.cfg.n_visual)
}

/// Figure 3: Mobile-ALOHA suite. Pick&Place evaluated for 30 trials (10
/// per object), other tasks 24 trials, matching the paper's protocol.
pub fn fig3_aloha(budget: &EvalBudget) -> Table {
    let tasks = aloha_suite();
    let tb = build_testbed(HeadKind::Chunk, tasks.clone(), budget.n_demos, budget.seed);
    let columns = ["Pick & Place", "Sequenced Instr", "Flexible Folding"];
    let trials = |suite: &str| -> usize {
        // 10 per pick-place object / 24 per other task, scaled by budget.
        let full = if suite == "aloha_pick_place" { 10 } else { 24 };
        (full * budget.episodes_per_task / 50).max(2)
    };
    let eval_model = |m: &crate::model::MiniVla| -> Vec<f64> {
        ["aloha_pick_place", "aloha_sequenced", "aloha_folding"]
            .iter()
            .map(|suite| {
                let st: Vec<_> = tasks.iter().filter(|t| t.suite == *suite).cloned().collect();
                let cfg = RolloutConfig {
                    episodes_per_task: trials(suite),
                    mode: ObsMode::VisualMatching,
                    seed: budget.seed,
                    threads: budget.threads,
                };
                eval_tasks(m, &st, &cfg).success_rate()
            })
            .collect()
    };
    let mut t = Table::new("Figure 3 — Mobile-ALOHA suite (success rate, %)", &columns);
    t.add_row("OpenVLA-OFT-mini (FP Model)", eval_model(&tb.model));
    for name in ["billm", "hbllm", "hbvla"] {
        let method = by_name(name).unwrap();
        let (qm, _) = quantize_model(
            &tb.model,
            &tb.calib,
            method.as_ref(),
            &crate::eval::harness::paper_components(),
            budget.threads,
        );
        t.add_row(method.name(), eval_model(&qm));
    }
    t
}

/// Figure 4: component sensitivity — quantize one component at a time
/// (HBVLA quantizer), evaluate on SIMPLER Visual Matching.
pub fn fig4_sensitivity(budget: &EvalBudget) -> Table {
    let tasks = simpler_suite();
    let tb = build_testbed(HeadKind::Diffusion, tasks.clone(), budget.n_demos, budget.seed);
    let cfg = RolloutConfig {
        episodes_per_task: budget.episodes_per_task,
        mode: ObsMode::VisualMatching,
        seed: budget.seed,
        threads: budget.threads,
    };
    let mut t = Table::new(
        "Figure 4 — component-wise quantization sensitivity (success rate, %)",
        &["SR"],
    );
    t.add_row("FP Model", vec![eval_tasks(&tb.model, &tasks, &cfg).success_rate()]);
    let method = by_name("hbvla").unwrap();
    for (label, comp) in [
        ("Vision only", Component::Vision),
        ("Language only", Component::Language),
        ("Projector only", Component::Projector),
        ("Action head only", Component::ActionHead),
    ] {
        let (qm, _) = quantize_model(&tb.model, &tb.calib, method.as_ref(), &[comp], budget.threads);
        t.add_row(label, vec![eval_tasks(&qm, &tasks, &cfg).success_rate()]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_reproduces_dual_dominance() {
        let s = fig1_dual_dominance(&EvalBudget::smoke());
        // Figure 1's phenomenon: extreme background activations (the paper
        // highlights Val=106.5) and heavy-tailed statistics.
        assert!(s.max_abs > 30.0, "max_abs={}", s.max_abs);
        assert!(s.kurtosis > 5.0, "kurtosis={}", s.kurtosis);
        assert!(s.visual_token_ratio >= 8.0);
    }
}
