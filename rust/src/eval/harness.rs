//! Shared experiment harness: builds a fitted MiniVLA ("checkpoint"), its
//! demonstration corpus, and the calibration Hessians — the inputs every
//! table/figure driver consumes.

use std::collections::HashMap;

use crate::calib::capture::{capture_calibration, CaptureConfig};
use crate::calib::demos::collect_demos;
use crate::methods::traits::{CalibData, Component};
use crate::model::{HeadKind, MiniVla, VlaConfig};
use crate::sim::tasks::Task;
use crate::train::bc::fit_policy;

/// Ridge strength used for every head fit (chosen once; see DESIGN.md §9).
pub const BC_LAMBDA: f64 = 1.0;

/// Demonstrations per checkpoint. The paper samples 256 calibration
/// trajectories; we reuse the BC corpus for calibration.
pub const N_DEMOS: usize = 256;

/// A ready-to-evaluate checkpoint.
pub struct Testbed {
    pub model: MiniVla,
    pub calib: HashMap<String, CalibData>,
    pub tasks: Vec<Task>,
}

/// The component set the paper's main tables quantize: vision + language
/// backbones, everything else FP.
pub fn paper_components() -> Vec<Component> {
    vec![Component::Vision, Component::Language]
}

/// Build a fitted + calibrated checkpoint for `head` over `tasks`.
/// `n_demos` can be reduced for smoke runs.
pub fn build_testbed(head: HeadKind, tasks: Vec<Task>, n_demos: usize, seed: u64) -> Testbed {
    let cfg = VlaConfig::base(head).with_seed(seed);
    let mut model = MiniVla::new(cfg);
    let demos = collect_demos(&model, &tasks, n_demos, seed ^ 0xD37A);
    fit_policy(&mut model, &demos, BC_LAMBDA);
    let calib = capture_calibration(&model, &demos, &CaptureConfig::default());
    Testbed { model, calib, tasks }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::HeadKind;
    use crate::sim::tasks::libero_suite;

    #[test]
    fn testbed_builds_and_calibrates() {
        let tb = build_testbed(HeadKind::Chunk, libero_suite("object"), 8, 3);
        assert!(!tb.calib.is_empty());
        for name in tb.model.store.quantizable_layers(None) {
            assert!(tb.calib.contains_key(&name), "{name}");
        }
    }

    #[test]
    fn paper_components_exclude_head() {
        let c = paper_components();
        assert!(c.contains(&Component::Vision));
        assert!(c.contains(&Component::Language));
        assert!(!c.contains(&Component::ActionHead));
        assert!(!c.contains(&Component::Projector));
    }
}
