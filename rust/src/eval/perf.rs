//! §Perf drivers: quantization throughput, packed-GEMV/GEMM vs dense,
//! rollout throughput, serving latency, batched-vs-sequential serving
//! forwards, the end-to-end dense-vs-packed forward comparison
//! (tokens/s + resident weight bytes), and the W1A32-vs-W1A8
//! activation-precision comparison (f32 vs integer packed kernels,
//! GEMV/GEMM GFLOPS + end-to-end tokens/s) — the measurements behind
//! EXPERIMENTS.md §Perf.

use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::registry::ModelRegistry;
use crate::coordinator::rollout::{eval_tasks, ObsMode, RolloutConfig};
use crate::coordinator::scheduler::{quantize_model, quantize_model_exact};
use crate::coordinator::server::{PolicyServer, ServeConfig, ServeRequest};
use crate::eval::harness::{build_testbed, paper_components};
use crate::methods::HbVla;
use crate::model::vla::ObsInput;
use crate::model::{HeadKind, MiniVla};
use crate::quant::packed::PackedBits;
use crate::sim::observe::{observe, ObsParams, Observation};
use crate::sim::tasks::libero_suite;
use crate::tensor::matrix::Matrix;
use crate::tensor::ops::{matmul_mt, matvec};
use crate::util::rng::Rng;

/// PR index stamped into the machine-readable bench baseline — bump this
/// alongside the `BENCH_PR<N>.json` filename CI archives, so trajectory
/// tooling keyed on the schema's own `pr` field stays truthful.
pub const BENCH_PR: u32 = 10;

pub struct PerfReport {
    /// Run parameters (recorded so `BENCH_*.json` baselines are
    /// self-describing across PRs).
    pub threads: usize,
    pub seed: u64,
    pub smoke: bool,
    pub quant_layers_per_sec: f64,
    pub quant_weights_per_sec: f64,
    pub rollout_eps_per_sec: f64,
    pub serve_p50_us: u64,
    pub serve_p99_us: u64,
    pub serve_p999_us: u64,
    pub serve_qps: f64,
    /// Dispatch shards of the serving-latency run (resolved: auto = one
    /// per worker), recorded so baselines say which router shape ran.
    pub serve_shards: usize,
    pub packed_gemv_gflops: f64,
    pub dense_gemv_gflops: f64,
    pub packed_gemm_gflops: f64,
    pub dense_gemm_gflops: f64,
    /// W1A8 integer kernels on the same packed weights: the bit-sliced
    /// popcount hot path, and the `trailing_zeros` extraction reference
    /// it replaced (kept like `matvec_per_bit` — the sliced/extract ratio
    /// is the PR-5 kernel speedup the bench baseline tracks).
    pub packed_gemv_i8_gflops: f64,
    pub packed_gemm_i8_gflops: f64,
    pub packed_gemv_i8_extract_gflops: f64,
    pub packed_gemm_i8_extract_gflops: f64,
    /// Mean per-call dispatch overhead of an 8-item trivial
    /// `parallel_for` on the persistent pool vs the per-call spawn
    /// reference — the dispatch cost the threshold retune is based on.
    pub pool_dispatch_us: f64,
    pub spawn_dispatch_us: f64,
    pub packed_mem_ratio: f64,
    /// End-to-end policy forward on the dense-twin model.
    pub e2e_dense_tok_per_sec: f64,
    /// End-to-end policy forward with every quantizable layer packed.
    pub e2e_packed_tok_per_sec: f64,
    /// End-to-end packed forward with Int8 activations (W1A8).
    pub e2e_packed_a8_tok_per_sec: f64,
    /// Resident weight bytes of the dense-twin / packed stores.
    pub e2e_dense_weight_bytes: usize,
    pub e2e_packed_weight_bytes: usize,
    /// Batched-serve forward throughput per batch size (dense vs packed,
    /// sequential per-request loop vs `features_batch`/`decode_batch`).
    pub batched_serve: Vec<BatchServeRow>,
    /// HBVLA deploy-form comparison — residual-plane repack
    /// (`hbvla-packed`) vs transform-domain exact serving (`hbvla-exact`)
    /// of the same checkpoint: end-to-end tokens/s, resident weight bytes
    /// (exact drops the residual planes), and closed-form action MSE
    /// against the FP policy.
    pub hbvla_repacked_tok_per_sec: f64,
    pub hbvla_exact_tok_per_sec: f64,
    pub hbvla_repacked_bytes: usize,
    pub hbvla_exact_bytes: usize,
    pub hbvla_repacked_action_mse: f64,
    pub hbvla_exact_action_mse: f64,
    /// Per-token vs calibrated-static activation scales on the W1A8
    /// serving variants (`rtn-packed-a8` / `hbvla-packed-a8` /
    /// `hbvla-exact` under Int8): end-to-end tokens/s and closed-form
    /// action MSE vs the FP policy for BOTH modes side by side — swept
    /// over both [`crate::calib::ScaleClip`] policies (max and p99.9).
    pub act_scale_rows: Vec<ActScaleRow>,
    /// The SIMD lane the forced-lane dispatch resolves to on this
    /// machine (`scalar`/`wide4`/`avx2`) — recorded so archived
    /// baselines say which kernel produced their numbers.
    pub simd_lane_active: String,
    /// Per-lane W1A8 sliced-kernel throughput on identical packed
    /// weights (bit-identical outputs; only the word-level inner loop
    /// differs). The wide4-vs-scalar and avx2-vs-scalar ratios are the
    /// PR-6 kernel win the baseline archives.
    pub simd_lanes: Vec<SimdLaneRow>,
    /// f32 vs INT8 attention core on the W1A8 commit: end-to-end
    /// tokens/s and closed-form action MSE vs the FP policy.
    pub attn_rows: Vec<AttnPrecRow>,
    /// Mixed-variant serving under the single-queue shape (`shards: 1`)
    /// vs the variant-affine sharded shape, same worker count and
    /// traffic — the dispatch-convoy fix the PR-8 baseline tracks via
    /// mean same-variant group size and tail latency.
    pub mixed_traffic: Vec<MixedTrafficRow>,
    /// Multi-host serving through the wire router: the same mixed
    /// traffic against 1/2/4 loopback hosts (every request crosses TCP +
    /// the placement-hashed router) — the scale-out trajectory the PR-9
    /// baseline tracks. The 4-host aggregate must beat single-host.
    pub multi_host: Vec<MultiHostRow>,
}

/// One row of the multi-host table: mixed-variant traffic routed over N
/// loopback wire hosts (2 workers each).
pub struct MultiHostRow {
    pub hosts: usize,
    pub requests: usize,
    pub responses_ok: u64,
    pub sheds: u64,
    pub errors: u64,
    /// Served tokens per second aggregated across hosts
    /// (`responses_ok × seq_len / wall`).
    pub tok_s: f64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub shed_rate: f64,
    /// Host rejoins observed by the router's reconnect supervisor during
    /// the run (0 on a fault-free bench; the column exists so chaos runs
    /// and the fleet drills share one schema).
    pub redials: u64,
    /// Requests transparently re-submitted to a replica after a host
    /// drop (0 on a fault-free bench).
    pub failovers: u64,
}

/// One row of the mixed-traffic table: 3-variant round-robin load from
/// concurrent clients against one router shape.
pub struct MixedTrafficRow {
    /// `single-queue` (shards pinned to 1) or `sharded` (one per worker).
    pub mode: String,
    pub workers: usize,
    pub shards: usize,
    pub requests: usize,
    pub responses_ok: u64,
    pub p50_us: u64,
    pub p99_us: u64,
    /// Mean dispatched batch size (any variant mix).
    pub mean_batch: f64,
    /// Mean same-variant group size — what the batched packed GEMM
    /// actually sees; the number sharding exists to raise.
    pub mean_group: f64,
    /// Whole-group steals across all shards (0 in single-queue mode).
    pub stolen_groups: u64,
}

/// One row of the SIMD-lane table: the forced-lane W1A8 GEMV/GEMM
/// throughput for one [`crate::quant::packed::SimdLane`].
pub struct SimdLaneRow {
    pub lane: String,
    pub gemv_gflops: f64,
    pub gemm_gflops: f64,
}

/// One row of the attention-precision table: the a8 serving model with
/// its attention core pinned to one [`crate::model::AttnPrecision`].
pub struct AttnPrecRow {
    pub precision: String,
    pub tok_s: f64,
    pub action_mse: f64,
}

/// One row of the batched-serve table: tokens/s at a given batch size for
/// the sequential per-request loop vs the batched forward, on the dense
/// twin and on the packed commit.
pub struct BatchServeRow {
    pub batch: usize,
    pub dense_seq_tok_s: f64,
    pub dense_batch_tok_s: f64,
    pub packed_seq_tok_s: f64,
    pub packed_batch_tok_s: f64,
}

/// One row of the activation-scale-mode table: a W1A8 variant measured
/// under per-token dynamic scales and under calibrated static scales.
pub struct ActScaleRow {
    pub variant: String,
    /// Clip policy of the static calibration (`max` or `p999`).
    pub clip: String,
    pub calibrated_layers: usize,
    pub per_token_tok_s: f64,
    pub static_tok_s: f64,
    pub per_token_action_mse: f64,
    pub static_action_mse: f64,
}

impl PerfReport {
    pub fn render(&self) -> String {
        format!(
            "quantization: {:.1} layers/s ({:.2} Mweights/s)\n\
             rollout:      {:.1} episodes/s\n\
             serving:      p50={}us p99={}us p999={}us throughput={:.0} req/s shards={}\n\
             packed GEMV:  {:.2} GFLOP/s (dense {:.2} GFLOP/s), memory ×{:.1} smaller\n\
             packed GEMM:  {:.2} GFLOP/s (dense {:.2} GFLOP/s), 16-token batch\n\
             {}\n\
             {}\n\
             end-to-end forward (dense twin vs 1-plane packed commit):\n\
             {}\n\
             {}\n\
             {}\n\
             {}\n\
             {}\n\
             {}\n\
             {}\n\
             {}",
            self.quant_layers_per_sec,
            self.quant_weights_per_sec / 1e6,
            self.rollout_eps_per_sec,
            self.serve_p50_us,
            self.serve_p99_us,
            self.serve_p999_us,
            self.serve_qps,
            self.serve_shards,
            self.packed_gemv_gflops,
            self.dense_gemv_gflops,
            self.packed_mem_ratio,
            self.packed_gemm_gflops,
            self.dense_gemm_gflops,
            self.kernel_table(),
            self.lane_table(),
            self.e2e_table(),
            self.act_table(),
            self.attn_table(),
            self.batched_serve_table(),
            self.exact_table(),
            self.act_scale_table(),
            self.mixed_table(),
            self.multi_host_table()
        )
    }

    /// The PR-9 multi-host table: the same mixed traffic routed across
    /// 1/2/4 loopback wire hosts.
    pub fn multi_host_table(&self) -> String {
        let mut s = String::from(
            "multi-host serving (wire router over N loopback hosts, 2 workers each):\n\
             \x20 hosts    reqs      ok   sheds    errs       tok/s   p50us   p99us  shed_rate  redial  failov\n",
        );
        for r in &self.multi_host {
            s.push_str(&format!(
                "  {:>5} {:>7} {:>7} {:>7} {:>7} {:>11.0} {:>7} {:>7} {:>10.4} {:>7} {:>7}\n",
                r.hosts,
                r.requests,
                r.responses_ok,
                r.sheds,
                r.errors,
                r.tok_s,
                r.p50_us,
                r.p99_us,
                r.shed_rate,
                r.redials,
                r.failovers
            ));
        }
        s
    }

    /// The PR-8 mixed-traffic table: single-queue vs variant-affine
    /// sharded dispatch under identical 3-variant concurrent load.
    pub fn mixed_table(&self) -> String {
        let mut s = String::from(
            "mixed-variant serving (single-queue vs variant-affine sharded dispatch):\n\
             \x20 mode          wrk shards    reqs     ok   p50us   p99us  mean_batch  mean_group  steals\n",
        );
        for r in &self.mixed_traffic {
            s.push_str(&format!(
                "  {:<12} {:>4} {:>6} {:>7} {:>6} {:>7} {:>7} {:>11.2} {:>11.2} {:>7}\n",
                r.mode,
                r.workers,
                r.shards,
                r.requests,
                r.responses_ok,
                r.p50_us,
                r.p99_us,
                r.mean_batch,
                r.mean_group,
                r.stolen_groups
            ));
        }
        s
    }

    /// The PR-6 wide-lane table: the forced-lane W1A8 sliced kernel at
    /// every lane this machine can run (outputs bit-identical across
    /// lanes AND to the extraction reference — only the word-level inner
    /// loop differs).
    pub fn lane_table(&self) -> String {
        let mut s = format!(
            "W1A8 sliced kernel by SIMD lane (active: {}):\n\
             \x20 lane     GEMV GFLOP/s   GEMM GFLOP/s\n",
            self.simd_lane_active
        );
        for r in &self.simd_lanes {
            s.push_str(&format!(
                "  {:<7} {:>12.2}   {:>12.2}\n",
                r.lane, r.gemv_gflops, r.gemm_gflops
            ));
        }
        s
    }

    /// The attention-core table: f32 vs INT8 scores+context on the W1A8
    /// serving model (the last f32 GEMM traffic in the a8 forward).
    pub fn attn_table(&self) -> String {
        let mut s = String::from(
            "attention core on the W1A8 commit (f32 vs int8 scores+context):\n\
             \x20 precision   e2e tokens/s   action MSE vs FP\n",
        );
        for r in &self.attn_rows {
            s.push_str(&format!(
                "  {:<9} {:>14.0}   {:>16.6}\n",
                r.precision, r.tok_s, r.action_mse
            ));
        }
        s
    }

    /// The PR-5 kernel table: bit-sliced popcount vs extraction W1A8
    /// kernels on identical packed weights (bit-identical outputs — only
    /// the inner loop differs), plus the pooled-vs-spawn dispatch
    /// overhead the for_each_row_par threshold retune rests on.
    pub fn kernel_table(&self) -> String {
        format!(
            "W1A8 inner loop (bit-sliced popcount vs trailing_zeros extraction):\n\
             \x20 kernel      GEMV GFLOP/s   GEMM GFLOP/s\n\
             \x20 sliced      {:>12.2}   {:>12.2}\n\
             \x20 extraction  {:>12.2}   {:>12.2}   (sliced ×{:.2} / ×{:.2})\n\
             parallel_for dispatch (8 trivial items): pool {:.1}us, spawn {:.1}us — ×{:.1} cheaper\n",
            self.packed_gemv_i8_gflops,
            self.packed_gemm_i8_gflops,
            self.packed_gemv_i8_extract_gflops,
            self.packed_gemm_i8_extract_gflops,
            self.packed_gemv_i8_gflops / self.packed_gemv_i8_extract_gflops.max(1e-9),
            self.packed_gemm_i8_gflops / self.packed_gemm_i8_extract_gflops.max(1e-9),
            self.pool_dispatch_us,
            self.spawn_dispatch_us,
            self.spawn_dispatch_us / self.pool_dispatch_us.max(1e-9)
        )
    }

    /// The activation-scale-mode table: per-token dynamic vs calibrated
    /// static scales on each W1A8 serving variant (tokens/s + action MSE
    /// vs FP side by side — the accuracy cost of skipping the max sweep).
    pub fn act_scale_table(&self) -> String {
        let mut s = String::from(
            "activation scales on W1A8 variants (per-token dynamic vs calibrated static):\n\
             \x20 variant           clip  layers   tok/s dyn   tok/s stat   MSE dyn      MSE stat\n",
        );
        for r in &self.act_scale_rows {
            s.push_str(&format!(
                "  {:<16} {:<5} {:>6}  {:>10.0}  {:>11.0}   {:<11.6} {:<11.6}\n",
                r.variant,
                r.clip,
                r.calibrated_layers,
                r.per_token_tok_s,
                r.static_tok_s,
                r.per_token_action_mse,
                r.static_action_mse
            ));
        }
        s
    }

    /// Machine-readable form of the whole report (hand-rolled JSON — no
    /// serde offline). This is the `BENCH_*.json` schema CI validates and
    /// archives per PR so kernel/dispatch speedups stay provable across
    /// the perf trajectory:
    /// `schema` pins the layout; every throughput is in the unit its key
    /// names (GFLOP/s, tokens/s, req/s, µs).
    pub fn to_json(&self) -> String {
        fn num(v: f64) -> String {
            if v.is_finite() {
                format!("{v:.6}")
            } else {
                "0.0".to_string()
            }
        }
        let batched: Vec<String> = self
            .batched_serve
            .iter()
            .map(|r| {
                format!(
                    "{{\"batch\":{},\"dense_seq_tok_s\":{},\"dense_batch_tok_s\":{},\
                     \"packed_seq_tok_s\":{},\"packed_batch_tok_s\":{}}}",
                    r.batch,
                    num(r.dense_seq_tok_s),
                    num(r.dense_batch_tok_s),
                    num(r.packed_seq_tok_s),
                    num(r.packed_batch_tok_s)
                )
            })
            .collect();
        let act_scale: Vec<String> = self
            .act_scale_rows
            .iter()
            .map(|r| {
                format!(
                    "{{\"variant\":\"{}\",\"clip\":\"{}\",\"calibrated_layers\":{},\
                     \"per_token_tok_s\":{},\
                     \"static_tok_s\":{},\"per_token_action_mse\":{},\"static_action_mse\":{}}}",
                    r.variant,
                    r.clip,
                    r.calibrated_layers,
                    num(r.per_token_tok_s),
                    num(r.static_tok_s),
                    num(r.per_token_action_mse),
                    num(r.static_action_mse)
                )
            })
            .collect();
        let lanes: Vec<String> = self
            .simd_lanes
            .iter()
            .map(|r| {
                format!(
                    "{{\"lane\":\"{}\",\"gemv_gflops\":{},\"gemm_gflops\":{}}}",
                    r.lane,
                    num(r.gemv_gflops),
                    num(r.gemm_gflops)
                )
            })
            .collect();
        let attn: Vec<String> = self
            .attn_rows
            .iter()
            .map(|r| {
                format!(
                    "{{\"precision\":\"{}\",\"tok_s\":{},\"action_mse\":{}}}",
                    r.precision,
                    num(r.tok_s),
                    num(r.action_mse)
                )
            })
            .collect();
        let multi_host: Vec<String> = self
            .multi_host
            .iter()
            .map(|r| {
                format!(
                    "{{\"hosts\":{},\"requests\":{},\"responses_ok\":{},\"sheds\":{},\
                     \"errors\":{},\"tok_s\":{},\"p50_us\":{},\"p99_us\":{},\"shed_rate\":{},\
                     \"redials\":{},\"failovers\":{}}}",
                    r.hosts,
                    r.requests,
                    r.responses_ok,
                    r.sheds,
                    r.errors,
                    num(r.tok_s),
                    r.p50_us,
                    r.p99_us,
                    num(r.shed_rate),
                    r.redials,
                    r.failovers
                )
            })
            .collect();
        let mixed: Vec<String> = self
            .mixed_traffic
            .iter()
            .map(|r| {
                format!(
                    "{{\"mode\":\"{}\",\"workers\":{},\"shards\":{},\"requests\":{},\
                     \"responses_ok\":{},\"p50_us\":{},\"p99_us\":{},\"mean_batch\":{},\
                     \"mean_group\":{},\"stolen_groups\":{}}}",
                    r.mode,
                    r.workers,
                    r.shards,
                    r.requests,
                    r.responses_ok,
                    r.p50_us,
                    r.p99_us,
                    num(r.mean_batch),
                    num(r.mean_group),
                    r.stolen_groups
                )
            })
            .collect();
        format!(
            "{{\n\
             \x20 \"schema\": \"hbvla-bench-v1\",\n\
             \x20 \"pr\": {BENCH_PR},\n\
             \x20 \"threads\": {},\n\
             \x20 \"seed\": {},\n\
             \x20 \"smoke\": {},\n\
             \x20 \"quant\": {{\"layers_per_s\": {}, \"mweights_per_s\": {}}},\n\
             \x20 \"rollout_eps_per_s\": {},\n\
             \x20 \"serve\": {{\"p50_us\": {}, \"p99_us\": {}, \"p999_us\": {}, \"qps\": {}, \"shards\": {}}},\n\
             \x20 \"gemv_gflops\": {{\"dense\": {}, \"packed_f32\": {}, \"packed_i8_sliced\": {}, \"packed_i8_extract\": {}}},\n\
             \x20 \"gemm_gflops\": {{\"dense\": {}, \"packed_f32\": {}, \"packed_i8_sliced\": {}, \"packed_i8_extract\": {}}},\n\
             \x20 \"simd_lane_active\": \"{}\",\n\
             \x20 \"simd_lanes\": [{}],\n\
             \x20 \"dispatch_us\": {{\"pool\": {}, \"spawn\": {}}},\n\
             \x20 \"packed_mem_ratio\": {},\n\
             \x20 \"e2e\": {{\"dense_tok_s\": {}, \"packed_tok_s\": {}, \"packed_a8_tok_s\": {}, \"dense_bytes\": {}, \"packed_bytes\": {}}},\n\
             \x20 \"attn_precision\": [{}],\n\
             \x20 \"batched_serve\": [{}],\n\
             \x20 \"hbvla_deploy\": {{\"repacked_tok_s\": {}, \"exact_tok_s\": {}, \"repacked_bytes\": {}, \"exact_bytes\": {}, \"repacked_action_mse\": {}, \"exact_action_mse\": {}}},\n\
             \x20 \"act_scale\": [{}],\n\
             \x20 \"mixed_traffic\": [{}],\n\
             \x20 \"multi_host\": [{}]\n\
             }}\n",
            self.threads,
            self.seed,
            self.smoke,
            num(self.quant_layers_per_sec),
            num(self.quant_weights_per_sec / 1e6),
            num(self.rollout_eps_per_sec),
            self.serve_p50_us,
            self.serve_p99_us,
            self.serve_p999_us,
            num(self.serve_qps),
            self.serve_shards,
            num(self.dense_gemv_gflops),
            num(self.packed_gemv_gflops),
            num(self.packed_gemv_i8_gflops),
            num(self.packed_gemv_i8_extract_gflops),
            num(self.dense_gemm_gflops),
            num(self.packed_gemm_gflops),
            num(self.packed_gemm_i8_gflops),
            num(self.packed_gemm_i8_extract_gflops),
            self.simd_lane_active,
            lanes.join(","),
            num(self.pool_dispatch_us),
            num(self.spawn_dispatch_us),
            num(self.packed_mem_ratio),
            num(self.e2e_dense_tok_per_sec),
            num(self.e2e_packed_tok_per_sec),
            num(self.e2e_packed_a8_tok_per_sec),
            self.e2e_dense_weight_bytes,
            self.e2e_packed_weight_bytes,
            attn.join(","),
            batched.join(","),
            num(self.hbvla_repacked_tok_per_sec),
            num(self.hbvla_exact_tok_per_sec),
            self.hbvla_repacked_bytes,
            self.hbvla_exact_bytes,
            num(self.hbvla_repacked_action_mse),
            num(self.hbvla_exact_action_mse),
            act_scale.join(","),
            mixed.join(","),
            multi_host.join(",")
        )
    }

    /// The HBVLA exact-vs-repacked table: serving the committed Haar-domain
    /// bitplanes (transform on the activation, zero residual planes) vs
    /// re-packing the reconstruction with residual planes. Exact serving
    /// should DROP memory — the residual planes existed only to absorb
    /// reconstruction error the exact form doesn't have.
    pub fn exact_table(&self) -> String {
        let mem_ratio =
            self.hbvla_repacked_bytes as f64 / self.hbvla_exact_bytes.max(1) as f64;
        format!(
            "hbvla deploy form (repacked residual planes vs transform-domain exact):\n\
             \x20 form      tokens/s   resident bytes   action MSE vs FP\n\
             \x20 repacked  {:>8.0}   {:>14}   {:>16.6}\n\
             \x20 exact     {:>8.0}   {:>14}   {:>16.6}   (×{:.2} less memory)\n",
            self.hbvla_repacked_tok_per_sec,
            self.hbvla_repacked_bytes,
            self.hbvla_repacked_action_mse,
            self.hbvla_exact_tok_per_sec,
            self.hbvla_exact_bytes,
            self.hbvla_exact_action_mse,
            mem_ratio
        )
    }

    /// The W1A32-vs-W1A8 comparison: f32 vs integer packed kernels on the
    /// same packed weights (effective GFLOPS counted at the dense FLOP
    /// equivalent), plus the end-to-end packed forward at each activation
    /// precision.
    pub fn act_table(&self) -> String {
        format!(
            "activation precision on packed weights (W1A32 f32 kernels vs W1A8 i8 kernels):\n\
             \x20 path    GEMV GFLOP/s   GEMM GFLOP/s   e2e tokens/s\n\
             \x20 W1A32   {:>12.2}   {:>12.2}   {:>12.0}\n\
             \x20 W1A8    {:>12.2}   {:>12.2}   {:>12.0}\n",
            self.packed_gemv_gflops,
            self.packed_gemm_gflops,
            self.e2e_packed_tok_per_sec,
            self.packed_gemv_i8_gflops,
            self.packed_gemm_i8_gflops,
            self.e2e_packed_a8_tok_per_sec
        )
    }

    /// The batched-serve table: per-request-loop vs batched forward
    /// tokens/s at each batch size, dense twin vs packed commit.
    pub fn batched_serve_table(&self) -> String {
        let mut s = String::from(
            "batched serve forward (tokens/s; seq = per-request loop, bat = features_batch):\n\
             \x20 batch   dense seq   dense bat   packed seq   packed bat\n",
        );
        for row in &self.batched_serve {
            s.push_str(&format!(
                "  {:>5}  {:>10.0}  {:>10.0}  {:>11.0}  {:>11.0}\n",
                row.batch,
                row.dense_seq_tok_s,
                row.dense_batch_tok_s,
                row.packed_seq_tok_s,
                row.packed_batch_tok_s
            ));
        }
        s
    }

    /// The end-to-end dense-vs-packed table: tokens/s and resident weight
    /// bytes per representation.
    pub fn e2e_table(&self) -> String {
        let mem_ratio =
            self.e2e_dense_weight_bytes as f64 / self.e2e_packed_weight_bytes.max(1) as f64;
        format!(
            "  repr             tokens/s   resident weight bytes\n\
             \x20 dense twin     {:>10.0}   {:>10}\n\
             \x20 packed 1-plane {:>10.0}   {:>10}   (weights ×{:.1} smaller)\n",
            self.e2e_dense_tok_per_sec,
            self.e2e_dense_weight_bytes,
            self.e2e_packed_tok_per_sec,
            self.e2e_packed_weight_bytes,
            mem_ratio
        )
    }
}

pub fn run_perf(threads: usize, seed: u64) -> PerfReport {
    run_perf_opts(threads, seed, false)
}

/// [`run_perf`] with a smoke switch: `smoke = true` shrinks every
/// iteration budget (CI runs this to emit the `BENCH_*.json` baseline on
/// the small testbed without burning minutes; the relative comparisons —
/// sliced vs extraction, pool vs spawn, static vs per-token — stay
/// meaningful at the reduced budget, absolute numbers are noisier).
pub fn run_perf_opts(threads: usize, seed: u64, smoke: bool) -> PerfReport {
    let tasks = libero_suite("object");
    let tb = build_testbed(HeadKind::Chunk, tasks.clone(), if smoke { 12 } else { 32 }, seed);

    // --- PTQ throughput ---
    let t0 = Instant::now();
    let reps = if smoke { 1 } else { 3 };
    let mut total_layers = 0usize;
    let mut total_weights = 0usize;
    for _ in 0..reps {
        let (_, rep) = quantize_model(&tb.model, &tb.calib, &HbVla::new(), &paper_components(), threads);
        total_layers += rep.layers.len();
        total_weights += rep.stats.weights as usize;
    }
    let quant_secs = t0.elapsed().as_secs_f64();

    // --- rollout throughput ---
    let cfg = RolloutConfig {
        episodes_per_task: if smoke { 2 } else { 6 },
        mode: ObsMode::VisualMatching,
        seed,
        threads,
    };
    let t1 = Instant::now();
    let r = eval_tasks(&tb.model, &tasks, &cfg);
    let rollout_secs = t1.elapsed().as_secs_f64();

    // --- serving latency/throughput (async waves exercise coalescing) ---
    let registry = Arc::new(ModelRegistry::new());
    registry.register("dense", Arc::new(tb.model.clone())).expect("register dense");
    let server = PolicyServer::start(Arc::clone(&registry), ServeConfig::default());
    let mut rng = Rng::with_stream(seed, 0x9F);
    let scene = tasks[0].instantiate(&mut rng);
    let obs =
        observe(&scene, tasks[0].stages[0].instr(), 100, &tb.model, &ObsParams::clean(), &mut rng);
    let n_req = if smoke { 64 } else { 400 };
    let wave = 16;
    let t2 = Instant::now();
    for _ in 0..n_req / wave {
        let handles: Vec<_> = (0..wave)
            .map(|_| server.submit_async(ServeRequest::new(obs.clone())).expect("submit"))
            .collect();
        for h in handles {
            let _ = h.wait().expect("serve");
        }
    }
    let serve_secs = t2.elapsed().as_secs_f64();
    let stats = server.latency_stats();
    // One sort serves all three ranks (the summary-path fix, applied here
    // too).
    let pcts = stats.percentiles_us(&[0.50, 0.99, 0.999]);
    let (p50, p99, p999) = (pcts[0], pcts[1], pcts[2]);
    let serve_shards = server.n_shards();
    server.shutdown();

    // --- packed vs dense GEMV ---
    let (rows, cols) = (512usize, 2048usize);
    let mut wr = Rng::with_stream(seed, 0x6E);
    let w = Matrix::gauss(rows, cols, 1.0, &mut wr);
    let x: Vec<f32> = (0..cols).map(|_| wr.gauss() as f32).collect();
    let packed = PackedBits::pack(&w, 128);
    let gsums = packed.group_sums(&x);
    let mut y = vec![0.0f32; rows];
    let iters = if smoke { 40 } else { 200 };
    let t3 = Instant::now();
    for _ in 0..iters {
        packed.matvec(&x, &gsums, &mut y);
    }
    let packed_secs = t3.elapsed().as_secs_f64();
    let t4 = Instant::now();
    for _ in 0..iters {
        matvec(&w, &x);
    }
    let dense_secs = t4.elapsed().as_secs_f64();
    let flops = 2.0 * rows as f64 * cols as f64 * iters as f64;

    // --- packed vs dense multi-token GEMM (rows over the thread pool) ---
    let batch = 16usize;
    let xb = Matrix::gauss(cols, batch, 1.0, &mut wr);
    let gemm_iters = if smoke { 8 } else { 30 };
    let t5 = Instant::now();
    for _ in 0..gemm_iters {
        std::hint::black_box(packed.matmul_mt(&xb, threads));
    }
    let packed_gemm_secs = t5.elapsed().as_secs_f64();
    let t6 = Instant::now();
    for _ in 0..gemm_iters {
        std::hint::black_box(matmul_mt(&w, &xb, threads));
    }
    let dense_gemm_secs = t6.elapsed().as_secs_f64();
    let gemm_flops = 2.0 * rows as f64 * cols as f64 * batch as f64 * gemm_iters as f64;

    // --- W1A8 integer kernels on the same packed weights ---
    // The f32 loop above amortizes its group sums outside the timing loop;
    // the i8 loop mirrors that with the quantized token prepared once (one
    // activation pass either way — the serving path pays it per token).
    let act = packed.quantize_act(&x);
    let t6b = Instant::now();
    for _ in 0..iters {
        packed.matvec_i8(&act, &mut y);
    }
    let packed_i8_secs = t6b.elapsed().as_secs_f64();
    let t6c = Instant::now();
    for _ in 0..gemm_iters {
        std::hint::black_box(packed.matmul_i8_mt(&xb, threads));
    }
    let packed_gemm_i8_secs = t6c.elapsed().as_secs_f64();
    // The extraction-kernel references the sliced kernels replaced (same
    // packed weights, bit-identical outputs — this ratio is the PR-5
    // kernel win the baseline archives).
    let t6d = Instant::now();
    for _ in 0..iters {
        packed.matvec_i8_extract(&act, &mut y);
    }
    let packed_i8_extract_secs = t6d.elapsed().as_secs_f64();
    let t6e = Instant::now();
    for _ in 0..gemm_iters {
        std::hint::black_box(packed.matmul_i8_extract_mt(&xb, threads));
    }
    let packed_gemm_i8_extract_secs = t6e.elapsed().as_secs_f64();

    // --- forced-lane sliced kernels: every lane this machine can run ---
    // Same packed weights, same quantized token; GEMV single-threaded so
    // the per-lane inner loop (not the fan-out) is what's measured, GEMM
    // under the run's thread budget like the rows above.
    let simd_lane_active = crate::quant::packed::SimdLane::active().label().to_string();
    let simd_lanes: Vec<SimdLaneRow> = crate::quant::packed::SimdLane::available()
        .into_iter()
        .map(|lane| {
            let tg = Instant::now();
            for _ in 0..iters {
                packed.matvec_i8_lane(&act, &mut y, 1, lane);
            }
            let gemv_secs = tg.elapsed().as_secs_f64();
            let tm = Instant::now();
            for _ in 0..gemm_iters {
                std::hint::black_box(packed.matmul_i8_lane(&xb, threads, lane));
            }
            let gemm_secs = tm.elapsed().as_secs_f64();
            SimdLaneRow {
                lane: lane.label().to_string(),
                gemv_gflops: flops / gemv_secs / 1e9,
                gemm_gflops: gemm_flops / gemm_secs / 1e9,
            }
        })
        .collect();

    // --- parallel_for dispatch overhead: pool vs per-call spawn ---
    let dispatch_iters = if smoke { 200 } else { 1000 };
    let sink = std::sync::atomic::AtomicUsize::new(0);
    let t6f = Instant::now();
    for _ in 0..dispatch_iters {
        crate::util::threadpool::parallel_for(8, 8, |i| {
            sink.fetch_add(i, std::sync::atomic::Ordering::Relaxed);
        });
    }
    let pool_dispatch_us = t6f.elapsed().as_secs_f64() / dispatch_iters as f64 * 1e6;
    let spawn_iters = if smoke { 50 } else { 200 };
    let t6g = Instant::now();
    for _ in 0..spawn_iters {
        crate::util::threadpool::parallel_for_spawn(8, 8, |i| {
            sink.fetch_add(i, std::sync::atomic::Ordering::Relaxed);
        });
    }
    let spawn_dispatch_us = t6g.elapsed().as_secs_f64() / spawn_iters as f64 * 1e6;
    std::hint::black_box(sink.load(std::sync::atomic::Ordering::Relaxed));

    // --- end-to-end: order-1 packed model vs its dense twin ---
    // This measures the single-bitplane (RTN-style) commit; transform
    // methods deploy pack_deploy chains whose GEMM cost scales linearly
    // with plane count — the table row is labeled accordingly.
    let mut packed_model = tb.model.clone();
    packed_model.store.pack_quantizable(64);
    // Pin the kernel thread budget to this run's --threads so the
    // emitted baseline's "threads" field describes what actually ran
    // (clones below inherit the pinned budget).
    packed_model.store.set_exec_threads(threads);
    let mut dense_model = packed_model.clone();
    dense_model.store.dequantize_all();
    let fw_iters = if smoke { 12 } else { 60 };
    let toks = (fw_iters * tb.model.cfg.seq_len()) as f64;
    let t7 = Instant::now();
    for _ in 0..fw_iters {
        let f = dense_model.features(&obs.visual_raw, obs.instr_id, &obs.proprio, &mut None);
        std::hint::black_box(f);
    }
    let e2e_dense_secs = t7.elapsed().as_secs_f64();
    let t8 = Instant::now();
    for _ in 0..fw_iters {
        let f = packed_model.features(&obs.visual_raw, obs.instr_id, &obs.proprio, &mut None);
        std::hint::black_box(f);
    }
    let e2e_packed_secs = t8.elapsed().as_secs_f64();
    // Same packed commit, Int8 activations: the W1A8 serving twin.
    let a8_model = packed_model.clone().with_act_precision(crate::model::ActPrecision::Int8);
    let t8b = Instant::now();
    for _ in 0..fw_iters {
        let f = a8_model.features(&obs.visual_raw, obs.instr_id, &obs.proprio, &mut None);
        std::hint::black_box(f);
    }
    let e2e_packed_a8_secs = t8b.elapsed().as_secs_f64();

    // --- batched vs sequential serving forward, dense vs packed ---
    let batch_sizes: &[usize] = if smoke { &[1, 4, 8] } else { &[1, 4, 8, 16] };
    let batched_serve = batch_sizes
        .iter()
        .map(|&batch| batched_serve_row(&dense_model, &packed_model, &obs, batch))
        .collect();

    // --- HBVLA deploy forms: residual-plane repack vs transform-exact ---
    let (mut hb_repacked, _) =
        quantize_model(&tb.model, &tb.calib, &HbVla::new(), &paper_components(), threads);
    let (mut hb_exact, _) = quantize_model_exact(
        &tb.model,
        &tb.calib,
        &HbVla::new(),
        &paper_components(),
        threads,
        "hbvla-exact",
    )
    .expect("HBVLA commits the transform-exact form");
    hb_repacked.store.set_exec_threads(threads);
    hb_exact.store.set_exec_threads(threads);
    let time_fw = |model: &MiniVla| -> f64 {
        let t = Instant::now();
        for _ in 0..fw_iters {
            let f = model.features(&obs.visual_raw, obs.instr_id, &obs.proprio, &mut None);
            std::hint::black_box(f);
        }
        toks / t.elapsed().as_secs_f64()
    };
    let hbvla_repacked_tok_per_sec = time_fw(&hb_repacked);
    let hbvla_exact_tok_per_sec = time_fw(&hb_exact);
    // Closed-form action MSE against the FP policy over a spread of
    // observations (Chunk head decode is deterministic).
    let probe_obs: Vec<Observation> = (0..if smoke { 4 } else { 8 })
        .map(|k| {
            let mut r = Rng::with_stream(seed, 0xE0 + k);
            let scene = tasks[k as usize % tasks.len()].instantiate(&mut r);
            observe(
                &scene,
                tasks[k as usize % tasks.len()].stages[0].instr(),
                100,
                &tb.model,
                &ObsParams::clean(),
                &mut r,
            )
        })
        .collect();
    let action_mse = |model: &MiniVla| -> f64 {
        let mut se = 0.0f64;
        let mut n = 0usize;
        for (k, o) in probe_obs.iter().enumerate() {
            let fq = model.features(&o.visual_raw, o.instr_id, &o.proprio, &mut None);
            let ff = tb.model.features(&o.visual_raw, o.instr_id, &o.proprio, &mut None);
            let aq = model.decode(&fq, &mut Rng::with_stream(0xAC, k as u64));
            let af = tb.model.decode(&ff, &mut Rng::with_stream(0xAC, k as u64));
            for (ca, cb) in aq.iter().zip(&af) {
                for (a, b) in ca.iter().zip(cb) {
                    se += ((a - b) as f64).powi(2);
                    n += 1;
                }
            }
        }
        se / n.max(1) as f64
    };
    let hbvla_repacked_action_mse = action_mse(&hb_repacked);
    let hbvla_exact_action_mse = action_mse(&hb_exact);

    // --- attention-core precision on the W1A8 commit ---
    // The a8 twin inherits INT8 attention; pinning f32 back isolates the
    // attention-core cost/accuracy from the packed-GEMM precision.
    let attn_f32 = a8_model.clone().with_attn_precision(crate::model::AttnPrecision::F32);
    let attn_rows = vec![
        AttnPrecRow {
            precision: "f32".to_string(),
            tok_s: time_fw(&attn_f32),
            action_mse: action_mse(&attn_f32),
        },
        AttnPrecRow {
            precision: "int8".to_string(),
            tok_s: time_fw(&a8_model),
            action_mse: action_mse(&a8_model),
        },
    ];

    // --- per-token vs calibrated-static activation scales (W1A8) ---
    // Each serving variant measured at Int8 under both scale modes; the
    // static twin is calibrated on a small demo stream exactly like
    // `serve --act-scale static` does.
    let (n_calib_demos, calib_steps) = crate::calib::scales::calib_recipe(smoke);
    let calib_demos = crate::calib::demos::collect_demos(
        &tb.model,
        &tasks,
        n_calib_demos,
        seed ^ crate::calib::scales::CALIB_SEED_STREAM,
    );
    let measure_scale_modes =
        |variant: &str, base: &MiniVla, clip: crate::calib::ScaleClip| -> ActScaleRow {
            let dyn_m = base.clone().with_act_precision(crate::model::ActPrecision::Int8);
            let mut stat_m = dyn_m.clone();
            let layers = crate::calib::scales::calibrate_static_scales_clip(
                &mut stat_m,
                &calib_demos,
                calib_steps,
                clip,
            );
            ActScaleRow {
                variant: variant.to_string(),
                clip: clip.label().to_string(),
                calibrated_layers: layers,
                per_token_tok_s: time_fw(&dyn_m),
                static_tok_s: time_fw(&stat_m),
                per_token_action_mse: action_mse(&dyn_m),
                static_action_mse: action_mse(&stat_m),
            }
        };
    let mut act_scale_rows = Vec::new();
    for clip in [crate::calib::ScaleClip::Max, crate::calib::ScaleClip::Percentile] {
        act_scale_rows.push(measure_scale_modes("rtn-packed-a8", &packed_model, clip));
        act_scale_rows.push(measure_scale_modes("hbvla-packed-a8", &hb_repacked, clip));
        act_scale_rows.push(measure_scale_modes("hbvla-exact", &hb_exact, clip));
    }

    // --- mixed-variant traffic: single-queue vs variant-affine sharded ---
    // Identical 3-variant round-robin load from concurrent clients against
    // both router shapes at the same worker count. The variant names are
    // chosen to spread across all the sharded run's shards (FNV-1a mod 4:
    // dense→0, rtn-packed→2, hbvla-packed-a8→3), so the comparison shows
    // the affinity effect, not a hash-collision accident.
    let mix_registry = Arc::new(ModelRegistry::new());
    mix_registry.register("dense", Arc::new(dense_model.clone())).expect("register dense");
    mix_registry
        .register("rtn-packed", Arc::new(packed_model.clone()))
        .expect("register rtn-packed");
    mix_registry
        .register(
            "hbvla-packed-a8",
            Arc::new(hb_repacked.clone().with_act_precision(crate::model::ActPrecision::Int8)),
        )
        .expect("register hbvla-packed-a8");
    let mix_variants = ["dense", "rtn-packed", "hbvla-packed-a8"];
    let mix_requests = if smoke { 120 } else { 480 };
    let mixed_traffic = vec![
        mixed_traffic_row(&mix_registry, &obs, &mix_variants, "single-queue", 4, 1, mix_requests),
        mixed_traffic_row(&mix_registry, &obs, &mix_variants, "sharded", 4, 4, mix_requests),
    ];

    // --- multi-host serving: the same mix through the wire router ---
    // 1/2/4 loopback hosts (2 workers each) behind one placement-hashed
    // router; every request crosses real TCP. Aggregate capacity grows
    // with hosts, so the 4-host tok/s row must beat single-host — that
    // ratio is the scale-out win the PR-9 baseline archives.
    let mh_requests = if smoke { 96 } else { 384 };
    let seq_len = tb.model.cfg.seq_len();
    let multi_host = [1usize, 2, 4]
        .iter()
        .map(|&h| multi_host_row(&mix_registry, &obs, &mix_variants, h, seq_len, mh_requests))
        .collect();

    PerfReport {
        threads,
        seed,
        smoke,
        quant_layers_per_sec: total_layers as f64 / quant_secs,
        quant_weights_per_sec: total_weights as f64 / quant_secs,
        rollout_eps_per_sec: r.episodes as f64 / rollout_secs,
        serve_p50_us: p50,
        serve_p99_us: p99,
        serve_p999_us: p999,
        serve_qps: n_req as f64 / serve_secs,
        serve_shards,
        packed_gemv_gflops: flops / packed_secs / 1e9,
        dense_gemv_gflops: flops / dense_secs / 1e9,
        packed_gemm_gflops: gemm_flops / packed_gemm_secs / 1e9,
        dense_gemm_gflops: gemm_flops / dense_gemm_secs / 1e9,
        packed_gemv_i8_gflops: flops / packed_i8_secs / 1e9,
        packed_gemm_i8_gflops: gemm_flops / packed_gemm_i8_secs / 1e9,
        packed_gemv_i8_extract_gflops: flops / packed_i8_extract_secs / 1e9,
        packed_gemm_i8_extract_gflops: gemm_flops / packed_gemm_i8_extract_secs / 1e9,
        pool_dispatch_us,
        spawn_dispatch_us,
        packed_mem_ratio: packed.compression_ratio(),
        e2e_dense_tok_per_sec: toks / e2e_dense_secs,
        e2e_packed_tok_per_sec: toks / e2e_packed_secs,
        e2e_packed_a8_tok_per_sec: toks / e2e_packed_a8_secs,
        e2e_dense_weight_bytes: dense_model.store.resident_weight_bytes(),
        e2e_packed_weight_bytes: packed_model.store.resident_weight_bytes(),
        batched_serve,
        hbvla_repacked_tok_per_sec,
        hbvla_exact_tok_per_sec,
        hbvla_repacked_bytes: hb_repacked.store.resident_weight_bytes(),
        hbvla_exact_bytes: hb_exact.store.resident_weight_bytes(),
        hbvla_repacked_action_mse,
        hbvla_exact_action_mse,
        act_scale_rows,
        simd_lane_active,
        simd_lanes,
        attn_rows,
        mixed_traffic,
        multi_host,
    }
}

/// Drive one loopback cluster size with the mixed round-robin traffic
/// from 4 concurrent clients through the router, and fold the row the
/// multi-host table reports. A generous deadline arms the full routed
/// admission path (host-health-priced shedding) without tripping it on
/// healthy hosts.
fn multi_host_row(
    registry: &Arc<ModelRegistry>,
    obs: &Observation,
    variants: &[&str],
    hosts: usize,
    seq_len: usize,
    n_req: usize,
) -> MultiHostRow {
    use crate::coordinator::router::LocalCluster;
    use crate::coordinator::server::AdmissionControl;
    use crate::coordinator::{LatencyStats, RouterConfig};
    let serve_cfg = ServeConfig {
        workers: 2,
        shards: 0,
        max_batch: 8,
        max_wait: std::time::Duration::from_micros(300),
        admission: AdmissionControl::DeadlineAware { min_samples: 16 },
    };
    let router_cfg = RouterConfig {
        admission: AdmissionControl::DeadlineAware { min_samples: 16 },
        replicas: 1,
    };
    let cluster = LocalCluster::spawn(Arc::clone(registry), serve_cfg, hosts, router_cfg)
        .expect("spawn loopback cluster");
    let deadline = std::time::Duration::from_millis(50);
    let clients = 4usize;
    let per_client = n_req / clients;
    let ok = std::sync::atomic::AtomicU64::new(0);
    let sheds = std::sync::atomic::AtomicU64::new(0);
    let errors = std::sync::atomic::AtomicU64::new(0);
    let latency = std::sync::Mutex::new(LatencyStats::default());
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for c in 0..clients {
            let router = &cluster.router;
            let (ok, sheds, errors, latency) = (&ok, &sheds, &errors, &latency);
            s.spawn(move || {
                let wave = 8usize;
                let mut sent = 0usize;
                while sent < per_client {
                    let n = wave.min(per_client - sent);
                    let mut handles = Vec::with_capacity(n);
                    for k in 0..n {
                        let v = variants[(c + sent + k) % variants.len()];
                        let req = ServeRequest::new(obs.clone())
                            .with_variant(v)
                            .with_deadline(deadline);
                        match router.submit_async(req) {
                            Ok(h) => handles.push(h),
                            Err(crate::coordinator::ServeError::Overloaded { .. }) => {
                                sheds.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            }
                            Err(_) => {
                                errors.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            }
                        }
                    }
                    for h in handles {
                        match h.wait() {
                            Ok(rsp) => {
                                ok.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                                latency.lock().unwrap().record(rsp.latency());
                            }
                            Err(_) => {
                                errors.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            }
                        }
                    }
                    sent += n;
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let p = latency.lock().unwrap().percentiles_us(&[0.50, 0.99]);
    // Self-heal counters must be read before shutdown severs the slots.
    let redials = cluster.router.redials_total();
    let failovers = cluster.router.failovers_total();
    cluster.shutdown();
    let requests = per_client * clients;
    let responses_ok = ok.load(std::sync::atomic::Ordering::Relaxed);
    let shed_count = sheds.load(std::sync::atomic::Ordering::Relaxed);
    MultiHostRow {
        hosts,
        requests,
        responses_ok,
        sheds: shed_count,
        errors: errors.load(std::sync::atomic::Ordering::Relaxed),
        tok_s: responses_ok as f64 * seq_len as f64 / wall.max(1e-9),
        p50_us: p[0],
        p99_us: p[1],
        shed_rate: shed_count as f64 / requests.max(1) as f64,
        redials,
        failovers,
    }
}

/// Drive one router shape with 3-variant round-robin traffic from 4
/// concurrent clients (async waves, so submits from different variants
/// interleave in arrival order) and fold the row the mixed-traffic table
/// reports.
fn mixed_traffic_row(
    registry: &Arc<ModelRegistry>,
    obs: &Observation,
    variants: &[&str],
    mode: &str,
    workers: usize,
    shards: usize,
    n_req: usize,
) -> MixedTrafficRow {
    let server = PolicyServer::start(
        Arc::clone(registry),
        ServeConfig {
            workers,
            shards,
            max_batch: 8,
            max_wait: std::time::Duration::from_micros(300),
            ..Default::default()
        },
    );
    let clients = 4usize;
    let per_client = n_req / clients;
    let ok = std::sync::atomic::AtomicU64::new(0);
    std::thread::scope(|s| {
        for c in 0..clients {
            let server = &server;
            let ok = &ok;
            s.spawn(move || {
                let wave = 8usize;
                let mut sent = 0usize;
                while sent < per_client {
                    let n = wave.min(per_client - sent);
                    let handles: Vec<_> = (0..n)
                        .map(|k| {
                            let v = variants[(c + sent + k) % variants.len()];
                            server
                                .submit_async(ServeRequest::new(obs.clone()).with_variant(v))
                                .expect("mixed-traffic submit")
                        })
                        .collect();
                    for h in handles {
                        if h.wait().is_ok() {
                            ok.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        }
                    }
                    sent += n;
                }
            });
        }
    });
    let p = server.latency_stats().percentiles_us(&[0.50, 0.99]);
    let stolen_groups: u64 = server.shard_stats().iter().map(|s| s.stolen_groups).sum();
    let row = MixedTrafficRow {
        mode: mode.to_string(),
        workers,
        shards: server.n_shards(),
        requests: per_client * clients,
        responses_ok: ok.load(std::sync::atomic::Ordering::Relaxed),
        p50_us: p[0],
        p99_us: p[1],
        mean_batch: server.mean_batch_size(),
        mean_group: server.mean_group_size(),
        stolen_groups,
    };
    server.shutdown();
    row
}

/// Measure one batch size: trunk+decode tokens/s for the per-request loop
/// (`features` + `decode` per observation) vs the batched path
/// (`features_batch` + `decode_batch` over the coalesced group), on the
/// dense twin and on the packed commit.
fn batched_serve_row(
    dense_model: &MiniVla,
    packed_model: &MiniVla,
    obs: &Observation,
    batch: usize,
) -> BatchServeRow {
    let rounds = (48 / batch).max(3);
    let toks = (rounds * batch * dense_model.cfg.seq_len()) as f64;
    let measure = |model: &MiniVla, batched: bool| -> f64 {
        let t0 = Instant::now();
        for round in 0..rounds {
            if batched {
                let inputs: Vec<ObsInput> = (0..batch)
                    .map(|_| ObsInput {
                        visual_raw: &obs.visual_raw,
                        instr_id: obs.instr_id,
                        proprio: &obs.proprio,
                    })
                    .collect();
                let feats = model.features_batch(&inputs);
                let mut rngs: Vec<Rng> =
                    (0..batch).map(|r| Rng::with_stream(0xBA7C, (round * batch + r) as u64)).collect();
                std::hint::black_box(model.decode_batch(&feats, &mut rngs));
            } else {
                for r in 0..batch {
                    let f = model.features(&obs.visual_raw, obs.instr_id, &obs.proprio, &mut None);
                    let mut rng = Rng::with_stream(0xBA7C, (round * batch + r) as u64);
                    std::hint::black_box(model.decode(&f, &mut rng));
                }
            }
        }
        toks / t0.elapsed().as_secs_f64()
    };
    BatchServeRow {
        batch,
        dense_seq_tok_s: measure(dense_model, false),
        dense_batch_tok_s: measure(dense_model, true),
        packed_seq_tok_s: measure(packed_model, false),
        packed_batch_tok_s: measure(packed_model, true),
    }
}
