//! §Perf drivers: quantization throughput, packed-GEMV vs dense GEMV,
//! rollout throughput and serving latency — the measurements behind
//! EXPERIMENTS.md §Perf.

use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::rollout::{eval_tasks, ObsMode, RolloutConfig};
use crate::coordinator::scheduler::quantize_model;
use crate::coordinator::server::{PolicyServer, ServeConfig};
use crate::eval::harness::{build_testbed, paper_components};
use crate::methods::HbVla;
use crate::model::HeadKind;
use crate::quant::packed::PackedBits;
use crate::sim::observe::{observe, ObsParams};
use crate::sim::tasks::libero_suite;
use crate::tensor::matrix::Matrix;
use crate::tensor::ops::matvec;
use crate::util::rng::Rng;

pub struct PerfReport {
    pub quant_layers_per_sec: f64,
    pub quant_weights_per_sec: f64,
    pub rollout_eps_per_sec: f64,
    pub serve_p50_us: u64,
    pub serve_p99_us: u64,
    pub serve_qps: f64,
    pub packed_gemv_gflops: f64,
    pub dense_gemv_gflops: f64,
    pub packed_mem_ratio: f64,
}

impl PerfReport {
    pub fn render(&self) -> String {
        format!(
            "quantization: {:.1} layers/s ({:.2} Mweights/s)\n\
             rollout:      {:.1} episodes/s\n\
             serving:      p50={}us p99={}us throughput={:.0} req/s\n\
             packed GEMV:  {:.2} GFLOP/s (dense {:.2} GFLOP/s), memory ×{:.1} smaller",
            self.quant_layers_per_sec,
            self.quant_weights_per_sec / 1e6,
            self.rollout_eps_per_sec,
            self.serve_p50_us,
            self.serve_p99_us,
            self.serve_qps,
            self.packed_gemv_gflops,
            self.dense_gemv_gflops,
            self.packed_mem_ratio
        )
    }
}

pub fn run_perf(threads: usize, seed: u64) -> PerfReport {
    let tasks = libero_suite("object");
    let tb = build_testbed(HeadKind::Chunk, tasks.clone(), 32, seed);

    // --- PTQ throughput ---
    let t0 = Instant::now();
    let reps = 3;
    let mut total_layers = 0usize;
    let mut total_weights = 0usize;
    for _ in 0..reps {
        let (_, rep) = quantize_model(&tb.model, &tb.calib, &HbVla::new(), &paper_components(), threads);
        total_layers += rep.layers.len();
        total_weights += rep.stats.weights as usize;
    }
    let quant_secs = t0.elapsed().as_secs_f64();

    // --- rollout throughput ---
    let cfg = RolloutConfig { episodes_per_task: 6, mode: ObsMode::VisualMatching, seed, threads };
    let t1 = Instant::now();
    let r = eval_tasks(&tb.model, &tasks, &cfg);
    let rollout_secs = t1.elapsed().as_secs_f64();

    // --- serving latency/throughput ---
    let model = Arc::new(tb.model.clone());
    let server = PolicyServer::start(Arc::clone(&model), ServeConfig::default());
    let mut rng = Rng::with_stream(seed, 0x9F);
    let scene = tasks[0].instantiate(&mut rng);
    let obs = observe(&scene, tasks[0].stages[0].instr(), 100, &model, &ObsParams::clean(), &mut rng);
    let n_req = 400;
    let t2 = Instant::now();
    for _ in 0..n_req {
        let _ = server.submit(obs.clone());
    }
    let serve_secs = t2.elapsed().as_secs_f64();
    let stats = server.latency_stats();
    let (p50, p99) = (stats.p50_us(), stats.p99_us());
    server.shutdown();

    // --- packed vs dense GEMV ---
    let (rows, cols) = (512usize, 2048usize);
    let mut wr = Rng::with_stream(seed, 0x6E);
    let w = Matrix::gauss(rows, cols, 1.0, &mut wr);
    let x: Vec<f32> = (0..cols).map(|_| wr.gauss() as f32).collect();
    let packed = PackedBits::pack(&w, 128);
    let gsums = packed.group_sums(&x);
    let mut y = vec![0.0f32; rows];
    let iters = 200;
    let t3 = Instant::now();
    for _ in 0..iters {
        packed.matvec(&x, &gsums, &mut y);
    }
    let packed_secs = t3.elapsed().as_secs_f64();
    let t4 = Instant::now();
    for _ in 0..iters {
        matvec(&w, &x);
    }
    let dense_secs = t4.elapsed().as_secs_f64();
    let flops = 2.0 * rows as f64 * cols as f64 * iters as f64;

    PerfReport {
        quant_layers_per_sec: total_layers as f64 / quant_secs,
        quant_weights_per_sec: total_weights as f64 / quant_secs,
        rollout_eps_per_sec: r.episodes as f64 / rollout_secs,
        serve_p50_us: p50,
        serve_p99_us: p99,
        serve_qps: n_req as f64 / serve_secs,
        packed_gemv_gflops: flops / packed_secs / 1e9,
        dense_gemv_gflops: flops / dense_secs / 1e9,
        packed_mem_ratio: packed.compression_ratio(),
    }
}
