//! Ablation drivers (Tables 3 & 4 and extras).
//!
//! Both paper tables report a relative quantization-error metric (↓, %):
//! we use the Hessian-weighted relative reconstruction error
//! ‖(W−Ŵ)X‖²/‖WX‖² averaged over the quantized layers, evaluated under
//! the *standard* Hessian for fairness across variants.

use std::collections::HashMap;

use crate::eval::harness::{build_testbed, paper_components, Testbed};
use crate::eval::tables::EvalBudget;
use crate::methods::hbvla::{HaarHybridConfig, HbVla};
use crate::methods::traits::{Binarizer, CalibData};
use crate::model::HeadKind;
use crate::quant::hessian::relative_hessian_error;
use crate::quant::permute::NormKind;
use crate::report::Table;
use crate::sim::tasks::simpler_suite;

/// Mean relative H-weighted error (%) of quantizing the paper components
/// of `tb.model` with `method`.
pub fn mean_layer_error(tb: &Testbed, method: &dyn Binarizer) -> f64 {
    let comps = paper_components();
    let names = tb.model.store.quantizable_layers(Some(&comps));
    let mut total = 0.0;
    for name in &names {
        let w = tb.model.store.get(name);
        let cd = tb.calib.get(name).cloned().unwrap_or_else(|| {
            CalibData::identity(w.cols, tb.model.store.component_of(name))
        });
        let q = method.quantize(w, &cd);
        total += relative_hessian_error(w, &q.w_hat, &cd.hessian);
    }
    100.0 * total / names.len().max(1) as f64
}

fn two_setting_testbeds(budget: &EvalBudget) -> (Testbed, Testbed) {
    // Visual Matching vs Variant Aggregation differ in the *calibration
    // distribution* here: the VA testbed derives its Hessians from a model
    // seeded differently (scene/obs perturbations shift the activations).
    let tasks = simpler_suite();
    let vm = build_testbed(HeadKind::Diffusion, tasks.clone(), budget.n_demos, budget.seed);
    let va = build_testbed(HeadKind::Diffusion, tasks, budget.n_demos, budget.seed ^ 0xA66);
    (vm, va)
}

/// Table 3: permutation column-norm criterion, ℓ1 vs ℓ2 (error ↓ %).
pub fn table3_permutation(budget: &EvalBudget) -> Table {
    let (vm, va) = two_setting_testbeds(budget);
    let mut t = Table::new(
        "Table 3 — non-salient column permutation criterion (error ↓, %)",
        &["Visual Matching", "Variant Aggregation"],
    );
    t.decimals = 2;
    for (label, norm) in [("l1", NormKind::L1), ("l2", NormKind::L2)] {
        let m = HbVla::with_config(HaarHybridConfig { norm, ..HaarHybridConfig::hbvla() }, "cfg");
        t.add_row(label, vec![mean_layer_error(&vm, &m) / 100.0, mean_layer_error(&va, &m) / 100.0]);
    }
    t
}

/// Table 4: Hessian formulation, standard vs policy-aware (error ↓ %),
/// evaluated under the rectified Hessian objective (what the policy-aware
/// selection optimizes; see the paper's Eq. 3 discussion).
pub fn table4_hessian(budget: &EvalBudget) -> Table {
    let (vm, va) = two_setting_testbeds(budget);
    let mut t = Table::new(
        "Table 4 — Hessian formulation (error ↓, %)",
        &["Visual Matching", "Variant Aggregation"],
    );
    t.decimals = 2;
    let err_under_rect = |tb: &Testbed, policy_aware: bool| -> f64 {
        let m = HbVla::with_config(
            HaarHybridConfig { policy_aware, ..HaarHybridConfig::hbvla() },
            "cfg",
        );
        let comps = paper_components();
        let names = tb.model.store.quantizable_layers(Some(&comps));
        let mut total = 0.0;
        for name in &names {
            let w = tb.model.store.get(name);
            let cd = tb.calib.get(name).cloned().unwrap_or_else(|| {
                CalibData::identity(w.cols, tb.model.store.component_of(name))
            });
            let q = m.quantize(w, &cd);
            let h_eval = cd.hessian_rect.as_ref().unwrap_or(&cd.hessian);
            total += relative_hessian_error(w, &q.w_hat, h_eval);
        }
        total / names.len().max(1) as f64
    };
    t.add_row("Standard", vec![err_under_rect(&vm, false), err_under_rect(&va, false)]);
    t.add_row("Policy-Aware", vec![err_under_rect(&vm, true), err_under_rect(&va, true)]);
    t
}

/// Extra ablation (DESIGN.md §4): OBQ/Eq-28 compensation vs the Fig-2
/// transform pipeline, on the same testbed. Returns (transform, obq)
/// mean relative errors (%).
pub fn ablation_obq(budget: &EvalBudget) -> (f64, f64) {
    let tasks = simpler_suite();
    let tb = build_testbed(HeadKind::Diffusion, tasks, budget.n_demos, budget.seed);
    let transform = mean_layer_error(&tb, &HbVla::new());
    // OBQ path: per-column residual/plain binarization swept with Eq-28
    // compensation under the rectified Hessian.
    let comps = paper_components();
    let names = tb.model.store.quantizable_layers(Some(&comps));
    let mut total = 0.0;
    for name in &names {
        let w = tb.model.store.get(name);
        let cd = &tb.calib[name];
        let h = cd.hessian_rect.as_ref().unwrap_or(&cd.hessian);
        let part = crate::quant::saliency::select_salient(w, &cd.diag(true), 40.min(w.cols / 2));
        let sal = {
            let mut s = vec![false; w.cols];
            for &j in &part.salient {
                s[j] = true;
            }
            s
        };
        let q = crate::quant::obq::obq_sweep(w, h, |j, col| {
            if sal[j] {
                crate::quant::obq::residual_binarize_col(col)
            } else {
                crate::quant::obq::binarize_col(col)
            }
        });
        total += relative_hessian_error(w, &q, &cd.hessian);
    }
    let obq = 100.0 * total / names.len().max(1) as f64;
    (transform, obq)
}

/// Map of per-layer errors for every method (used by reports/benches).
pub fn per_method_layer_errors(tb: &Testbed) -> HashMap<String, f64> {
    let mut out = HashMap::new();
    for method in crate::methods::paper_methods() {
        out.insert(method.name().to_string(), mean_layer_error(tb, method.as_ref()));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::tasks::libero_suite;

    fn tiny_testbed() -> Testbed {
        build_testbed(HeadKind::Chunk, libero_suite("object"), 8, 5)
    }

    #[test]
    fn l2_beats_l1_criterion() {
        // Table 3's finding on a small testbed.
        let tb = tiny_testbed();
        let l2 = mean_layer_error(
            &tb,
            &HbVla::with_config(HaarHybridConfig { norm: NormKind::L2, ..HaarHybridConfig::hbvla() }, "l2"),
        );
        let l1 = mean_layer_error(
            &tb,
            &HbVla::with_config(HaarHybridConfig { norm: NormKind::L1, ..HaarHybridConfig::hbvla() }, "l1"),
        );
        assert!(l2 <= l1 * 1.1, "l2={l2} l1={l1}");
    }

    #[test]
    fn policy_aware_wins_on_rect_objective() {
        // Table 4's finding: the rectified-Hessian selection reduces the
        // policy-weighted error.
        let tb = tiny_testbed();
        let comps = paper_components();
        let names = tb.model.store.quantizable_layers(Some(&comps));
        let err = |pa: bool| -> f64 {
            let m = HbVla::with_config(HaarHybridConfig { policy_aware: pa, ..HaarHybridConfig::hbvla() }, "x");
            names
                .iter()
                .map(|name| {
                    let w = tb.model.store.get(name);
                    let cd = &tb.calib[name];
                    let q = m.quantize(w, cd);
                    let h = cd.hessian_rect.as_ref().unwrap_or(&cd.hessian);
                    relative_hessian_error(w, &q.w_hat, h)
                })
                .sum()
        };
        assert!(err(true) <= err(false) * 1.05, "{} vs {}", err(true), err(false));
    }
}
