//! One-level Haar wavelet transform (paper Appendix, Eqs. 34–48).
//!
//! The transform is the pairwise "average and difference" map implemented
//! as two fixed stride-2 kernels h_lo = [1/2, 1/2], h_hi = [1/2, −1/2],
//! exactly the convention HBLLM and this paper use (note: *not* the
//! orthonormal 1/√2 scaling; the inverse is the exact pairwise
//! reconstruction w_{2k} = lo + hi, w_{2k+1} = lo − hi).
//!
//! Layout: the transformed row is the concatenation [lo | hi] with
//! J = ⌈m/2⌉ low-pass then high-pass coefficients. Odd lengths are handled
//! by carrying the leftover sample in the low-pass band with a zero
//! high-pass partner (equivalent to padding with a duplicate, noted in the
//! paper's "Odd m" remark).

use crate::tensor::matrix::Matrix;

/// Number of low-pass coefficients for signal length m.
#[inline]
pub fn half_len(m: usize) -> usize {
    m.div_ceil(2)
}

/// One-level Haar analysis of a single row: returns [lo | hi].
pub fn haar_fwd_vec(w: &[f32]) -> Vec<f32> {
    let m = w.len();
    let j = half_len(m);
    let mut out = vec![0.0f32; 2 * j];
    for k in 0..m / 2 {
        let a = w[2 * k];
        let b = w[2 * k + 1];
        out[k] = 0.5 * (a + b);
        out[j + k] = 0.5 * (a - b);
    }
    if m % 2 == 1 {
        // Leftover sample: lo = value, hi = 0 → inverse reproduces exactly.
        out[j - 1] = w[m - 1];
        out[2 * j - 1] = 0.0;
    }
    out
}

/// One-level Haar synthesis: input [lo | hi] of length 2·⌈m/2⌉, original
/// length `m` must be supplied to undo odd-length handling.
pub fn haar_inv_vec(c: &[f32], m: usize) -> Vec<f32> {
    let j = half_len(m);
    assert_eq!(c.len(), 2 * j, "coefficient length mismatch");
    let mut w = vec![0.0f32; m];
    for k in 0..m / 2 {
        let lo = c[k];
        let hi = c[j + k];
        w[2 * k] = lo + hi;
        w[2 * k + 1] = lo - hi;
    }
    if m % 2 == 1 {
        w[m - 1] = c[j - 1];
    }
    w
}

/// Activation-side forward for transform-domain serving: apply the
/// *synthesis* matrix B (the map [`haar_inv_vec`] realizes on
/// coefficients) to an activation vector x of original length m.
///
/// Why B and not the analysis kernels: the committed Haar-domain weights C
/// reconstruct as Ŵ = C·B (each row synthesized by [`haar_inv_vec`]), so
/// Ŵ·x = C·(B·x) — serving the bitplanes exactly needs z = B·x on the
/// activation, which is the *unnormalized* pairwise sum/difference
///   z_k = x_{2k} + x_{2k+1},   z_{J+k} = x_{2k} − x_{2k+1}
/// (2× the [`haar_fwd_vec`] pairs), with an odd leftover carried at
/// weight 1: z_{J−1} = x_{m−1}, z_{2J−1} = 0. The defining identity
/// ⟨haar_act_fwd_vec(x), c⟩ = ⟨x, haar_inv_vec(c, m)⟩ is pinned in tests
/// (unit + proptests).
pub fn haar_act_fwd_vec(x: &[f32]) -> Vec<f32> {
    let m = x.len();
    let j = half_len(m);
    let mut out = vec![0.0f32; 2 * j];
    haar_act_fwd_into(x, &mut out);
    out
}

/// In-place form of [`haar_act_fwd_vec`]: writes z = B·x into `out`
/// (length 2·⌈m/2⌉). The hot-loop form — the serving path fuses this with
/// the permuted gather and, under W1A8, the activation-scale sweep.
#[inline]
pub fn haar_act_fwd_into(x: &[f32], out: &mut [f32]) {
    let m = x.len();
    let j = half_len(m);
    debug_assert_eq!(out.len(), 2 * j);
    for k in 0..m / 2 {
        let a = x[2 * k];
        let b = x[2 * k + 1];
        out[k] = a + b;
        out[j + k] = a - b;
    }
    if m % 2 == 1 {
        out[j - 1] = x[m - 1];
        out[2 * j - 1] = 0.0;
    }
}

/// Row-wise Haar (Eq. 46): transform each row of W along the column axis.
/// Output shape: rows × 2·⌈cols/2⌉.
pub fn haar_rows(w: &Matrix) -> Matrix {
    let j2 = 2 * half_len(w.cols);
    let mut out = Matrix::zeros(w.rows, j2);
    for i in 0..w.rows {
        let t = haar_fwd_vec(w.row(i));
        out.row_mut(i).copy_from_slice(&t);
    }
    out
}

/// Inverse of [`haar_rows`]; `cols` is the original column count.
pub fn haar_rows_inv(c: &Matrix, cols: usize) -> Matrix {
    let mut out = Matrix::zeros(c.rows, cols);
    for i in 0..c.rows {
        let w = haar_inv_vec(c.row(i), cols);
        out.row_mut(i).copy_from_slice(&w);
    }
    out
}

/// Column-wise Haar (Eq. 47): Hᵀ_d W — pairwise average/difference of
/// adjacent *rows* per column. Implemented via transposition (Eq. 48).
pub fn haar_cols(w: &Matrix) -> Matrix {
    haar_rows(&w.transpose()).transpose()
}

/// Inverse of [`haar_cols`]; `rows` is the original row count.
pub fn haar_cols_inv(c: &Matrix, rows: usize) -> Matrix {
    haar_rows_inv(&c.transpose(), rows).transpose()
}

/// High-pass energy of a row-wise transform: ‖W H_hi‖²_F. By the identity
/// of Eq. 14 this equals ¼ Σ_k ‖W(:,2k-1) − W(:,2k)‖² — verified in tests.
pub fn highpass_energy(w: &Matrix) -> f64 {
    let t = haar_rows(w);
    let j = half_len(w.cols);
    let mut e = 0.0f64;
    for i in 0..t.rows {
        for k in j..2 * j {
            let v = t.at(i, k) as f64;
            e += v * v;
        }
    }
    e
}

/// Direct evaluation of the pairwise-difference identity (Eq. 14) for a
/// given column ordering π over W's columns: ¼ Σ ‖w_{π(2k-1)} − w_{π(2k)}‖².
pub fn pairwise_highpass_energy(w: &Matrix, perm: &[usize]) -> f64 {
    let mut e = 0.0f64;
    let mut k = 0;
    while k + 1 < perm.len() {
        let (a, b) = (perm[k], perm[k + 1]);
        let mut d2 = 0.0f64;
        for i in 0..w.rows {
            let d = (w.at(i, a) - w.at(i, b)) as f64;
            d2 += d * d;
        }
        e += d2;
        k += 2;
    }
    0.25 * e
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn fwd_matches_closed_form() {
        // Eq. 39-40: lo = (a+b)/2, hi = (a-b)/2.
        let w = [4.0f32, 2.0, -1.0, 3.0];
        let c = haar_fwd_vec(&w);
        assert_eq!(c, vec![3.0, 1.0, 1.0, -2.0]);
    }

    #[test]
    fn roundtrip_even() {
        let mut rng = Rng::new(31);
        for m in [2usize, 8, 64, 128] {
            let w: Vec<f32> = (0..m).map(|_| rng.gauss() as f32).collect();
            let c = haar_fwd_vec(&w);
            let r = haar_inv_vec(&c, m);
            for (a, b) in w.iter().zip(&r) {
                assert!((a - b).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn roundtrip_odd() {
        let mut rng = Rng::new(32);
        for m in [1usize, 3, 7, 65] {
            let w: Vec<f32> = (0..m).map(|_| rng.gauss() as f32).collect();
            let c = haar_fwd_vec(&w);
            assert_eq!(c.len(), 2 * half_len(m));
            let r = haar_inv_vec(&c, m);
            for (a, b) in w.iter().zip(&r) {
                assert!((a - b).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn matrix_row_roundtrip() {
        let mut rng = Rng::new(33);
        for cols in [6usize, 7, 128] {
            let w = Matrix::gauss(9, cols, 1.5, &mut rng);
            let c = haar_rows(&w);
            let r = haar_rows_inv(&c, cols);
            assert!(w.dist_sq(&r) < 1e-8);
        }
    }

    #[test]
    fn matrix_col_roundtrip() {
        let mut rng = Rng::new(34);
        for rows in [6usize, 9, 32] {
            let w = Matrix::gauss(rows, 11, 1.5, &mut rng);
            let c = haar_cols(&w);
            let r = haar_cols_inv(&c, rows);
            assert!(w.dist_sq(&r) < 1e-8);
        }
    }

    #[test]
    fn col_equals_transposed_row() {
        // Eq. 48: H_col(W) = (H_row(Wᵀ))ᵀ
        let mut rng = Rng::new(35);
        let w = Matrix::gauss(8, 5, 1.0, &mut rng);
        let a = haar_cols(&w);
        let b = haar_rows(&w.transpose()).transpose();
        assert!(a.dist_sq(&b) < 1e-10);
    }

    #[test]
    fn act_fwd_is_adjoint_of_synthesis() {
        // ⟨B·x, c⟩ = ⟨x, haar_inv(c)⟩ for every (x, c) — the identity that
        // makes transform-domain serving exact: Ŵx = C·(B·x).
        let mut rng = Rng::new(37);
        for m in [1usize, 2, 5, 7, 64, 65, 70, 128] {
            let x: Vec<f32> = (0..m).map(|_| rng.gauss() as f32).collect();
            let j = half_len(m);
            let c: Vec<f32> = (0..2 * j).map(|_| rng.gauss() as f32).collect();
            let z = haar_act_fwd_vec(&x);
            let w = haar_inv_vec(&c, m);
            let lhs: f64 = z.iter().zip(&c).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
            let rhs: f64 = x.iter().zip(&w).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
            assert!((lhs - rhs).abs() < 1e-4 * (1.0 + lhs.abs()), "m={m}: {lhs} vs {rhs}");
        }
    }

    #[test]
    fn act_fwd_doubles_fwd_pairs_and_carries_odd_tail_unscaled() {
        // Even pairs: B·x = 2·haar_fwd(x); the odd leftover is carried at
        // weight 1 (matching the synthesis w_{m−1} = c_{J−1}, NOT 2×).
        let x = [4.0f32, 2.0, -1.0, 3.0, 5.0];
        let z = haar_act_fwd_vec(&x);
        let f = haar_fwd_vec(&x);
        let j = half_len(x.len());
        for k in 0..x.len() / 2 {
            assert_eq!(z[k], 2.0 * f[k]);
            assert_eq!(z[j + k], 2.0 * f[j + k]);
        }
        assert_eq!(z[j - 1], 5.0);
        assert_eq!(z[2 * j - 1], 0.0);
    }

    #[test]
    fn highpass_identity_eq14() {
        let mut rng = Rng::new(36);
        let w = Matrix::gauss(16, 20, 1.0, &mut rng);
        let id: Vec<usize> = (0..20).collect();
        let direct = highpass_energy(&w);
        let pairwise = pairwise_highpass_energy(&w, &id);
        assert!((direct - pairwise).abs() < 1e-6 * (1.0 + direct.abs()));
    }

    #[test]
    fn constant_signal_has_zero_highpass() {
        let w = Matrix::filled(4, 10, 3.0);
        assert!(highpass_energy(&w) < 1e-12);
    }

    #[test]
    fn smooth_signal_energy_compacts_to_lowpass() {
        // Haar on a slowly varying signal puts most energy in the low band.
        let m = 64;
        let w = Matrix::from_fn(1, m, |_, j| (j as f32 / m as f32 * 3.0).sin());
        let hi = highpass_energy(&w);
        let total = w.frob_norm_sq();
        assert!(hi / total < 0.01, "hi/total = {}", hi / total);
    }
}
