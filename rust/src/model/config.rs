//! MiniVLA configuration.
//!
//! MiniVLA mirrors the component inventory of the paper's subject models
//! (OpenVLA / OpenVLA-OFT / CogACT): a vision encoder over visual tokens,
//! a projector into the language-model width, a causal-attention language
//! trunk consuming [visual | instruction | proprio] tokens, and one of
//! three action heads. Sizes are laptop-scale by design (DESIGN.md §1);
//! the *structure* (layer types, modality interleaving, salient activation
//! columns) is what the quantizers see, and is faithful.

use crate::quant::packed::{ActPrecision, ActScaleMode, AttnPrecision};

/// Which committed deploy form a quantized variant's store holds — a
/// descriptive policy record (the per-layer [`crate::model::params::WeightRepr`]
/// is the execution truth), carried so registries, telemetry and the serve
/// demo can report what a variant executes without inspecting layers.
/// Like [`ActPrecision`], this is NOT part of the serving interface:
/// `hbvla-packed` and `hbvla-exact` stay [`VlaConfig::serve_compatible`]
/// behind one endpoint.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum DeployRepr {
    /// Residual-bitplane re-pack of the method's reconstruction
    /// (approximate to the deploy tolerance) — or a dense/FP store.
    #[default]
    Repacked,
    /// Transform-domain exact serving: the committed Haar-domain plane
    /// executes as y = C·haar(Pᵀx), zero residual planes.
    TransformExact,
}

impl DeployRepr {
    pub fn label(&self) -> &'static str {
        match self {
            DeployRepr::Repacked => "repacked",
            DeployRepr::TransformExact => "transform-exact",
        }
    }
}

/// Which action decoder the policy uses — the axis distinguishing
/// OpenVLA / OpenVLA-OFT / CogACT in the paper's tables.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HeadKind {
    /// OpenVLA-style: per-dimension discretized action tokens (argmax over
    /// bins).
    Token,
    /// OpenVLA-OFT-style: continuous action-chunk regression.
    Chunk,
    /// CogACT-style: DDIM-like iterative denoising action decoder.
    Diffusion,
}

impl HeadKind {
    pub fn model_name(&self) -> &'static str {
        match self {
            HeadKind::Token => "OpenVLA-mini",
            HeadKind::Chunk => "OpenVLA-OFT-mini",
            HeadKind::Diffusion => "CogACT-mini",
        }
    }
}

#[derive(Clone, Debug)]
pub struct VlaConfig {
    /// Vision encoder width.
    pub d_vision: usize,
    /// Vision encoder blocks.
    pub vision_blocks: usize,
    /// Language-model width (also the projector output).
    pub d_model: usize,
    /// Language trunk blocks.
    pub lm_blocks: usize,
    /// Attention heads (both encoders).
    pub heads: usize,
    /// MLP hidden width multiplier (hidden = mult × width).
    pub mlp_mult: usize,
    /// Raw visual-token feature dim (from the sim featurizer).
    pub d_vis_in: usize,
    /// Number of visual tokens (object slots + clutter slots).
    pub n_visual: usize,
    /// Instruction vocabulary size.
    pub vocab: usize,
    /// Raw proprio feature dim.
    pub d_proprio: usize,
    /// Action dimensionality (dx, dy, grip).
    pub act_dim: usize,
    /// Chunk length for the Chunk head.
    pub chunk: usize,
    /// Bins per action dim for the Token head.
    pub bins: usize,
    /// Denoising steps for the Diffusion head.
    pub diffusion_steps: usize,
    /// Hidden units of the action head's fixed tanh expansion (the
    /// "action MLP" — real VLA heads are nonlinear).
    pub head_hidden: usize,
    /// Action head kind.
    pub head: HeadKind,
    /// Weight-structure seed.
    pub seed: u64,
    /// Activation precision the packed layers execute at (W1A32 vs W1A8).
    /// A runtime policy, not an interface property: variants differing
    /// only here stay [`Self::serve_compatible`] — that is what lets one
    /// endpoint A/B `rtn-packed` against `rtn-packed-a8` per request.
    /// The kernel dispatch reads the `ParamStore`'s copy of this policy,
    /// seeded from here at construction; change both through
    /// [`crate::model::MiniVla::with_act_precision`], never this field
    /// alone on a built model.
    pub act_precision: ActPrecision,
    /// How the W1A8 kernels obtain activation scales (per-token dynamic
    /// vs calibrated static — see [`ActScaleMode`]). Runtime policy like
    /// [`Self::act_precision`]: variants differing only here stay
    /// [`Self::serve_compatible`]. The dispatch reads the `ParamStore`'s
    /// copy, seeded from here at construction; change both through
    /// [`crate::model::MiniVla::with_act_scale_mode`].
    pub act_scale_mode: ActScaleMode,
    /// Precision of the attention core (f32 vs per-token INT8 scores +
    /// context GEMM — see [`AttnPrecision`]). Runtime policy like
    /// [`Self::act_precision`]: variants differing only here stay
    /// [`Self::serve_compatible`]. Follows the activation precision
    /// through [`crate::model::MiniVla::with_act_precision`] (so `*-a8`
    /// variants inherit INT8 attention) and is overridden independently
    /// via [`crate::model::MiniVla::with_attn_precision`].
    pub attn_precision: AttnPrecision,
    /// Deploy-form policy record (see [`DeployRepr`]): which committed
    /// representation the store's quantized layers hold. Descriptive, not
    /// an interface property.
    pub deploy_repr: DeployRepr,
}

impl VlaConfig {
    /// The default evaluation-scale model (≈0.9 M parameters).
    pub fn base(head: HeadKind) -> Self {
        VlaConfig {
            d_vision: 48,
            vision_blocks: 2,
            d_model: 64,
            lm_blocks: 3,
            heads: 4,
            mlp_mult: 2,
            d_vis_in: 24,
            n_visual: 10,
            vocab: 64,
            d_proprio: 12,
            act_dim: 3,
            chunk: 4,
            bins: 32,
            diffusion_steps: 6,
            head_hidden: 96,
            head: HeadKind::Chunk,
            seed: 0xBEEF,
            act_precision: ActPrecision::F32,
            act_scale_mode: ActScaleMode::PerToken,
            attn_precision: AttnPrecision::F32,
            deploy_repr: DeployRepr::Repacked,
        }
        .with_head(head)
    }

    /// Small config for unit tests (fast).
    pub fn tiny(head: HeadKind) -> Self {
        VlaConfig {
            d_vision: 24, // must be ≥ channels::APPEAR_START (20)
            vision_blocks: 1,
            d_model: 32,
            lm_blocks: 2,
            heads: 2,
            mlp_mult: 2,
            d_vis_in: 16, // ≥ channels::RAW_APPEAR_START (12) + some appearance

            n_visual: 6,
            vocab: 64,
            d_proprio: 12,
            act_dim: 3,
            chunk: 2,
            bins: 32,
            diffusion_steps: 4,
            head_hidden: 48,
            head: HeadKind::Chunk,
            seed: 7,
            act_precision: ActPrecision::F32,
            act_scale_mode: ActScaleMode::PerToken,
            attn_precision: AttnPrecision::F32,
            deploy_repr: DeployRepr::Repacked,
        }
        .with_head(head)
    }

    pub fn with_head(mut self, head: HeadKind) -> Self {
        self.head = head;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_act_precision(mut self, p: ActPrecision) -> Self {
        self.act_precision = p;
        self
    }

    pub fn with_act_scale_mode(mut self, m: ActScaleMode) -> Self {
        self.act_scale_mode = m;
        self
    }

    pub fn with_attn_precision(mut self, p: AttnPrecision) -> Self {
        self.attn_precision = p;
        self
    }

    pub fn with_deploy_repr(mut self, r: DeployRepr) -> Self {
        self.deploy_repr = r;
        self
    }

    pub fn mlp_hidden(&self, width: usize) -> usize {
        self.mlp_mult * width
    }

    /// Whether two configs can serve behind one endpoint: the request /
    /// response interface (observation dims, vocabulary, action shape)
    /// must match; internal widths and seeds may differ. This is the
    /// compatibility contract [`crate::coordinator::ModelRegistry`]
    /// enforces across its variants.
    pub fn serve_compatible(&self, other: &VlaConfig) -> bool {
        self.d_vis_in == other.d_vis_in
            && self.n_visual == other.n_visual
            && self.vocab == other.vocab
            && self.d_proprio == other.d_proprio
            && self.act_dim == other.act_dim
            && self.head == other.head
            && self.chunk == other.chunk
            && self.bins == other.bins
            && self.diffusion_steps == other.diffusion_steps
    }

    /// Sequence length the language trunk sees:
    /// visual tokens + 1 instruction token + 1 proprio token.
    pub fn seq_len(&self) -> usize {
        self.n_visual + 2
    }

    /// Readout feature dim: LM output at the instruction token ⊕ raw
    /// proprio ⊕ held-gated copy of both (lets a linear head realize the
    /// grasp/transport mode switch).
    pub fn feat_dim(&self) -> usize {
        2 * (self.d_model + self.d_proprio)
    }

    /// Head-input dim after the fixed tanh expansion.
    pub fn head_in_dim(&self) -> usize {
        self.feat_dim() + self.head_hidden
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn base_config_consistent() {
        let c = VlaConfig::base(HeadKind::Chunk);
        assert_eq!(c.d_model % c.heads, 0);
        assert_eq!(c.d_vision % c.heads, 0);
        assert_eq!(c.seq_len(), c.n_visual + 2);
        assert_eq!(c.feat_dim(), 2 * (c.d_model + c.d_proprio));
    }

    #[test]
    fn head_names() {
        assert_eq!(HeadKind::Token.model_name(), "OpenVLA-mini");
        assert_eq!(HeadKind::Chunk.model_name(), "OpenVLA-OFT-mini");
        assert_eq!(HeadKind::Diffusion.model_name(), "CogACT-mini");
    }

    #[test]
    fn tiny_is_smaller() {
        let t = VlaConfig::tiny(HeadKind::Token);
        let b = VlaConfig::base(HeadKind::Token);
        assert!(t.d_model < b.d_model);
        assert_eq!(t.head, HeadKind::Token);
    }

    #[test]
    fn act_precision_does_not_change_serving_interface() {
        let a = VlaConfig::tiny(HeadKind::Chunk);
        let b = a.clone().with_act_precision(ActPrecision::Int8);
        assert_eq!(a.act_precision, ActPrecision::F32);
        assert_eq!(b.act_precision, ActPrecision::Int8);
        // W1A32 and W1A8 twins can serve behind one endpoint.
        assert!(a.serve_compatible(&b));
        assert!(b.serve_compatible(&a));
    }

    #[test]
    fn attn_precision_does_not_change_serving_interface() {
        let a = VlaConfig::tiny(HeadKind::Chunk);
        let b = a.clone().with_attn_precision(AttnPrecision::Int8);
        assert_eq!(a.attn_precision, AttnPrecision::F32);
        assert_eq!(b.attn_precision, AttnPrecision::Int8);
        // f32-attention and i8-attention twins share one endpoint.
        assert!(a.serve_compatible(&b));
        assert!(b.serve_compatible(&a));
    }

    #[test]
    fn deploy_repr_is_policy_not_interface() {
        let a = VlaConfig::tiny(HeadKind::Chunk);
        let b = a.clone().with_deploy_repr(DeployRepr::TransformExact);
        assert_eq!(a.deploy_repr, DeployRepr::Repacked);
        assert_eq!(b.deploy_repr, DeployRepr::TransformExact);
        assert_eq!(b.deploy_repr.label(), "transform-exact");
        // Repacked and transform-exact variants share one endpoint.
        assert!(a.serve_compatible(&b));
        assert!(b.serve_compatible(&a));
    }
}
