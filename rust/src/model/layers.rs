//! Transformer layer forwards operating directly on the [`ParamStore`]
//! (so PTQ weight swaps take effect with no model rebuild) with an
//! optional activation hook for Hessian calibration capture.
//!
//! Every weight product goes through the [`linear`] / [`linear_vec`]
//! dispatch, which executes on whatever [`WeightRepr`] the store holds for
//! that layer: dense f32 GEMM for FP weights, the packed 1-bit GEMM of
//! [`crate::quant::packed::PackedBits`] for quantized ones. This is the
//! single seam that makes packed execution the real inference path
//! (serve, rollout, eval) rather than a microbenchmark
//! (DESIGN.md §Hardware-Adaptation).
//!
//! Block structure (both encoders): Φ_attn(X) = X + MHSA(X) followed by
//! Φ_mlp(X) = X + W₂·gelu(W₁·X), each followed by a column RMS-norm.
//! The attention math mirrors `quant::probe::AttnBlock` (finite-diff
//! verified there); a parity test pins the two implementations together.

use crate::model::params::{ParamStore, WeightRepr};
use crate::quant::packed::ActPrecision;
use crate::tensor::matrix::Matrix;
use crate::tensor::ops::{gelu, matmul, matmul_mt, matvec, softmax_rows};

/// Activation hook: called with (layer_name, layer_input) right before
/// each quantizable matmul. Inputs are d_in × n_tokens.
pub type Hook<'a> = &'a mut dyn FnMut(&str, &Matrix);

/// Y = W · X through the layer's stored representation: dense GEMM for FP
/// layers; for packed 1-bit layers the store's
/// [`ActPrecision`] picks the kernel — f32 packed GEMM (W1A32)
/// or the integer-inner-loop i8 GEMM (W1A8). This is the single
/// quantizable-matmul dispatch point, so every execution path (serving,
/// rollouts, eval drivers) inherits the activation precision with no
/// call-site changes.
pub fn linear(store: &ParamStore, name: &str, x: &Matrix) -> Matrix {
    let threads = store.exec_threads();
    match store.repr(name) {
        // Dense layers thread under the same budget (threshold inside
        // matmul_mt), so dense-vs-packed comparisons measure kernels,
        // not a threading asymmetry.
        WeightRepr::Dense(w) => matmul_mt(w, x, threads),
        // Packed GEMMs fan rows over the persistent pool when the
        // problem crosses the work threshold (bit-identical at every
        // thread count), honoring the store's pinned thread budget;
        // under W1A8 + ActScaleMode::Static the store supplies the
        // calibrated per-layer scale and the max sweeps are skipped.
        WeightRepr::Packed(p) => match store.act_precision() {
            ActPrecision::F32 => p.matmul_mt(x, threads),
            ActPrecision::Int8 => {
                p.matmul_i8_with_scale(x, threads, store.active_static_scale(name))
            }
        },
        // Transform-domain exact serving: per-token gather+Haar on the
        // activations, then the same packed GEMM against the committed
        // Haar-domain plane (+ salient side-channel). Static scales for
        // these layers are calibrated over the TRANSFORMED z.
        WeightRepr::TransformPacked(t) => match store.act_precision() {
            ActPrecision::F32 => t.matmul_mt(x, threads),
            ActPrecision::Int8 => {
                t.matmul_i8_scaled_mt(x, store.active_static_scale(name), threads)
            }
        },
    }
}

/// y = W · x (single-token GEMV form of [`linear`], same per-token kernel
/// under both activation precisions; large layers row-parallelize over
/// the pool, bit-identically, within the store's thread budget).
pub fn linear_vec(store: &ParamStore, name: &str, x: &[f32]) -> Vec<f32> {
    let threads = store.exec_threads();
    match store.repr(name) {
        WeightRepr::Dense(w) => matvec(w, x),
        WeightRepr::Packed(p) => match store.act_precision() {
            ActPrecision::F32 => p.matvec_owned_mt(x, None, threads),
            ActPrecision::Int8 => {
                p.matvec_i8_owned_mt(x, store.active_static_scale(name), threads)
            }
        },
        WeightRepr::TransformPacked(t) => match store.act_precision() {
            ActPrecision::F32 => t.matvec_owned_mt(x, threads),
            ActPrecision::Int8 => {
                t.matvec_i8_owned_mt(x, store.active_static_scale(name), threads)
            }
        },
    }
}

/// RMS-normalize each column (token) toward unit RMS, with a *floor*:
/// near-silent tokens (padding slots) are left small instead of being
/// blown up into random unit vectors that would pollute attention.
pub fn rmsnorm_cols(m: &mut Matrix) {
    let d = m.rows as f32;
    for t in 0..m.cols {
        let mut ss = 0.0f32;
        for i in 0..m.rows {
            let v = m.at(i, t);
            ss += v * v;
        }
        let inv = 1.0 / (ss / d + 0.05).sqrt();
        for i in 0..m.rows {
            *m.at_mut(i, t) *= inv;
        }
    }
}

/// Multi-head self-attention sub-layer: returns X + MHSA(X). The
/// single-request form of [`attn_forward_seg`] (one segment spanning all
/// columns), so single and batched serving share one kernel by
/// construction.
pub fn attn_forward(
    store: &ParamStore,
    prefix: &str,
    heads: usize,
    x: &Matrix,
    hook: &mut Option<Hook>,
) -> Matrix {
    attn_forward_seg(store, prefix, heads, x, x.cols, hook)
}

/// Segmented multi-head self-attention: `x` holds the column-concatenated
/// token sequences of `x.cols / seg` independent requests, each `seg`
/// columns wide. The Q/K/V/O projections run ONCE over the whole
/// concatenation — on packed layers this is the multi-token packed GEMM
/// amortizing sign-word traffic across every coalesced request — while
/// scores/softmax/context stay local to each segment, so tokens never
/// attend across requests. Per request the result is bit-identical to
/// [`attn_forward`] on that request alone: every linear kernel computes
/// output columns independently and in the same operation order.
pub fn attn_forward_seg(
    store: &ParamStore,
    prefix: &str,
    heads: usize,
    x: &Matrix,
    seg: usize,
    hook: &mut Option<Hook>,
) -> Matrix {
    assert!(seg > 0 && x.cols % seg == 0, "ragged batch: {} cols, segment {}", x.cols, seg);
    let nq = format!("{prefix}.wq");
    let nk = format!("{prefix}.wk");
    let nv = format!("{prefix}.wv");
    let no = format!("{prefix}.wo");
    if let Some(h) = hook {
        h(&nq, x);
        h(&nk, x);
        h(&nv, x);
    }
    let d = store.dims(&nq).0;
    let dh = d / heads;
    let q = linear(store, &nq, x);
    let k = linear(store, &nk, x);
    let v = linear(store, &nv, x);
    let scale = 1.0 / (dh as f32).sqrt();
    let mut ctx = Matrix::zeros(d, x.cols);
    for h in 0..heads {
        let r0 = h * dh;
        let r1 = r0 + dh;
        let qh_all = q.slice_rows(r0, r1);
        let kh_all = k.slice_rows(r0, r1);
        let vh_all = v.slice_rows(r0, r1);
        for s0 in (0..x.cols).step_by(seg) {
            // Single-segment fast path: borrow the head slices directly —
            // the per-request (non-batched) forward pays no extra copy.
            let (qc, kc, vc);
            let (qh, kh, vh) = if seg == x.cols {
                (&qh_all, &kh_all, &vh_all)
            } else {
                qc = qh_all.slice_cols(s0, s0 + seg);
                kc = kh_all.slice_cols(s0, s0 + seg);
                vc = vh_all.slice_cols(s0, s0 + seg);
                (&qc, &kc, &vc)
            };
            let mut s = matmul(&qh.transpose(), kh);
            s.scale(scale);
            softmax_rows(&mut s);
            let ch = matmul(vh, &s.transpose());
            for i in 0..dh {
                for t in 0..seg {
                    ctx.set(r0 + i, s0 + t, ch.at(i, t));
                }
            }
        }
    }
    if let Some(h) = hook {
        h(&no, &ctx);
    }
    let yo = linear(store, &no, &ctx);
    x.add(&yo)
}

/// Batched transformer block over `x.cols / seg` concatenated requests:
/// segment-local attention ([`attn_forward_seg`]), fully batched MLP (both
/// GEMMs see every request's tokens at once), optional per-sublayer
/// RMS-norm matching [`block_forward_norm`] (which is the `seg == x.cols`
/// case of this function — one kernel, parity by construction).
pub fn block_forward_batch(
    store: &ParamStore,
    prefix: &str,
    heads: usize,
    x: &Matrix,
    seg: usize,
    norm: bool,
) -> Matrix {
    block_forward_seg(store, prefix, heads, x, seg, norm, &mut None)
}

fn block_forward_seg(
    store: &ParamStore,
    prefix: &str,
    heads: usize,
    x: &Matrix,
    seg: usize,
    norm: bool,
    hook: &mut Option<Hook>,
) -> Matrix {
    let mut h = attn_forward_seg(store, prefix, heads, x, seg, hook);
    if norm {
        rmsnorm_cols(&mut h);
    }
    let mut out = mlp_forward(store, prefix, &h, hook);
    if norm {
        rmsnorm_cols(&mut out);
    }
    out
}

/// MLP sub-layer: returns X + W₂·gelu(W₁·X).
pub fn mlp_forward(store: &ParamStore, prefix: &str, x: &Matrix, hook: &mut Option<Hook>) -> Matrix {
    let n1 = format!("{prefix}.w1");
    let n2 = format!("{prefix}.w2");
    if let Some(h) = hook {
        h(&n1, x);
    }
    let mut hmid = linear(store, &n1, x);
    gelu(&mut hmid.data);
    if let Some(h) = hook {
        h(&n2, &hmid);
    }
    let out = linear(store, &n2, &hmid);
    x.add(&out)
}

/// One full transformer block: attention + MLP, RMS-norm after each.
pub fn block_forward(
    store: &ParamStore,
    prefix: &str,
    heads: usize,
    x: &Matrix,
    hook: &mut Option<Hook>,
) -> Matrix {
    block_forward_norm(store, prefix, heads, x, hook, true)
}

/// Block with optional per-sublayer RMS-norm. The language trunk runs
/// norm-free (gains are small, so norms stay bounded over a few blocks):
/// per-token normalization would rescale the readout token by a
/// scene-dependent factor, injecting multiplicative noise into the
/// linear position decode the action head depends on.
pub fn block_forward_norm(
    store: &ParamStore,
    prefix: &str,
    heads: usize,
    x: &Matrix,
    hook: &mut Option<Hook>,
    norm: bool,
) -> Matrix {
    block_forward_seg(store, prefix, heads, x, x.cols, norm, hook)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::methods::traits::Component;
    use crate::quant::probe::AttnBlock;
    use crate::util::rng::Rng;

    fn store_with_block(d: usize, hidden: usize, rng: &mut Rng) -> ParamStore {
        let mut s = ParamStore::new();
        let g = 1.0 / (d as f32).sqrt();
        for w in ["wq", "wk", "wv", "wo"] {
            s.insert(&format!("b.{w}"), Component::Language, true, Matrix::gauss(d, d, g, rng));
        }
        s.insert("b.w1", Component::Language, true, Matrix::gauss(hidden, d, g, rng));
        s.insert("b.w2", Component::Language, true, Matrix::gauss(d, hidden, g, rng));
        s
    }

    #[test]
    fn attn_matches_probe_block() {
        let mut rng = Rng::new(171);
        let s = store_with_block(16, 32, &mut rng);
        let x = Matrix::gauss(16, 7, 1.0, &mut rng);
        let mut none: Option<Hook> = None;
        let here = attn_forward(&s, "b", 4, &x, &mut none);
        let probe = AttnBlock {
            wq: s.get("b.wq").clone(),
            wk: s.get("b.wk").clone(),
            wv: s.get("b.wv").clone(),
            wo: s.get("b.wo").clone(),
            heads: 4,
        };
        let z = probe.forward(&x).z;
        assert!(here.dist_sq(&z) < 1e-9, "dist={}", here.dist_sq(&z));
    }

    #[test]
    fn hook_sees_every_quantizable_layer() {
        let mut rng = Rng::new(172);
        let s = store_with_block(8, 16, &mut rng);
        let x = Matrix::gauss(8, 5, 1.0, &mut rng);
        let mut seen: Vec<String> = Vec::new();
        {
            let mut f = |name: &str, _inp: &Matrix| seen.push(name.to_string());
            let mut hook: Option<Hook> = Some(&mut f);
            block_forward(&s, "b", 2, &x, &mut hook);
        }
        assert_eq!(seen, vec!["b.wq", "b.wk", "b.wv", "b.wo", "b.w1", "b.w2"]);
    }

    #[test]
    fn packed_block_forward_matches_dense_twin() {
        // The dispatch seam itself: a block whose six layers are packed
        // must produce the same output as a dense store holding the
        // dequantized weights.
        let mut rng = Rng::new(175);
        let mut packed = store_with_block(16, 32, &mut rng);
        assert_eq!(packed.pack_quantizable(8), 6);
        let mut dense = packed.clone();
        assert_eq!(dense.dequantize_all(), 6);
        let x = Matrix::gauss(16, 7, 1.0, &mut rng);
        let mut none: Option<Hook> = None;
        let yp = block_forward(&packed, "b", 4, &x, &mut none);
        let mut none2: Option<Hook> = None;
        let yd = block_forward(&dense, "b", 4, &x, &mut none2);
        assert!(
            yp.dist_sq(&yd) < 1e-6,
            "packed vs dense-twin block forward dist={}",
            yp.dist_sq(&yd)
        );
    }

    #[test]
    fn linear_dispatch_matches_reprs() {
        let mut rng = Rng::new(176);
        let mut s = ParamStore::new();
        s.insert("w", Component::Language, true, Matrix::gauss(12, 70, 1.0, &mut rng));
        let x = Matrix::gauss(70, 3, 1.0, &mut rng);
        let xv: Vec<f32> = x.col(0);
        let y_dense = linear(&s, "w", &x);
        let yv_dense = linear_vec(&s, "w", &xv);
        s.pack_quantizable(64); // 70 = 64 + 6 tail
        let y_packed = linear(&s, "w", &x);
        let yv_packed = linear_vec(&s, "w", &xv);
        // Packed dispatch must agree with the dense product of its own
        // dequantization (bit-true), not with the FP weights.
        let deq = s.dense_view("w").into_owned();
        let y_ref = crate::tensor::ops::matmul(&deq, &x);
        assert!(y_packed.dist_sq(&y_ref) < 1e-6 * y_ref.frob_norm_sq().max(1.0));
        for (a, b) in yv_packed.iter().zip(y_packed.col(0)) {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
        }
        // And the FP dispatch was a plain dense matmul.
        assert_eq!(y_dense.cols, 3);
        assert_eq!(yv_dense.len(), 12);
    }

    #[test]
    fn int8_dispatch_agrees_between_gemv_and_gemm_and_tracks_f32() {
        let mut rng = Rng::new(178);
        let mut s = ParamStore::new();
        s.insert("w", Component::Language, true, Matrix::gauss(12, 70, 1.0, &mut rng));
        s.pack_quantizable(64);
        let x = Matrix::gauss(70, 3, 1.0, &mut rng);
        let xv: Vec<f32> = x.col(0);
        let y32 = linear(&s, "w", &x);
        s.set_act_precision(crate::quant::packed::ActPrecision::Int8);
        let y8 = linear(&s, "w", &x);
        let yv8 = linear_vec(&s, "w", &xv);
        // GEMV and GEMM share the per-token integer kernel: bit-equal.
        for (a, b) in yv8.iter().zip(y8.col(0)) {
            assert_eq!(*a, b);
        }
        // And the W1A8 output stays within the analytic activation
        // round-off of W1A32: per (row, token), half the token scale
        // pushed through the dequantized row.
        let deq = s.dense_view("w").into_owned();
        for t in 0..3 {
            let scale = crate::tensor::ops::act_scale_i8(&x.col(t));
            for r in 0..12 {
                let abs_row: f32 = deq.row(r).iter().map(|v| v.abs()).sum();
                let bound = 0.5 * scale * abs_row * 1.001 + 1e-3;
                let (a, b) = (y8.at(r, t), y32.at(r, t));
                assert!((a - b).abs() <= bound, "({r},{t}): {a} vs {b} (bound {bound})");
            }
        }
    }

    #[test]
    fn batched_block_bit_identical_to_per_segment_forward() {
        // The serving-batch seam: a block run over two concatenated
        // requests must reproduce each request's solo forward exactly —
        // dense and packed — or batching would change served actions.
        let mut rng = Rng::new(177);
        let mut s = store_with_block(16, 32, &mut rng);
        let a = Matrix::gauss(16, 5, 1.0, &mut rng);
        let b = Matrix::gauss(16, 5, 1.0, &mut rng);
        let x = Matrix::hcat(&[&a, &b]);
        for packed in [false, true] {
            if packed {
                assert_eq!(s.pack_quantizable(8), 6);
            }
            let batched = block_forward_batch(&s, "b", 4, &x, 5, true);
            let mut none: Option<Hook> = None;
            let ya = block_forward(&s, "b", 4, &a, &mut none);
            let mut none2: Option<Hook> = None;
            let yb = block_forward(&s, "b", 4, &b, &mut none2);
            for i in 0..16 {
                for t in 0..5 {
                    assert_eq!(batched.at(i, t), ya.at(i, t), "seg A ({i},{t}) packed={packed}");
                    assert_eq!(batched.at(i, 5 + t), yb.at(i, t), "seg B ({i},{t}) packed={packed}");
                }
            }
        }
    }

    #[test]
    fn rmsnorm_near_unit_rms_with_floor() {
        let mut rng = Rng::new(173);
        let mut m = Matrix::gauss(32, 5, 4.0, &mut rng);
        rmsnorm_cols(&mut m);
        for t in 0..5 {
            let ss: f32 = (0..32).map(|i| m.at(i, t) * m.at(i, t)).sum();
            // Floor of 0.05 ⇒ strong tokens normalize just below unit RMS.
            assert!((ss / 32.0 - 1.0).abs() < 0.05, "ms={}", ss / 32.0);
        }
        // Near-silent tokens stay small instead of exploding.
        let mut z = Matrix::filled(32, 1, 0.01);
        rmsnorm_cols(&mut z);
        assert!(z.at(0, 0).abs() < 0.1);
    }

    #[test]
    fn block_output_finite_and_normed() {
        let mut rng = Rng::new(174);
        let s = store_with_block(16, 32, &mut rng);
        let x = Matrix::gauss(16, 6, 1.0, &mut rng);
        let mut none: Option<Hook> = None;
        let y = block_forward(&s, "b", 4, &x, &mut none);
        assert!(y.is_finite());
        assert_eq!((y.rows, y.cols), (16, 6));
    }
}
