//! Transformer layer forwards operating directly on the [`ParamStore`]
//! (so PTQ weight swaps take effect with no model rebuild) with an
//! optional activation hook for Hessian calibration capture.
//!
//! Every weight product goes through the [`linear`] / [`linear_vec`]
//! dispatch, which executes on whatever [`WeightRepr`] the store holds for
//! that layer: dense f32 GEMM for FP weights, the packed 1-bit GEMM of
//! [`crate::quant::packed::PackedBits`] for quantized ones. This is the
//! single seam that makes packed execution the real inference path
//! (serve, rollout, eval) rather than a microbenchmark
//! (DESIGN.md §Hardware-Adaptation).
//!
//! Block structure (both encoders): Φ_attn(X) = X + MHSA(X) followed by
//! Φ_mlp(X) = X + W₂·gelu(W₁·X), each followed by a column RMS-norm.
//! The attention math mirrors `quant::probe::AttnBlock` (finite-diff
//! verified there); a parity test pins the two implementations together.

use crate::model::params::{ParamStore, WeightRepr};
use crate::quant::packed::{put_scratch_attn, take_scratch_attn, ActPrecision, AttnPrecision};
use crate::tensor::matrix::Matrix;
use crate::tensor::ops::{
    act_scale_i8, dot_i8, gelu, matmul, matmul_mt, matvec, quantize_i8, softmax_rows,
};

/// Activation hook: called with (layer_name, layer_input) right before
/// each quantizable matmul. Inputs are d_in × n_tokens.
pub type Hook<'a> = &'a mut dyn FnMut(&str, &Matrix);

/// Y = W · X through the layer's stored representation: dense GEMM for FP
/// layers; for packed 1-bit layers the store's
/// [`ActPrecision`] picks the kernel — f32 packed GEMM (W1A32)
/// or the integer-inner-loop i8 GEMM (W1A8). This is the single
/// quantizable-matmul dispatch point, so every execution path (serving,
/// rollouts, eval drivers) inherits the activation precision with no
/// call-site changes.
pub fn linear(store: &ParamStore, name: &str, x: &Matrix) -> Matrix {
    let threads = store.exec_threads();
    match store.repr(name) {
        // Dense layers thread under the same budget (threshold inside
        // matmul_mt), so dense-vs-packed comparisons measure kernels,
        // not a threading asymmetry.
        WeightRepr::Dense(w) => matmul_mt(w, x, threads),
        // Packed GEMMs fan rows over the persistent pool when the
        // problem crosses the work threshold (bit-identical at every
        // thread count), honoring the store's pinned thread budget;
        // under W1A8 + ActScaleMode::Static the store supplies the
        // calibrated per-layer scale and the max sweeps are skipped.
        WeightRepr::Packed(p) => match store.act_precision() {
            ActPrecision::F32 => p.matmul_mt(x, threads),
            ActPrecision::Int8 => {
                p.matmul_i8_with_scale(x, threads, store.active_static_scale(name))
            }
        },
        // Transform-domain exact serving: per-token gather+Haar on the
        // activations, then the same packed GEMM against the committed
        // Haar-domain plane (+ salient side-channel). Static scales for
        // these layers are calibrated over the TRANSFORMED z.
        WeightRepr::TransformPacked(t) => match store.act_precision() {
            ActPrecision::F32 => t.matmul_mt(x, threads),
            ActPrecision::Int8 => {
                t.matmul_i8_scaled_mt(x, store.active_static_scale(name), threads)
            }
        },
    }
}

/// y = W · x (single-token GEMV form of [`linear`], same per-token kernel
/// under both activation precisions; large layers row-parallelize over
/// the pool, bit-identically, within the store's thread budget).
pub fn linear_vec(store: &ParamStore, name: &str, x: &[f32]) -> Vec<f32> {
    let threads = store.exec_threads();
    match store.repr(name) {
        WeightRepr::Dense(w) => matvec(w, x),
        WeightRepr::Packed(p) => match store.act_precision() {
            ActPrecision::F32 => p.matvec_owned_mt(x, None, threads),
            ActPrecision::Int8 => {
                p.matvec_i8_owned_mt(x, store.active_static_scale(name), threads)
            }
        },
        WeightRepr::TransformPacked(t) => match store.act_precision() {
            ActPrecision::F32 => t.matvec_owned_mt(x, threads),
            ActPrecision::Int8 => {
                t.matvec_i8_owned_mt(x, store.active_static_scale(name), threads)
            }
        },
    }
}

/// RMS-normalize each column (token) toward unit RMS, with a *floor*:
/// near-silent tokens (padding slots) are left small instead of being
/// blown up into random unit vectors that would pollute attention.
pub fn rmsnorm_cols(m: &mut Matrix) {
    let d = m.rows as f32;
    for t in 0..m.cols {
        let mut ss = 0.0f32;
        for i in 0..m.rows {
            let v = m.at(i, t);
            ss += v * v;
        }
        let inv = 1.0 / (ss / d + 0.05).sqrt();
        for i in 0..m.rows {
            *m.at_mut(i, t) *= inv;
        }
    }
}

/// Multi-head self-attention sub-layer: returns X + MHSA(X). The
/// single-request form of [`attn_forward_seg`] (one segment spanning all
/// columns), so single and batched serving share one kernel by
/// construction.
pub fn attn_forward(
    store: &ParamStore,
    prefix: &str,
    heads: usize,
    x: &Matrix,
    hook: &mut Option<Hook>,
) -> Matrix {
    attn_forward_seg(store, prefix, heads, x, x.cols, hook)
}

/// Segmented multi-head self-attention: `x` holds the column-concatenated
/// token sequences of `x.cols / seg` independent requests, each `seg`
/// columns wide. The Q/K/V/O projections run ONCE over the whole
/// concatenation — on packed layers this is the multi-token packed GEMM
/// amortizing sign-word traffic across every coalesced request — while
/// scores/softmax/context stay local to each segment, so tokens never
/// attend across requests. Per request the result is bit-identical to
/// [`attn_forward`] on that request alone: every linear kernel computes
/// output columns independently and in the same operation order.
pub fn attn_forward_seg(
    store: &ParamStore,
    prefix: &str,
    heads: usize,
    x: &Matrix,
    seg: usize,
    hook: &mut Option<Hook>,
) -> Matrix {
    assert!(seg > 0 && x.cols % seg == 0, "ragged batch: {} cols, segment {}", x.cols, seg);
    let nq = format!("{prefix}.wq");
    let nk = format!("{prefix}.wk");
    let nv = format!("{prefix}.wv");
    let no = format!("{prefix}.wo");
    if let Some(h) = hook {
        h(&nq, x);
        h(&nk, x);
        h(&nv, x);
    }
    let d = store.dims(&nq).0;
    let dh = d / heads;
    let q = linear(store, &nq, x);
    let k = linear(store, &nk, x);
    let v = linear(store, &nv, x);
    let scale = 1.0 / (dh as f32).sqrt();
    let mut ctx = Matrix::zeros(d, x.cols);
    match store.attn_precision() {
        AttnPrecision::F32 => attn_context_f32(&q, &k, &v, heads, dh, scale, seg, &mut ctx),
        AttnPrecision::Int8 => attn_context_i8(&q, &k, &v, heads, dh, scale, seg, &mut ctx),
    }
    if let Some(h) = hook {
        h(&no, &ctx);
    }
    let yo = linear(store, &no, &ctx);
    x.add(&yo)
}

/// f32 attention core: per (head, segment) scores → softmax → context,
/// written straight into `ctx` (no per-head transpose or copy-back
/// matrices — the context dot products target the output slots directly).
#[allow(clippy::too_many_arguments)]
fn attn_context_f32(
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    heads: usize,
    dh: usize,
    scale: f32,
    seg: usize,
    ctx: &mut Matrix,
) {
    for h in 0..heads {
        let r0 = h * dh;
        let r1 = r0 + dh;
        let qh_all = q.slice_rows(r0, r1);
        let kh_all = k.slice_rows(r0, r1);
        let vh_all = v.slice_rows(r0, r1);
        for s0 in (0..q.cols).step_by(seg) {
            // Single-segment fast path: borrow the head slices directly —
            // the per-request (non-batched) forward pays no extra copy.
            let (qc, kc, vc);
            let (qh, kh, vh) = if seg == q.cols {
                (&qh_all, &kh_all, &vh_all)
            } else {
                qc = qh_all.slice_cols(s0, s0 + seg);
                kc = kh_all.slice_cols(s0, s0 + seg);
                vc = vh_all.slice_cols(s0, s0 + seg);
                (&qc, &kc, &vc)
            };
            let mut s = matmul(&qh.transpose(), kh);
            s.scale(scale);
            softmax_rows(&mut s);
            // ctx[r0+i][s0+t] = Σ_u vh[i,u]·s[t,u]: the second transpose
            // and the elementwise copy-back the old code paid are gone —
            // both vh rows and s rows are contiguous, so this is a plain
            // dot per output slot, accumulated in the same ascending-u
            // order as the GEMM it replaces.
            for i in 0..dh {
                let vrow = vh.row(i);
                let crow = &mut ctx.row_mut(r0 + i)[s0..s0 + seg];
                for (t, slot) in crow.iter_mut().enumerate() {
                    let srow = s.row(t);
                    let mut acc = 0.0f32;
                    for (a, b) in vrow.iter().zip(srow) {
                        acc += a * b;
                    }
                    *slot = acc;
                }
            }
        }
    }
}

/// Column-max scales for one head's rows over one segment: `scales[t]` =
/// max_i |m[r0+i, s0+t]| / 127 and `inv[t]` its reciprocal (both 0 for an
/// all-zero token, so the quantized column is exactly zero).
fn head_col_scales(
    m: &Matrix,
    r0: usize,
    dh: usize,
    s0: usize,
    seg: usize,
    scales: &mut Vec<f32>,
    inv: &mut Vec<f32>,
) {
    scales.clear();
    scales.resize(seg, 0.0);
    for i in 0..dh {
        let row = &m.row(r0 + i)[s0..s0 + seg];
        for (sm, xv) in scales.iter_mut().zip(row) {
            *sm = sm.max(xv.abs());
        }
    }
    inv.clear();
    inv.resize(seg, 0.0);
    for (iv, sm) in inv.iter_mut().zip(scales.iter_mut()) {
        if *sm > 0.0 {
            *sm /= 127.0;
            *iv = 1.0 / *sm;
        }
    }
}

/// Quantize one head's segment token-major: `q8[t*dh + i]` = round(m[r0+i,
/// s0+t] / scale_t), so the score kernel's per-token rows are contiguous.
fn quant_cols_token_major(
    m: &Matrix,
    r0: usize,
    dh: usize,
    s0: usize,
    seg: usize,
    inv: &[f32],
    q8: &mut Vec<i8>,
) {
    q8.clear();
    q8.resize(seg * dh, 0);
    for i in 0..dh {
        let row = &m.row(r0 + i)[s0..s0 + seg];
        for (t, (xv, iv)) in row.iter().zip(inv).enumerate() {
            q8[t * dh + i] = quantize_i8(*xv, *iv);
        }
    }
}

/// Quantize one head's segment d-major: `q8[i*seg + u]` = round(m[r0+i,
/// s0+u] / scale_u), so the context kernel's per-dimension rows are
/// contiguous.
fn quant_cols_d_major(
    m: &Matrix,
    r0: usize,
    dh: usize,
    s0: usize,
    seg: usize,
    inv: &[f32],
    q8: &mut Vec<i8>,
) {
    q8.clear();
    q8.resize(seg * dh, 0);
    for i in 0..dh {
        let row = &m.row(r0 + i)[s0..s0 + seg];
        let dst = &mut q8[i * seg..(i + 1) * seg];
        for ((slot, xv), iv) in dst.iter_mut().zip(row).zip(inv) {
            *slot = quantize_i8(*xv, *iv);
        }
    }
}

/// INT8 attention core (the `*-a8` serve path): per (head, segment) the
/// Q/K columns quantize to i8 with per-token scales, scores accumulate in
/// i32 via [`dot_i8`] and rescale ONCE by `scale·sq[t]·sk[u]` before
/// softmax; the probability row then folds the per-token V scales in,
/// re-quantizes to i8, and the context GEMM runs i8×i8→i32 with a single
/// f32 rescale per output slot (DESIGN.md §INT8 Attention). Everything is
/// segment-local and per-token, so batched serving stays bit-identical to
/// sequential — the same argument as the segmented f32 path. All buffers
/// come from the pooled [`crate::quant::packed::GemmScratch`], so steady-
/// state serving allocates nothing here.
#[allow(clippy::too_many_arguments)]
fn attn_context_i8(
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    heads: usize,
    dh: usize,
    scale: f32,
    seg: usize,
    ctx: &mut Matrix,
) {
    let mut sc = take_scratch_attn();
    for h in 0..heads {
        let r0 = h * dh;
        for s0 in (0..q.cols).step_by(seg) {
            head_col_scales(q, r0, dh, s0, seg, &mut sc.sq, &mut sc.inv);
            quant_cols_token_major(q, r0, dh, s0, seg, &sc.inv, &mut sc.qq);
            head_col_scales(k, r0, dh, s0, seg, &mut sc.sk, &mut sc.inv);
            quant_cols_token_major(k, r0, dh, s0, seg, &sc.inv, &mut sc.qk);
            // i32 score accumulation is overflow-safe by a wide margin:
            // |q·k| ≤ dh · 127² ≈ dh · 16 K, and dh here is ≤ a few
            // hundred — orders of magnitude below i32::MAX.
            sc.scores.rows = seg;
            sc.scores.cols = seg;
            sc.scores.data.clear();
            sc.scores.data.resize(seg * seg, 0.0);
            for t in 0..seg {
                let qt = &sc.qq[t * dh..(t + 1) * dh];
                let f = scale * sc.sq[t];
                let srow = &mut sc.scores.data[t * seg..(t + 1) * seg];
                for (u, slot) in srow.iter_mut().enumerate() {
                    let ku = &sc.qk[u * dh..(u + 1) * dh];
                    *slot = f * sc.sk[u] * dot_i8(qt, ku) as f32;
                }
            }
            softmax_rows(&mut sc.scores);
            head_col_scales(v, r0, dh, s0, seg, &mut sc.sv, &mut sc.inv);
            quant_cols_d_major(v, r0, dh, s0, seg, &sc.inv, &mut sc.qv);
            for t in 0..seg {
                let prow = &sc.scores.data[t * seg..(t + 1) * seg];
                // Fold the per-token V scales into the probability row so
                // ONE row scale covers the whole context column.
                sc.pr.clear();
                sc.pr.extend(prow.iter().zip(&sc.sv).map(|(p, svu)| p * svu));
                let sr = act_scale_i8(&sc.pr);
                let inv_sr = if sr > 0.0 { 1.0 / sr } else { 0.0 };
                sc.qr.clear();
                sc.qr.resize(seg, 0);
                for (slot, rv) in sc.qr.iter_mut().zip(&sc.pr) {
                    *slot = quantize_i8(*rv, inv_sr);
                }
                for i in 0..dh {
                    let vrow = &sc.qv[i * seg..(i + 1) * seg];
                    ctx.row_mut(r0 + i)[s0 + t] = sr * dot_i8(vrow, &sc.qr) as f32;
                }
            }
        }
    }
    put_scratch_attn(sc);
}

/// Batched transformer block over `x.cols / seg` concatenated requests:
/// segment-local attention ([`attn_forward_seg`]), fully batched MLP (both
/// GEMMs see every request's tokens at once), optional per-sublayer
/// RMS-norm matching [`block_forward_norm`] (which is the `seg == x.cols`
/// case of this function — one kernel, parity by construction).
pub fn block_forward_batch(
    store: &ParamStore,
    prefix: &str,
    heads: usize,
    x: &Matrix,
    seg: usize,
    norm: bool,
) -> Matrix {
    block_forward_seg(store, prefix, heads, x, seg, norm, &mut None)
}

fn block_forward_seg(
    store: &ParamStore,
    prefix: &str,
    heads: usize,
    x: &Matrix,
    seg: usize,
    norm: bool,
    hook: &mut Option<Hook>,
) -> Matrix {
    let mut h = attn_forward_seg(store, prefix, heads, x, seg, hook);
    if norm {
        rmsnorm_cols(&mut h);
    }
    let mut out = mlp_forward(store, prefix, &h, hook);
    if norm {
        rmsnorm_cols(&mut out);
    }
    out
}

/// MLP sub-layer: returns X + W₂·gelu(W₁·X).
pub fn mlp_forward(store: &ParamStore, prefix: &str, x: &Matrix, hook: &mut Option<Hook>) -> Matrix {
    let n1 = format!("{prefix}.w1");
    let n2 = format!("{prefix}.w2");
    if let Some(h) = hook {
        h(&n1, x);
    }
    let mut hmid = linear(store, &n1, x);
    gelu(&mut hmid.data);
    if let Some(h) = hook {
        h(&n2, &hmid);
    }
    let out = linear(store, &n2, &hmid);
    x.add(&out)
}

/// One full transformer block: attention + MLP, RMS-norm after each.
pub fn block_forward(
    store: &ParamStore,
    prefix: &str,
    heads: usize,
    x: &Matrix,
    hook: &mut Option<Hook>,
) -> Matrix {
    block_forward_norm(store, prefix, heads, x, hook, true)
}

/// Block with optional per-sublayer RMS-norm. The language trunk runs
/// norm-free (gains are small, so norms stay bounded over a few blocks):
/// per-token normalization would rescale the readout token by a
/// scene-dependent factor, injecting multiplicative noise into the
/// linear position decode the action head depends on.
pub fn block_forward_norm(
    store: &ParamStore,
    prefix: &str,
    heads: usize,
    x: &Matrix,
    hook: &mut Option<Hook>,
    norm: bool,
) -> Matrix {
    block_forward_seg(store, prefix, heads, x, x.cols, norm, hook)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::methods::traits::Component;
    use crate::quant::probe::AttnBlock;
    use crate::util::rng::Rng;

    fn store_with_block(d: usize, hidden: usize, rng: &mut Rng) -> ParamStore {
        let mut s = ParamStore::new();
        let g = 1.0 / (d as f32).sqrt();
        for w in ["wq", "wk", "wv", "wo"] {
            s.insert(&format!("b.{w}"), Component::Language, true, Matrix::gauss(d, d, g, rng));
        }
        s.insert("b.w1", Component::Language, true, Matrix::gauss(hidden, d, g, rng));
        s.insert("b.w2", Component::Language, true, Matrix::gauss(d, hidden, g, rng));
        s
    }

    #[test]
    fn attn_matches_probe_block() {
        let mut rng = Rng::new(171);
        let s = store_with_block(16, 32, &mut rng);
        let x = Matrix::gauss(16, 7, 1.0, &mut rng);
        let mut none: Option<Hook> = None;
        let here = attn_forward(&s, "b", 4, &x, &mut none);
        let probe = AttnBlock {
            wq: s.get("b.wq").clone(),
            wk: s.get("b.wk").clone(),
            wv: s.get("b.wv").clone(),
            wo: s.get("b.wo").clone(),
            heads: 4,
        };
        let z = probe.forward(&x).z;
        assert!(here.dist_sq(&z) < 1e-9, "dist={}", here.dist_sq(&z));
    }

    #[test]
    fn hook_sees_every_quantizable_layer() {
        let mut rng = Rng::new(172);
        let s = store_with_block(8, 16, &mut rng);
        let x = Matrix::gauss(8, 5, 1.0, &mut rng);
        let mut seen: Vec<String> = Vec::new();
        {
            let mut f = |name: &str, _inp: &Matrix| seen.push(name.to_string());
            let mut hook: Option<Hook> = Some(&mut f);
            block_forward(&s, "b", 2, &x, &mut hook);
        }
        assert_eq!(seen, vec!["b.wq", "b.wk", "b.wv", "b.wo", "b.w1", "b.w2"]);
    }

    #[test]
    fn packed_block_forward_matches_dense_twin() {
        // The dispatch seam itself: a block whose six layers are packed
        // must produce the same output as a dense store holding the
        // dequantized weights.
        let mut rng = Rng::new(175);
        let mut packed = store_with_block(16, 32, &mut rng);
        assert_eq!(packed.pack_quantizable(8), 6);
        let mut dense = packed.clone();
        assert_eq!(dense.dequantize_all(), 6);
        let x = Matrix::gauss(16, 7, 1.0, &mut rng);
        let mut none: Option<Hook> = None;
        let yp = block_forward(&packed, "b", 4, &x, &mut none);
        let mut none2: Option<Hook> = None;
        let yd = block_forward(&dense, "b", 4, &x, &mut none2);
        assert!(
            yp.dist_sq(&yd) < 1e-6,
            "packed vs dense-twin block forward dist={}",
            yp.dist_sq(&yd)
        );
    }

    #[test]
    fn linear_dispatch_matches_reprs() {
        let mut rng = Rng::new(176);
        let mut s = ParamStore::new();
        s.insert("w", Component::Language, true, Matrix::gauss(12, 70, 1.0, &mut rng));
        let x = Matrix::gauss(70, 3, 1.0, &mut rng);
        let xv: Vec<f32> = x.col(0);
        let y_dense = linear(&s, "w", &x);
        let yv_dense = linear_vec(&s, "w", &xv);
        s.pack_quantizable(64); // 70 = 64 + 6 tail
        let y_packed = linear(&s, "w", &x);
        let yv_packed = linear_vec(&s, "w", &xv);
        // Packed dispatch must agree with the dense product of its own
        // dequantization (bit-true), not with the FP weights.
        let deq = s.dense_view("w").into_owned();
        let y_ref = crate::tensor::ops::matmul(&deq, &x);
        assert!(y_packed.dist_sq(&y_ref) < 1e-6 * y_ref.frob_norm_sq().max(1.0));
        for (a, b) in yv_packed.iter().zip(y_packed.col(0)) {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
        }
        // And the FP dispatch was a plain dense matmul.
        assert_eq!(y_dense.cols, 3);
        assert_eq!(yv_dense.len(), 12);
    }

    #[test]
    fn int8_dispatch_agrees_between_gemv_and_gemm_and_tracks_f32() {
        let mut rng = Rng::new(178);
        let mut s = ParamStore::new();
        s.insert("w", Component::Language, true, Matrix::gauss(12, 70, 1.0, &mut rng));
        s.pack_quantizable(64);
        let x = Matrix::gauss(70, 3, 1.0, &mut rng);
        let xv: Vec<f32> = x.col(0);
        let y32 = linear(&s, "w", &x);
        s.set_act_precision(crate::quant::packed::ActPrecision::Int8);
        let y8 = linear(&s, "w", &x);
        let yv8 = linear_vec(&s, "w", &xv);
        // GEMV and GEMM share the per-token integer kernel: bit-equal.
        for (a, b) in yv8.iter().zip(y8.col(0)) {
            assert_eq!(*a, b);
        }
        // And the W1A8 output stays within the analytic activation
        // round-off of W1A32: per (row, token), half the token scale
        // pushed through the dequantized row.
        let deq = s.dense_view("w").into_owned();
        for t in 0..3 {
            let scale = crate::tensor::ops::act_scale_i8(&x.col(t));
            for r in 0..12 {
                let abs_row: f32 = deq.row(r).iter().map(|v| v.abs()).sum();
                let bound = 0.5 * scale * abs_row * 1.001 + 1e-3;
                let (a, b) = (y8.at(r, t), y32.at(r, t));
                assert!((a - b).abs() <= bound, "({r},{t}): {a} vs {b} (bound {bound})");
            }
        }
    }

    #[test]
    fn batched_block_bit_identical_to_per_segment_forward() {
        // The serving-batch seam: a block run over two concatenated
        // requests must reproduce each request's solo forward exactly —
        // dense and packed — or batching would change served actions.
        let mut rng = Rng::new(177);
        let mut s = store_with_block(16, 32, &mut rng);
        let a = Matrix::gauss(16, 5, 1.0, &mut rng);
        let b = Matrix::gauss(16, 5, 1.0, &mut rng);
        let x = Matrix::hcat(&[&a, &b]);
        for packed in [false, true] {
            if packed {
                assert_eq!(s.pack_quantizable(8), 6);
            }
            let batched = block_forward_batch(&s, "b", 4, &x, 5, true);
            let mut none: Option<Hook> = None;
            let ya = block_forward(&s, "b", 4, &a, &mut none);
            let mut none2: Option<Hook> = None;
            let yb = block_forward(&s, "b", 4, &b, &mut none2);
            for i in 0..16 {
                for t in 0..5 {
                    assert_eq!(batched.at(i, t), ya.at(i, t), "seg A ({i},{t}) packed={packed}");
                    assert_eq!(batched.at(i, 5 + t), yb.at(i, t), "seg B ({i},{t}) packed={packed}");
                }
            }
        }
    }

    #[test]
    fn int8_attention_tracks_f32_attention() {
        // Same store, same input: the INT8 attention core must stay
        // within quantization round-off of the f32 core (per-token
        // scales keep the relative error near 0.5/127 per tensor).
        let mut rng = Rng::new(179);
        let mut s = store_with_block(16, 32, &mut rng);
        let x = Matrix::gauss(16, 7, 1.0, &mut rng);
        let mut none: Option<Hook> = None;
        let yf = attn_forward(&s, "b", 4, &x, &mut none);
        s.set_attn_precision(AttnPrecision::Int8);
        let mut none2: Option<Hook> = None;
        let yi = attn_forward(&s, "b", 4, &x, &mut none2);
        let rel = yi.dist_sq(&yf) / yf.frob_norm_sq().max(1.0);
        assert!(rel < 2e-3, "i8 attention drifted: rel dist_sq = {rel}");
        // And the paths genuinely differ (the i8 core really ran).
        assert!(yi.dist_sq(&yf) > 0.0, "i8 path produced bit-identical f32 output");
    }

    #[test]
    fn batched_int8_attention_bit_identical_to_solo() {
        // The serving-batch seam under INT8 attention: scores, softmax
        // and context are all segment-local with per-token scales, so
        // batching two requests must reproduce each solo forward
        // bitwise — same contract the f32 path pins above.
        let mut rng = Rng::new(180);
        let mut s = store_with_block(16, 32, &mut rng);
        s.set_attn_precision(AttnPrecision::Int8);
        let a = Matrix::gauss(16, 5, 1.0, &mut rng);
        let b = Matrix::gauss(16, 5, 1.0, &mut rng);
        let x = Matrix::hcat(&[&a, &b]);
        for packed in [false, true] {
            if packed {
                assert_eq!(s.pack_quantizable(8), 6);
            }
            let batched = block_forward_batch(&s, "b", 4, &x, 5, true);
            let mut none: Option<Hook> = None;
            let ya = block_forward(&s, "b", 4, &a, &mut none);
            let mut none2: Option<Hook> = None;
            let yb = block_forward(&s, "b", 4, &b, &mut none2);
            for i in 0..16 {
                for t in 0..5 {
                    assert_eq!(batched.at(i, t), ya.at(i, t), "seg A ({i},{t}) packed={packed}");
                    assert_eq!(batched.at(i, 5 + t), yb.at(i, t), "seg B ({i},{t}) packed={packed}");
                }
            }
        }
    }

    #[test]
    fn int8_attention_survives_zero_tokens() {
        // All-zero tokens yield zero per-token scales; the 0-guard must
        // produce exactly-zero quantized columns and context (no NaN
        // from a 0/0 reciprocal), matching the f32 core bitwise.
        let mut rng = Rng::new(181);
        let mut s = store_with_block(16, 32, &mut rng);
        let x = Matrix::zeros(16, 4);
        let mut none: Option<Hook> = None;
        let yf = attn_forward(&s, "b", 4, &x, &mut none);
        s.set_attn_precision(AttnPrecision::Int8);
        let mut none2: Option<Hook> = None;
        let yi = attn_forward(&s, "b", 4, &x, &mut none2);
        assert!(yi.is_finite());
        for (a, b) in yi.data.iter().zip(&yf.data) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn rmsnorm_near_unit_rms_with_floor() {
        let mut rng = Rng::new(173);
        let mut m = Matrix::gauss(32, 5, 4.0, &mut rng);
        rmsnorm_cols(&mut m);
        for t in 0..5 {
            let ss: f32 = (0..32).map(|i| m.at(i, t) * m.at(i, t)).sum();
            // Floor of 0.05 ⇒ strong tokens normalize just below unit RMS.
            assert!((ss / 32.0 - 1.0).abs() < 0.05, "ms={}", ss / 32.0);
        }
        // Near-silent tokens stay small instead of exploding.
        let mut z = Matrix::filled(32, 1, 0.01);
        rmsnorm_cols(&mut z);
        assert!(z.at(0, 0).abs() < 0.1);
    }

    #[test]
    fn block_output_finite_and_normed() {
        let mut rng = Rng::new(174);
        let s = store_with_block(16, 32, &mut rng);
        let x = Matrix::gauss(16, 6, 1.0, &mut rng);
        let mut none: Option<Hook> = None;
        let y = block_forward(&s, "b", 4, &x, &mut none);
        assert!(y.is_finite());
        assert_eq!((y.rows, y.cols), (16, 6));
    }
}
