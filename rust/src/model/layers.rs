//! Transformer layer forwards operating directly on the [`ParamStore`]
//! (so PTQ weight swaps take effect with no model rebuild) with an
//! optional activation hook for Hessian calibration capture.
//!
//! Block structure (both encoders): Φ_attn(X) = X + MHSA(X) followed by
//! Φ_mlp(X) = X + W₂·gelu(W₁·X), each followed by a column RMS-norm.
//! The attention math mirrors `quant::probe::AttnBlock` (finite-diff
//! verified there); a parity test pins the two implementations together.

use crate::model::params::ParamStore;
use crate::tensor::matrix::Matrix;
use crate::tensor::ops::{gelu, matmul, softmax_rows};

/// Activation hook: called with (layer_name, layer_input) right before
/// each quantizable matmul. Inputs are d_in × n_tokens.
pub type Hook<'a> = &'a mut dyn FnMut(&str, &Matrix);

/// RMS-normalize each column (token) toward unit RMS, with a *floor*:
/// near-silent tokens (padding slots) are left small instead of being
/// blown up into random unit vectors that would pollute attention.
pub fn rmsnorm_cols(m: &mut Matrix) {
    let d = m.rows as f32;
    for t in 0..m.cols {
        let mut ss = 0.0f32;
        for i in 0..m.rows {
            let v = m.at(i, t);
            ss += v * v;
        }
        let inv = 1.0 / (ss / d + 0.05).sqrt();
        for i in 0..m.rows {
            *m.at_mut(i, t) *= inv;
        }
    }
}

/// Multi-head self-attention sub-layer: returns X + MHSA(X).
pub fn attn_forward(
    store: &ParamStore,
    prefix: &str,
    heads: usize,
    x: &Matrix,
    hook: &mut Option<Hook>,
) -> Matrix {
    let wq = store.get(&format!("{prefix}.wq"));
    let wk = store.get(&format!("{prefix}.wk"));
    let wv = store.get(&format!("{prefix}.wv"));
    let wo = store.get(&format!("{prefix}.wo"));
    if let Some(h) = hook {
        h(&format!("{prefix}.wq"), x);
        h(&format!("{prefix}.wk"), x);
        h(&format!("{prefix}.wv"), x);
    }
    let d = wq.rows;
    let n = x.cols;
    let dh = d / heads;
    let q = matmul(wq, x);
    let k = matmul(wk, x);
    let v = matmul(wv, x);
    let scale = 1.0 / (dh as f32).sqrt();
    let mut ctx = Matrix::zeros(d, n);
    for h in 0..heads {
        let r0 = h * dh;
        let r1 = r0 + dh;
        let qh = q.slice_rows(r0, r1);
        let kh = k.slice_rows(r0, r1);
        let vh = v.slice_rows(r0, r1);
        let mut s = matmul(&qh.transpose(), &kh);
        s.scale(scale);
        softmax_rows(&mut s);
        let ch = matmul(&vh, &s.transpose());
        for i in 0..dh {
            for t in 0..n {
                ctx.set(r0 + i, t, ch.at(i, t));
            }
        }
    }
    if let Some(h) = hook {
        h(&format!("{prefix}.wo"), &ctx);
    }
    let yo = matmul(wo, &ctx);
    x.add(&yo)
}

/// MLP sub-layer: returns X + W₂·gelu(W₁·X).
pub fn mlp_forward(store: &ParamStore, prefix: &str, x: &Matrix, hook: &mut Option<Hook>) -> Matrix {
    let w1 = store.get(&format!("{prefix}.w1"));
    let w2 = store.get(&format!("{prefix}.w2"));
    if let Some(h) = hook {
        h(&format!("{prefix}.w1"), x);
    }
    let mut hmid = matmul(w1, x);
    gelu(&mut hmid.data);
    if let Some(h) = hook {
        h(&format!("{prefix}.w2"), &hmid);
    }
    let out = matmul(w2, &hmid);
    x.add(&out)
}

/// One full transformer block: attention + MLP, RMS-norm after each.
pub fn block_forward(
    store: &ParamStore,
    prefix: &str,
    heads: usize,
    x: &Matrix,
    hook: &mut Option<Hook>,
) -> Matrix {
    block_forward_norm(store, prefix, heads, x, hook, true)
}

/// Block with optional per-sublayer RMS-norm. The language trunk runs
/// norm-free (gains are small, so norms stay bounded over a few blocks):
/// per-token normalization would rescale the readout token by a
/// scene-dependent factor, injecting multiplicative noise into the
/// linear position decode the action head depends on.
pub fn block_forward_norm(
    store: &ParamStore,
    prefix: &str,
    heads: usize,
    x: &Matrix,
    hook: &mut Option<Hook>,
    norm: bool,
) -> Matrix {
    let mut h = attn_forward(store, prefix, heads, x, hook);
    if norm {
        rmsnorm_cols(&mut h);
    }
    let mut out = mlp_forward(store, prefix, &h, hook);
    if norm {
        rmsnorm_cols(&mut out);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::methods::traits::Component;
    use crate::quant::probe::AttnBlock;
    use crate::util::rng::Rng;

    fn store_with_block(d: usize, hidden: usize, rng: &mut Rng) -> ParamStore {
        let mut s = ParamStore::new();
        let g = 1.0 / (d as f32).sqrt();
        for w in ["wq", "wk", "wv", "wo"] {
            s.insert(&format!("b.{w}"), Component::Language, true, Matrix::gauss(d, d, g, rng));
        }
        s.insert("b.w1", Component::Language, true, Matrix::gauss(hidden, d, g, rng));
        s.insert("b.w2", Component::Language, true, Matrix::gauss(d, hidden, g, rng));
        s
    }

    #[test]
    fn attn_matches_probe_block() {
        let mut rng = Rng::new(171);
        let s = store_with_block(16, 32, &mut rng);
        let x = Matrix::gauss(16, 7, 1.0, &mut rng);
        let mut none: Option<Hook> = None;
        let here = attn_forward(&s, "b", 4, &x, &mut none);
        let probe = AttnBlock {
            wq: s.get("b.wq").clone(),
            wk: s.get("b.wk").clone(),
            wv: s.get("b.wv").clone(),
            wo: s.get("b.wo").clone(),
            heads: 4,
        };
        let z = probe.forward(&x).z;
        assert!(here.dist_sq(&z) < 1e-9, "dist={}", here.dist_sq(&z));
    }

    #[test]
    fn hook_sees_every_quantizable_layer() {
        let mut rng = Rng::new(172);
        let s = store_with_block(8, 16, &mut rng);
        let x = Matrix::gauss(8, 5, 1.0, &mut rng);
        let mut seen: Vec<String> = Vec::new();
        {
            let mut f = |name: &str, _inp: &Matrix| seen.push(name.to_string());
            let mut hook: Option<Hook> = Some(&mut f);
            block_forward(&s, "b", 2, &x, &mut hook);
        }
        assert_eq!(seen, vec!["b.wq", "b.wk", "b.wv", "b.wo", "b.w1", "b.w2"]);
    }

    #[test]
    fn rmsnorm_near_unit_rms_with_floor() {
        let mut rng = Rng::new(173);
        let mut m = Matrix::gauss(32, 5, 4.0, &mut rng);
        rmsnorm_cols(&mut m);
        for t in 0..5 {
            let ss: f32 = (0..32).map(|i| m.at(i, t) * m.at(i, t)).sum();
            // Floor of 0.05 ⇒ strong tokens normalize just below unit RMS.
            assert!((ss / 32.0 - 1.0).abs() < 0.05, "ms={}", ss / 32.0);
        }
        // Near-silent tokens stay small instead of exploding.
        let mut z = Matrix::filled(32, 1, 0.01);
        rmsnorm_cols(&mut z);
        assert!(z.at(0, 0).abs() < 0.1);
    }

    #[test]
    fn block_output_finite_and_normed() {
        let mut rng = Rng::new(174);
        let s = store_with_block(16, 32, &mut rng);
        let x = Matrix::gauss(16, 6, 1.0, &mut rng);
        let mut none: Option<Hook> = None;
        let y = block_forward(&s, "b", 4, &x, &mut none);
        assert!(y.is_finite());
        assert_eq!((y.rows, y.cols), (16, 6));
    }
}
