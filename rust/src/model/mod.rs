//! MiniVLA: configs, parameter store, transformer layers and the policy
//! forward passes (token / chunk / diffusion action heads).

pub mod config;
pub mod layers;
pub mod params;
pub mod vla;

pub use config::{DeployRepr, HeadKind, VlaConfig};
pub use crate::quant::packed::{ActPrecision, ActScaleMode, AttnPrecision};
pub use params::{ParamStore, WeightRepr};
pub use vla::{content_codes, instr_index, MiniVla, ObsInput, N_CONTENT_IDS};
