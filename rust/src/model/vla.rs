//! MiniVLA: the policy family under quantization.
//!
//! Architecture (mirroring OpenVLA / OpenVLA-OFT / CogACT at laptop scale):
//!
//! ```text
//! visual raw tokens ──vis.embed──▶ vision blocks ──proj──▶ ┐
//! instruction id ────lm.embed_instr──────────────────────▶ ├─ LM blocks ─▶ features ─▶ head
//! proprio ───────────lm.embed_proprio────────────────────▶ ┘
//! ```
//!
//! Grounding is *constructed*: LM block 0's Q/K projections share a
//! low-rank factor that scores content-code agreement between the
//! instruction token and visual tokens (target selection); block 1 does
//! the same for the goal code. Readout layers are ridge-fit by
//! behavioural cloning ([`crate::train::bc`]). See DESIGN.md §1.

use crate::methods::traits::Component;
use crate::model::config::{HeadKind, VlaConfig};
use crate::model::layers::{
    block_forward, block_forward_batch, linear, linear_vec, rmsnorm_cols, Hook,
};
use crate::model::params::{binary_factor, channels, grounding_proj, structured_weight, structured_weight_lattice, ParamStore};
use crate::tensor::matrix::Matrix;
use crate::util::rng::Rng;

/// Number of global content ids (objects the benchmarks reference).
pub const N_CONTENT_IDS: usize = 8;

/// Fixed orthonormal content-code table (8 ids × 8 dims), deterministic.
pub fn content_codes() -> Matrix {
    let mut rng = Rng::with_stream(0xC0DE, 0xC0);
    Matrix::orthogonal(N_CONTENT_IDS, channels::CONTENT.end - channels::CONTENT.start, 1.0, &mut rng)
}

/// Instruction index from (target content id, goal content id).
pub fn instr_index(target_id: usize, goal_id: usize) -> usize {
    target_id * N_CONTENT_IDS + goal_id
}

/// One request's trunk inputs, borrowed — the batch element of
/// [`MiniVla::features_batch`] (the slice form of [`MiniVla::features`]'s
/// arguments).
#[derive(Clone, Copy, Debug)]
pub struct ObsInput<'a> {
    /// d_vis_in × n_visual raw visual tokens.
    pub visual_raw: &'a Matrix,
    pub instr_id: usize,
    pub proprio: &'a [f32],
}

#[derive(Clone, Debug)]
pub struct MiniVla {
    pub cfg: VlaConfig,
    pub store: ParamStore,
}

impl MiniVla {
    /// Build a MiniVLA with structured weights (readout heads start at
    /// zero; fit them with [`crate::train::bc::fit_policy`]).
    pub fn new(cfg: VlaConfig) -> Self {
        let mut rng = Rng::with_stream(cfg.seed, 0x51A);
        let mut store = ParamStore::new();
        let codes = content_codes();

        // ---- vision embed: raw channels → reserved channels ----
        let dv = cfg.d_vision;
        // Detection channels get a strong identity map and appearance a
        // weak projection, so the (RMS-normalized) token keeps its
        // semantic content dominant regardless of the appearance width.
        let mut vis_embed = Matrix::gauss(dv, cfg.d_vis_in, 0.02, &mut rng);
        for (r, c) in channels::CONTENT.zip(channels::RAW_CONTENT) {
            vis_embed.set(r, c, 2.0);
        }
        for (r, c) in channels::POS.zip(channels::RAW_POS) {
            vis_embed.set(r, c, 2.0);
        }
        for (r, c) in channels::EXTRA.zip(channels::RAW_EXTRA) {
            vis_embed.set(r, c, 2.0);
        }
        // appearance → weak spread over remaining rows
        for r in channels::APPEAR_START..dv {
            for c in channels::RAW_APPEAR_START..cfg.d_vis_in {
                vis_embed.set(r, c, vis_embed.at(r, c) + 0.15 * rng.gauss() as f32);
            }
        }
        store.insert("vis.embed", Component::Vision, false, vis_embed);

        // ---- vision blocks: mild mixing (residual dominates). The
        // write-back projections (wo, w2) leave the reserved detection
        // channels untouched — the encoder refines appearance features
        // while the residual path carries content/pos/extra cleanly (and
        // zero rows stay zero under every 1-bit quantizer: α = 0).
        let zero_rows = |m: &mut Matrix, upto: usize| {
            for i in 0..upto {
                for j in 0..m.cols {
                    m.set(i, j, 0.0);
                }
            }
        };
        for b in 0..cfg.vision_blocks {
            let p = format!("vis.{b}");
            for w in ["wq", "wk", "wv"] {
                store.insert(
                    &format!("{p}.{w}"),
                    Component::Vision,
                    true,
                    structured_weight(dv, dv, 0.35, 2.0, &mut rng),
                );
            }
            let mut wo = structured_weight(dv, dv, 0.15, 2.0, &mut rng);
            zero_rows(&mut wo, channels::APPEAR_START);
            store.insert(&format!("{p}.wo"), Component::Vision, true, wo);
            let hid = cfg.mlp_hidden(dv);
            store.insert(&format!("{p}.w1"), Component::Vision, true, structured_weight(hid, dv, 0.35, 2.0, &mut rng));
            let mut w2 = structured_weight(dv, hid, 0.15, 2.0, &mut rng);
            zero_rows(&mut w2, channels::APPEAR_START);
            store.insert(&format!("{p}.w2"), Component::Vision, true, w2);
        }

        // ---- projector: identity-lift d_vision → d_model + mixing rows ----
        let dm = cfg.d_model;
        assert!(dm >= dv, "projector assumes d_model >= d_vision");
        let mut proj = Matrix::gauss(dm, dv, 0.05, &mut rng);
        for i in 0..dv.min(channels::tgt_range(dm).start) {
            proj.set(i, i, 1.0);
        }
        // Mixing rows stop below the instruction-target band: visual
        // tokens must stay (near-)zero there so grounding cannot
        // self-match (see channels::tgt_range).
        for i in dv..channels::tgt_range(dm).start {
            for j in channels::APPEAR_START..dv {
                proj.set(i, j, proj.at(i, j) + 0.3 * rng.gauss() as f32);
            }
        }
        for i in channels::tgt_range(dm) {
            for j in 0..dv {
                proj.set(i, j, 0.01 * rng.gauss() as f32);
            }
        }
        store.insert("proj", Component::Projector, true, proj);

        // ---- instruction embedding table (FP) ----
        let mut embed_instr = Matrix::gauss(dm, cfg.vocab, 0.02, &mut rng);
        let cdim = channels::CONTENT.end - channels::CONTENT.start;
        let tgt = channels::tgt_range(dm);
        for target in 0..N_CONTENT_IDS {
            for goal in 0..N_CONTENT_IDS {
                let col = instr_index(target, goal);
                if col >= cfg.vocab {
                    continue;
                }
                for k in 0..cdim {
                    // Target code in the dedicated instruction band (NOT in
                    // CONTENT — keeps the instruction's own key silent).
                    embed_instr.set(tgt.start + k, col, codes.at(target, k));
                    embed_instr.set(channels::GOAL.start + k, col, codes.at(goal, k));
                }
            }
        }
        store.insert("lm.embed_instr", Component::Language, false, embed_instr);
        let mut embed_proprio = structured_weight(dm, cfg.d_proprio, 0.8, 1.0, &mut rng);
        // The proprio token must be silent in the grounding match bands,
        // or its random embedding competes with visual keys.
        for i in channels::CONTENT.chain(channels::GOAL).chain(channels::tgt_range(dm)) {
            for j in 0..cfg.d_proprio {
                embed_proprio.set(i, j, 0.01 * rng.gauss() as f32);
            }
        }
        store.insert("lm.embed_proprio", Component::Language, false, embed_proprio);

        // ---- language blocks ----
        // Shared low-rank grounding factors (content-match spaces).
        let a_target = binary_factor(dm, cdim, 1.0, &mut rng);
        let a_goal = binary_factor(dm, cdim, 1.0, &mut rng);
        for b in 0..cfg.lm_blocks {
            let p = format!("lm.{b}");
            let (wq, wk) = match b {
                0 => (
                    // Query: instruction-target band; key: visual content.
                    grounding_proj(dm, dm, channels::tgt_range(dm), &a_target, 0.25, &mut rng),
                    grounding_proj(dm, dm, channels::CONTENT, &a_target, 0.25, &mut rng),
                ),
                1 => (
                    // Query: goal band (instruction only); key: content.
                    grounding_proj(dm, dm, channels::GOAL, &a_goal, 0.25, &mut rng),
                    grounding_proj(dm, dm, channels::CONTENT, &a_goal, 0.25, &mut rng),
                ),
                _ => (
                    // Non-grounding blocks: weak scores → high-entropy
                    // attention (≈ mean pooling), so the untrained mixing
                    // does not scramble the grounded readout.
                    structured_weight_lattice(dm, dm, 0.25, 2.0, &mut rng),
                    structured_weight_lattice(dm, dm, 0.25, 2.0, &mut rng),
                ),
            };
            store.insert(&format!("{p}.wq"), Component::Language, true, wq);
            store.insert(&format!("{p}.wk"), Component::Language, true, wk);
            // Grounding blocks carry the attended token's position/extra
            // channels through a dedicated low-rank factor in the value
            // path (plus the usual structured mixing), so the readout can
            // linearly recover target/goal positions.
            let gain_v = if b < 2 { 0.3 } else { 0.15 };
            let mut wv = structured_weight_lattice(dm, dm, gain_v, 2.0, &mut rng);
            if b < 2 {
                let span = channels::EXTRA.end - channels::POS.start;
                let bmat = binary_factor(dm, span, 2.0, &mut rng);
                for i in 0..dm {
                    for (k, j) in (channels::POS.start..channels::EXTRA.end).enumerate() {
                        *wv.at_mut(i, j) += bmat.at(i, k);
                    }
                }
            }
            store.insert(&format!("{p}.wv"), Component::Language, true, wv);
            let gain_o = if b < 2 { 0.25 } else { 0.12 };
            let mut wo = structured_weight_lattice(dm, dm, gain_o, 2.0, &mut rng);
            let hid = cfg.mlp_hidden(dm);
            let mut w1 = structured_weight_lattice(hid, dm, 0.4, 2.0, &mut rng);
            let mut w2 = structured_weight_lattice(dm, hid, 0.15, 2.0, &mut rng);
            let _ = &mut w1;
            if b == 0 {
                // Block 0 must not pollute the match bands the goal
                // grounding (block 1) reads: silence those write rows.
                for i in channels::CONTENT.chain(channels::GOAL) {
                    for j in 0..dm {
                        wo.set(i, j, 0.0);
                    }
                    for j in 0..hid {
                        w2.set(i, j, 0.0);
                    }
                }
            }
            store.insert(&format!("{p}.wo"), Component::Language, true, wo);
            store.insert(&format!("{p}.w1"), Component::Language, true, w1);
            store.insert(&format!("{p}.w2"), Component::Language, true, w2);
        }

        // ---- action heads (zero-init; BC fits them) ----
        // Fixed tanh random-feature expansion: the action head's "MLP"
        // nonlinearity (clamp/mode-switch shapes), ridge-fit on top.
        let fd = cfg.feat_dim();
        store.insert(
            "head.expand",
            Component::ActionHead,
            true,
            Matrix::gauss(cfg.head_hidden, fd, 1.0 / (fd as f32).sqrt() * 1.5, &mut rng),
        );
        let feat = cfg.head_in_dim();
        // Feature standardization (the head's input layernorm-affine):
        // row 0 = mean, row 1 = std, fit by BC. Keeps ridge regularization
        // uniform per dimension — no tiny-variance dim can acquire a huge
        // inverse weight that would amplify quantization noise.
        let mut hn = Matrix::zeros(2, feat);
        for j in 0..feat {
            hn.set(1, j, 1.0);
        }
        store.insert("head.norm", Component::ActionHead, false, hn);
        match cfg.head {
            HeadKind::Token => {
                store.insert(
                    "head.main",
                    Component::ActionHead,
                    true,
                    Matrix::zeros(cfg.act_dim, feat),
                );
            }
            HeadKind::Chunk => {
                store.insert(
                    "head.main",
                    Component::ActionHead,
                    true,
                    Matrix::zeros(cfg.chunk * cfg.act_dim, feat),
                );
            }
            HeadKind::Diffusion => {
                for t in 0..cfg.diffusion_steps {
                    store.insert(
                        &format!("head.diff.{t}"),
                        Component::ActionHead,
                        true,
                        Matrix::zeros(cfg.act_dim, cfg.act_dim + feat + 1),
                    );
                }
            }
        }

        store.set_act_precision(cfg.act_precision);
        store.set_act_scale_mode(cfg.act_scale_mode);
        store.set_attn_precision(cfg.attn_precision);
        MiniVla { cfg, store }
    }

    /// Switch the activation precision the packed layers execute at (both
    /// the config record and the store policy the dispatch reads). No
    /// repack: the W1A32 and W1A8 kernels read the same sign planes and
    /// (α, μ) scales — only the policy field changes. (Cloning a model to
    /// build an `-a8` twin still copies its store; on a packed commit
    /// that copy is ~32× smaller than the dense checkpoint.)
    ///
    /// The attention-core precision FOLLOWS this knob: `Int8` activations
    /// bring INT8 attention along (and `F32` brings f32 attention back),
    /// which is how every `*-a8` variant inherits the quantized attention
    /// path with zero call-site changes. Use
    /// [`Self::with_attn_precision`] AFTER this to override attention
    /// independently (e.g. W1A8 linears with f32 attention for A/B runs).
    pub fn with_act_precision(mut self, p: crate::quant::packed::ActPrecision) -> Self {
        self.cfg.act_precision = p;
        self.store.set_act_precision(p);
        let ap = match p {
            crate::quant::packed::ActPrecision::F32 => crate::quant::packed::AttnPrecision::F32,
            crate::quant::packed::ActPrecision::Int8 => crate::quant::packed::AttnPrecision::Int8,
        };
        self.cfg.attn_precision = ap;
        self.store.set_attn_precision(ap);
        self
    }

    /// Switch the attention-core precision alone (both the config record
    /// and the store policy `attn_forward_seg` reads). Independent of the
    /// linears' activation precision; call after
    /// [`Self::with_act_precision`] to override the default coupling.
    pub fn with_attn_precision(mut self, p: crate::quant::packed::AttnPrecision) -> Self {
        self.cfg.attn_precision = p;
        self.store.set_attn_precision(p);
        self
    }

    /// Switch how the W1A8 kernels obtain activation scales (per-token
    /// dynamic vs calibrated static — both the config record and the
    /// store policy the dispatch reads). Under `Static`, layers without a
    /// calibrated scale keep the per-token sweep, so this is safe to set
    /// before OR after `calib::scales` ran.
    pub fn with_act_scale_mode(mut self, m: crate::quant::packed::ActScaleMode) -> Self {
        self.cfg.act_scale_mode = m;
        self.store.set_act_scale_mode(m);
        self
    }

    /// Run the trunk: visual raw tokens (d_vis_in × n_visual), instruction
    /// index, proprio vector → readout feature vector.
    pub fn features(
        &self,
        visual_raw: &Matrix,
        instr_id: usize,
        proprio: &[f32],
        hook: &mut Option<Hook>,
    ) -> Vec<f32> {
        let cfg = &self.cfg;
        assert_eq!(visual_raw.rows, cfg.d_vis_in);
        assert_eq!(visual_raw.cols, cfg.n_visual);
        assert_eq!(proprio.len(), cfg.d_proprio);
        assert!(instr_id < cfg.vocab);

        // Vision encoder. Every weight product below goes through the
        // linear()/linear_vec() dispatch, so PTQ-committed packed layers
        // execute on the 1-bit kernels with no dequantization here.
        let mut xv = linear(&self.store, "vis.embed", visual_raw);
        rmsnorm_cols(&mut xv);
        for b in 0..cfg.vision_blocks {
            xv = block_forward(&self.store, &format!("vis.{b}"), cfg.heads, &xv, hook);
        }

        // Projector.
        if let Some(h) = hook {
            h("proj", &xv);
        }
        let mut xp = linear(&self.store, "proj", &xv);
        rmsnorm_cols(&mut xp);

        // Assemble the LM sequence: [visual | instruction | proprio].
        let n = cfg.seq_len();
        let dm = cfg.d_model;
        let mut seq = Matrix::zeros(dm, n);
        for t in 0..cfg.n_visual {
            for i in 0..dm {
                seq.set(i, t, xp.at(i, t));
            }
        }
        let instr = self.store.get("lm.embed_instr");
        for i in 0..dm {
            seq.set(i, cfg.n_visual, instr.at(i, instr_id));
        }
        let pvec = linear_vec(&self.store, "lm.embed_proprio", proprio);
        for i in 0..dm {
            seq.set(i, cfg.n_visual + 1, pvec[i]);
        }
        rmsnorm_cols(&mut seq);

        for b in 0..cfg.lm_blocks {
            seq = crate::model::layers::block_forward_norm(
                &self.store,
                &format!("lm.{b}"),
                cfg.heads,
                &seq,
                hook,
                true,
            );
        }

        // Readout: LM output at the instruction token ⊕ raw proprio,
        // duplicated with a held gate so a linear head can mode-switch.
        let held = proprio[3];
        let mut base = Vec::with_capacity(dm + cfg.d_proprio);
        for i in 0..dm {
            base.push(seq.at(i, cfg.n_visual));
        }
        base.extend_from_slice(proprio);
        let mut feat = Vec::with_capacity(2 * base.len());
        feat.extend_from_slice(&base);
        feat.extend(base.iter().map(|&v| held * v));
        feat
    }

    /// Batched trunk forward: run `batch.len()` requests through ONE pass
    /// of the encoder stack by concatenating their token sequences
    /// column-wise, so every quantizable weight product becomes a single
    /// wide GEMM — on packed layers, the row-parallel multi-token packed
    /// kernel of [`crate::quant::packed::PackedBits::matmul`] sweeping all
    /// coalesced requests per sign-word fetch. Attention stays
    /// segment-local (requests never attend to each other).
    ///
    /// Parity guarantee: element `r` of the result is bit-identical to
    /// `self.features(batch[r].visual_raw, batch[r].instr_id,
    /// batch[r].proprio, &mut None)` — every kernel on this path (dense
    /// ikj GEMM, packed per-token-group-sum GEMM, column RMS-norm,
    /// per-segment softmax) computes output columns independently and in
    /// the same operation order as the single-request path. The batched
    /// server's per-request answers therefore don't depend on which
    /// requests happened to be coalesced together.
    pub fn features_batch(&self, batch: &[ObsInput]) -> Vec<Vec<f32>> {
        let cfg = &self.cfg;
        if batch.is_empty() {
            return Vec::new();
        }
        for o in batch {
            assert_eq!(o.visual_raw.rows, cfg.d_vis_in);
            assert_eq!(o.visual_raw.cols, cfg.n_visual);
            assert_eq!(o.proprio.len(), cfg.d_proprio);
            assert!(o.instr_id < cfg.vocab);
        }

        // Vision encoder over the concatenated visual tokens.
        let visuals: Vec<&Matrix> = batch.iter().map(|o| o.visual_raw).collect();
        let x0 = Matrix::hcat(&visuals);
        let mut xv = linear(&self.store, "vis.embed", &x0);
        rmsnorm_cols(&mut xv);
        for b in 0..cfg.vision_blocks {
            let p = format!("vis.{b}");
            xv = block_forward_batch(&self.store, &p, cfg.heads, &xv, cfg.n_visual, true);
        }

        // Projector (fully batched).
        let mut xp = linear(&self.store, "proj", &xv);
        rmsnorm_cols(&mut xp);

        // Assemble every request's LM sequence [visual | instruction |
        // proprio] side by side.
        let n = cfg.seq_len();
        let dm = cfg.d_model;
        let mut seq = Matrix::zeros(dm, batch.len() * n);
        let instr = self.store.get("lm.embed_instr");
        for (r, o) in batch.iter().enumerate() {
            let c0 = r * n;
            for t in 0..cfg.n_visual {
                for i in 0..dm {
                    seq.set(i, c0 + t, xp.at(i, r * cfg.n_visual + t));
                }
            }
            for i in 0..dm {
                seq.set(i, c0 + cfg.n_visual, instr.at(i, o.instr_id));
            }
            let pvec = linear_vec(&self.store, "lm.embed_proprio", o.proprio);
            for i in 0..dm {
                seq.set(i, c0 + cfg.n_visual + 1, pvec[i]);
            }
        }
        rmsnorm_cols(&mut seq);

        for b in 0..cfg.lm_blocks {
            seq = block_forward_batch(&self.store, &format!("lm.{b}"), cfg.heads, &seq, n, true);
        }

        // Per-request readout, as in `features`.
        batch
            .iter()
            .enumerate()
            .map(|(r, o)| {
                let held = o.proprio[3];
                let mut base = Vec::with_capacity(dm + cfg.d_proprio);
                for i in 0..dm {
                    base.push(seq.at(i, r * n + cfg.n_visual));
                }
                base.extend_from_slice(o.proprio);
                let mut feat = Vec::with_capacity(2 * base.len());
                feat.extend_from_slice(&base);
                feat.extend(base.iter().map(|&v| held * v));
                feat
            })
            .collect()
    }

    /// Apply the head's fixed tanh expansion: [f | tanh(W_e f)] — the
    /// action head's MLP nonlinearity (ridge fits the layer on top) —
    /// followed by the BC-fit standardization (head.norm).
    pub fn head_features(&self, feat: &[f32]) -> Vec<f32> {
        let h = linear_vec(&self.store, "head.expand", feat);
        let mut out = Vec::with_capacity(feat.len() + h.len());
        out.extend_from_slice(feat);
        out.extend(h.iter().map(|v| v.tanh()));
        let norm = self.store.get("head.norm");
        for (j, v) in out.iter_mut().enumerate() {
            *v = (*v - norm.at(0, j)) / norm.at(1, j).max(1e-4);
        }
        out
    }

    /// Decode an action chunk from features. Every head returns
    /// `chunk_len()` consecutive actions (Token/Diffusion heads return a
    /// single action). `rng` drives the diffusion head's initial noise.
    pub fn decode(&self, trunk_feat: &[f32], rng: &mut Rng) -> Vec<Vec<f32>> {
        let feat = &self.head_features(trunk_feat);
        let cfg = &self.cfg;
        match cfg.head {
            HeadKind::Chunk => {
                let out = linear_vec(&self.store, "head.main", feat);
                (0..cfg.chunk)
                    .map(|c| {
                        (0..cfg.act_dim)
                            .map(|d| out[c * cfg.act_dim + d].clamp(-1.0, 1.0))
                            .collect()
                    })
                    .collect()
            }
            HeadKind::Token => {
                // OpenVLA-style discrete action tokens: the head predicts a
                // continuous value per dim which is emitted as the nearest
                // of `bins` token centers — the discretization error of the
                // token interface is exactly what distinguishes OpenVLA
                // from OFT's continuous chunks in the paper's tables.
                let pred = linear_vec(&self.store, "head.main", feat);
                let mut a = Vec::with_capacity(cfg.act_dim);
                for d in 0..cfg.act_dim {
                    let v = pred[d].clamp(-1.0, 1.0);
                    let b = (((v + 1.0) / 2.0 * cfg.bins as f32) as usize).min(cfg.bins - 1);
                    a.push(-1.0 + 2.0 * (b as f32 + 0.5) / cfg.bins as f32);
                }
                vec![a]
            }
            HeadKind::Diffusion => {
                let mut a: Vec<f32> = (0..cfg.act_dim).map(|_| rng.gauss() as f32).collect();
                let mut zin = vec![0.0f32; cfg.act_dim + feat.len() + 1];
                for t in (0..cfg.diffusion_steps).rev() {
                    zin[..cfg.act_dim].copy_from_slice(&a);
                    zin[cfg.act_dim..cfg.act_dim + feat.len()].copy_from_slice(feat);
                    zin[cfg.act_dim + feat.len()] = 1.0;
                    a = linear_vec(&self.store, &format!("head.diff.{t}"), &zin);
                }
                vec![a.into_iter().map(|v| v.clamp(-1.0, 1.0)).collect()]
            }
        }
    }

    /// Batched [`Self::head_features`]: stack the trunk features as
    /// columns and run the tanh expansion through one GEMM. Returns the
    /// head-input matrix (head_in_dim × batch).
    fn head_features_batch(&self, feats: &[Vec<f32>]) -> Matrix {
        let fd = self.cfg.feat_dim();
        let hd = self.cfg.head_in_dim();
        let mut f = Matrix::zeros(fd, feats.len());
        for (c, v) in feats.iter().enumerate() {
            assert_eq!(v.len(), fd, "trunk feature dim mismatch");
            for (i, &x) in v.iter().enumerate() {
                f.set(i, c, x);
            }
        }
        let h = linear(&self.store, "head.expand", &f);
        let norm = self.store.get("head.norm");
        let mut out = Matrix::zeros(hd, feats.len());
        for c in 0..feats.len() {
            for i in 0..fd {
                out.set(i, c, f.at(i, c));
            }
            for i in 0..h.rows {
                out.set(fd + i, c, h.at(i, c).tanh());
            }
            for j in 0..hd {
                let v = out.at(j, c);
                out.set(j, c, (v - norm.at(0, j)) / norm.at(1, j).max(1e-4));
            }
        }
        out
    }

    /// Batched [`Self::decode`]: every head matmul runs once over the whole
    /// batch (packed heads execute the multi-token packed GEMM). `rngs`
    /// holds one noise stream per request (diffusion head); request `r`
    /// draws exactly what `decode(&feats[r], &mut rngs[r])` would.
    ///
    /// On a store whose head layers are packed, the returned actions are
    /// bit-identical to per-request [`Self::decode`] calls: the packed
    /// GEMV and multi-token GEMM share one accumulation order. (Dense f32
    /// heads differ by float-summation-order noise only — the GEMV kernel
    /// unrolls four accumulators, the GEMM accumulates in ikj order.)
    pub fn decode_batch(&self, feats: &[Vec<f32>], rngs: &mut [Rng]) -> Vec<Vec<Vec<f32>>> {
        assert_eq!(feats.len(), rngs.len(), "one rng stream per request");
        if feats.is_empty() {
            return Vec::new();
        }
        let cfg = &self.cfg;
        let hf = self.head_features_batch(feats);
        let nb = feats.len();
        match cfg.head {
            HeadKind::Chunk => {
                let out = linear(&self.store, "head.main", &hf);
                (0..nb)
                    .map(|r| {
                        (0..cfg.chunk)
                            .map(|c| {
                                (0..cfg.act_dim)
                                    .map(|d| out.at(c * cfg.act_dim + d, r).clamp(-1.0, 1.0))
                                    .collect()
                            })
                            .collect()
                    })
                    .collect()
            }
            HeadKind::Token => {
                let pred = linear(&self.store, "head.main", &hf);
                (0..nb)
                    .map(|r| {
                        let mut a = Vec::with_capacity(cfg.act_dim);
                        for d in 0..cfg.act_dim {
                            let v = pred.at(d, r).clamp(-1.0, 1.0);
                            let b = (((v + 1.0) / 2.0 * cfg.bins as f32) as usize).min(cfg.bins - 1);
                            a.push(-1.0 + 2.0 * (b as f32 + 0.5) / cfg.bins as f32);
                        }
                        vec![a]
                    })
                    .collect()
            }
            HeadKind::Diffusion => {
                let hd = cfg.head_in_dim();
                let mut a = Matrix::zeros(cfg.act_dim, nb);
                for (c, rng) in rngs.iter_mut().enumerate() {
                    for d in 0..cfg.act_dim {
                        a.set(d, c, rng.gauss() as f32);
                    }
                }
                // The conditioning rows (head features + bias) are constant
                // across denoising steps; only the action rows evolve.
                let mut zin = Matrix::zeros(cfg.act_dim + hd + 1, nb);
                for c in 0..nb {
                    for j in 0..hd {
                        zin.set(cfg.act_dim + j, c, hf.at(j, c));
                    }
                    zin.set(cfg.act_dim + hd, c, 1.0);
                }
                for t in (0..cfg.diffusion_steps).rev() {
                    for c in 0..nb {
                        for d in 0..cfg.act_dim {
                            zin.set(d, c, a.at(d, c));
                        }
                    }
                    a = linear(&self.store, &format!("head.diff.{t}"), &zin);
                }
                (0..nb)
                    .map(|r| vec![(0..cfg.act_dim).map(|d| a.at(d, r).clamp(-1.0, 1.0)).collect()])
                    .collect()
            }
        }
    }

    /// How many actions one decode yields.
    pub fn chunk_len(&self) -> usize {
        match self.cfg.head {
            HeadKind::Chunk => self.cfg.chunk,
            _ => 1,
        }
    }

    /// Convenience: features + decode in one call.
    pub fn act(
        &self,
        visual_raw: &Matrix,
        instr_id: usize,
        proprio: &[f32],
        rng: &mut Rng,
    ) -> Vec<Vec<f32>> {
        let feat = self.features(visual_raw, instr_id, proprio, &mut None);
        self.decode(&feat, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::VlaConfig;

    fn rand_obs(cfg: &VlaConfig, rng: &mut Rng) -> (Matrix, usize, Vec<f32>) {
        let v = Matrix::gauss(cfg.d_vis_in, cfg.n_visual, 1.0, rng);
        let p: Vec<f32> = (0..cfg.d_proprio).map(|_| rng.gauss() as f32).collect();
        (v, 3, p)
    }

    #[test]
    fn forward_shapes_all_heads() {
        for head in [HeadKind::Token, HeadKind::Chunk, HeadKind::Diffusion] {
            let cfg = VlaConfig::tiny(head);
            let m = MiniVla::new(cfg.clone());
            let mut rng = Rng::new(181);
            let (v, i, p) = rand_obs(&cfg, &mut rng);
            let feat = m.features(&v, i, &p, &mut None);
            assert_eq!(feat.len(), cfg.feat_dim());
            let acts = m.decode(&feat, &mut rng);
            assert_eq!(acts.len(), m.chunk_len());
            for a in &acts {
                assert_eq!(a.len(), cfg.act_dim);
                assert!(a.iter().all(|v| v.is_finite() && *v >= -1.0 && *v <= 1.0));
            }
        }
    }

    #[test]
    fn forward_is_deterministic() {
        let cfg = VlaConfig::tiny(HeadKind::Chunk);
        let m = MiniVla::new(cfg.clone());
        let mut rng = Rng::new(182);
        let (v, i, p) = rand_obs(&cfg, &mut rng);
        let f1 = m.features(&v, i, &p, &mut None);
        let f2 = m.features(&v, i, &p, &mut None);
        assert_eq!(f1, f2);
    }

    #[test]
    fn held_gate_duplicates_features() {
        let cfg = VlaConfig::tiny(HeadKind::Chunk);
        let m = MiniVla::new(cfg.clone());
        let mut rng = Rng::new(183);
        let (v, i, mut p) = rand_obs(&cfg, &mut rng);
        p[3] = 0.0; // not held
        let f0 = m.features(&v, i, &p, &mut None);
        let half = f0.len() / 2;
        assert!(f0[half..].iter().all(|&x| x == 0.0));
        p[3] = 1.0; // held
        let f1 = m.features(&v, i, &p, &mut None);
        // held copies: second half equals first half (proprio differs in
        // the held flag itself, so compare the LM part only).
        for k in 0..cfg.d_model {
            assert!((f1[half + k] - f1[k]).abs() < 1e-6);
        }
    }

    #[test]
    fn grounding_attends_to_target_object() {
        // Token with the instruction's target content code should dominate
        // the block-0 attention from the instruction token.
        let cfg = VlaConfig::tiny(HeadKind::Chunk);
        let m = MiniVla::new(cfg.clone());
        let codes = content_codes();
        // Visual raw: slot 2 carries content id 5; others id 0..
        let mut v = Matrix::zeros(cfg.d_vis_in, cfg.n_visual);
        for t in 0..cfg.n_visual {
            let id = if t == 2 { 5 } else { 0 };
            for k in 0..8 {
                v.set(k, t, codes.at(id, k));
            }
            v.set(8, t, 0.1 * t as f32); // positions
            v.set(9, t, 0.2);
        }
        let p = vec![0.0f32; cfg.d_proprio];
        let instr = instr_index(5, 0);
        // Features must differ strongly when the target moves.
        let f_a = m.features(&v, instr, &p, &mut None);
        let mut v2 = v.clone();
        v2.set(8, 2, 0.9); // move target object
        let f_b = m.features(&v2, instr, &p, &mut None);
        let mut v3 = v.clone();
        v3.set(8, 4, 0.9); // move a distractor instead
        let f_c = m.features(&v3, instr, &p, &mut None);
        let d = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f32>().sqrt()
        };
        assert!(
            d(&f_a, &f_b) > 2.0 * d(&f_a, &f_c),
            "target move {} should outweigh distractor move {}",
            d(&f_a, &f_b),
            d(&f_a, &f_c)
        );
    }

    #[test]
    fn quantizable_inventory_excludes_embeddings() {
        let cfg = VlaConfig::base(HeadKind::Chunk);
        let m = MiniVla::new(cfg);
        let q = m.store.quantizable_layers(None);
        assert!(!q.iter().any(|n| n.contains("embed")));
        assert!(q.iter().any(|n| n == "proj"));
        assert!(q.iter().any(|n| n.starts_with("lm.")));
        assert!(q.iter().any(|n| n.starts_with("vis.")));
        assert!(q.iter().any(|n| n.starts_with("head.")));
    }
}
