//! Parameter store — with per-layer weight *representations* — and the
//! structured MiniVLA weight generator.
//!
//! Every parameter holds a [`WeightRepr`]: either a dense f32 [`Matrix`]
//! or a [`PackedBits`] 1-bit container. PTQ methods commit packed
//! representations directly (see [`crate::coordinator::scheduler`]), and
//! the forward pass dispatches per-layer through
//! [`crate::model::layers::linear`], so serving and rollouts execute on
//! the packed form with no dequantization on the hot path
//! (DESIGN.md §Hardware-Adaptation).
//!
//! Weights are *constructed*, not gradient-trained: the trunk is a
//! random-feature transformer whose grounding attention (instruction ↔
//! visual content matching) is built analytically from shared low-rank
//! factors, and whose readout layers are ridge-fit on expert
//! demonstrations ([`crate::train::bc`]). DESIGN.md §1 documents why this
//! substitution preserves the behaviours under study. Three structural
//! properties mirror real VLA checkpoints and drive the quantizers:
//!
//! 1. **modality column structure** — input channels belong to irregularly
//!    interleaved channel groups with distinct mean levels (what the
//!    permutation + Haar transform exploits);
//! 2. **row offsets** — per-output-row mean shifts (what sign-only
//!    binarization cannot represent);
//! 3. **low-rank semantic factors** — the grounding projections are
//!    rank-8 + noise (salient columns that Hessian-aware selection must
//!    protect).

use std::borrow::Cow;
use std::collections::HashMap;
use std::io::{Read, Write};

use crate::methods::traits::Component;
use crate::quant::packed::{ActPrecision, ActScaleMode, AttnPrecision, PackedBits};
use crate::quant::transform::TransformPacked;
use crate::tensor::matrix::Matrix;
use crate::util::rng::Rng;

/// Channel-layout constants shared between the model and the sim
/// featurizer (see `sim/observe.rs`).
pub mod channels {
    /// Content-code subspace (object identity embeddings).
    pub const CONTENT: std::ops::Range<usize> = 0..8;
    /// Secondary content slot (goal code in instruction embeddings).
    pub const GOAL: std::ops::Range<usize> = 8..16;
    /// Position (x, y).
    pub const POS: std::ops::Range<usize> = 16..18;
    /// Extra geometry (openness, held flag).
    pub const EXTRA: std::ops::Range<usize> = 18..20;
    /// Appearance features start here (outlier-prone).
    pub const APPEAR_START: usize = 20;

    /// Instruction-target code channels: the TOP 8 channels of the LM
    /// width. Visual tokens carry (near-)zero here — the projector's
    /// mixing rows stop below this band — so the grounding query (from
    /// this band) cannot self-match the instruction token's key (from
    /// CONTENT, which the instruction embedding leaves zero).
    pub fn tgt_range(d_model: usize) -> std::ops::Range<usize> {
        d_model - 8..d_model
    }

    /// Raw visual-token layout (before the vision embed).
    pub const RAW_CONTENT: std::ops::Range<usize> = 0..8;
    pub const RAW_POS: std::ops::Range<usize> = 8..10;
    pub const RAW_EXTRA: std::ops::Range<usize> = 10..12;
    pub const RAW_APPEAR_START: usize = 12;
}

/// Per-layer weight representation: what the forward pass executes on.
#[derive(Clone, Debug)]
pub enum WeightRepr {
    /// Dense f32 master weights (FP layers, pre-quantization).
    Dense(Matrix),
    /// Packed 1-bit signs + per-group scales (possibly with residual
    /// bitplanes re-packing a reconstruction) — the approximate deploy
    /// representation.
    Packed(PackedBits),
    /// Transform-domain exact representation: the Haar-domain plane the
    /// method committed plus permutation + salient side-channel; the
    /// forward executes y = C·haar(Pᵀx) — exact, zero residual planes.
    TransformPacked(TransformPacked),
}

impl WeightRepr {
    /// (rows, cols) of the underlying matrix.
    pub fn dims(&self) -> (usize, usize) {
        match self {
            WeightRepr::Dense(m) => (m.rows, m.cols),
            WeightRepr::Packed(p) => (p.rows, p.cols),
            WeightRepr::TransformPacked(t) => t.dims(),
        }
    }

    /// Bytes this representation actually keeps resident.
    pub fn resident_bytes(&self) -> usize {
        match self {
            WeightRepr::Dense(m) => m.rows * m.cols * 4,
            WeightRepr::Packed(p) => p.storage_bytes(),
            WeightRepr::TransformPacked(t) => t.storage_bytes(),
        }
    }

    /// Whether the layer executes on 1-bit sign planes (either the
    /// repacked or the transform-exact form).
    pub fn is_packed(&self) -> bool {
        matches!(self, WeightRepr::Packed(_) | WeightRepr::TransformPacked(_))
    }

    /// Specifically the transform-domain exact form.
    pub fn is_transform_packed(&self) -> bool {
        matches!(self, WeightRepr::TransformPacked(_))
    }
}

/// One named parameter.
#[derive(Clone, Debug)]
pub struct Param {
    pub name: String,
    pub component: Component,
    pub repr: WeightRepr,
    /// Whether PTQ methods may quantize this matrix (embeddings and
    /// norm-adjacent vectors are kept FP, as in the paper's setup).
    pub quantizable: bool,
    /// Calibrated static activation scale for the W1A8 path
    /// ([`crate::calib::scales`] pins it; for transform-exact layers the
    /// scale is over the TRANSFORMED z). `None` until a calibration pass
    /// runs. Serialized (format v4) — it is a checkpoint artifact like
    /// the weights, unlike the runtime [`ActScaleMode`] policy that
    /// decides whether it is USED.
    pub static_act_scale: Option<f32>,
}

/// Named parameter store with component tags — the unit the coordinator's
/// layer-parallel PTQ scheduler operates on.
#[derive(Clone, Debug, Default)]
pub struct ParamStore {
    params: Vec<Param>,
    index: HashMap<String, usize>,
    /// Activation precision the packed layers execute at
    /// ([`crate::quant::packed::ActPrecision`]) — a store-level runtime
    /// policy, so the `model::layers::linear`/`linear_vec` dispatch picks
    /// it up with no call-site changes. Not serialized: checkpoints carry
    /// weights, the serving/eval drivers choose the execution precision.
    act_precision: ActPrecision,
    /// How the W1A8 kernels obtain activation scales
    /// ([`ActScaleMode`]): per-token max sweeps, or the calibrated
    /// static per-layer scales held on each [`Param`]. Runtime policy
    /// like `act_precision` — not serialized (the SCALES are).
    act_scale_mode: ActScaleMode,
    /// Precision of the attention core ([`AttnPrecision`]): f32, or
    /// per-token INT8 scores + context GEMM. Runtime policy like
    /// `act_precision` — `model::layers::attn_forward_seg` reads it with
    /// no call-site changes, and it is not serialized.
    attn_precision: AttnPrecision,
    /// Thread budget the packed kernels may fan out over through the
    /// `model::layers` dispatch. 0 (the default) means "use the machine
    /// default" ([`crate::util::threadpool::default_threads`]); drivers
    /// honoring a `--threads` budget pin it here so every GEMM/GEMV the
    /// model executes respects it. Runtime policy, not serialized.
    exec_threads: usize,
}

impl ParamStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: &str, component: Component, quantizable: bool, m: Matrix) {
        self.insert_repr(name, component, quantizable, WeightRepr::Dense(m));
    }

    fn insert_repr(
        &mut self,
        name: &str,
        component: Component,
        quantizable: bool,
        repr: WeightRepr,
    ) {
        assert!(!self.index.contains_key(name), "duplicate param {name}");
        self.index.insert(name.to_string(), self.params.len());
        self.params.push(Param {
            name: name.to_string(),
            component,
            repr,
            quantizable,
            static_act_scale: None,
        });
    }

    fn idx(&self, name: &str) -> usize {
        *self.index.get(name).unwrap_or_else(|| panic!("missing param {name}"))
    }

    /// The representation the forward pass dispatches on.
    pub fn repr(&self, name: &str) -> &WeightRepr {
        &self.params[self.idx(name)].repr
    }

    /// Dense master weights. Panics for packed layers — quantizers and
    /// calibration only ever read the FP source model; execution paths
    /// must go through [`Self::repr`] / [`crate::model::layers::linear`].
    pub fn get(&self, name: &str) -> &Matrix {
        match self.repr(name) {
            WeightRepr::Dense(m) => m,
            WeightRepr::Packed(_) | WeightRepr::TransformPacked(_) => {
                panic!("param {name} is packed; use repr()/dense_view() instead of get()")
            }
        }
    }

    /// Dense view of any representation: borrows dense weights, or
    /// dequantizes packed ones into an owned copy (cold paths only —
    /// export, diffing, tests).
    pub fn dense_view(&self, name: &str) -> Cow<'_, Matrix> {
        match self.repr(name) {
            WeightRepr::Dense(m) => Cow::Borrowed(m),
            WeightRepr::Packed(p) => Cow::Owned(p.dequantize()),
            WeightRepr::TransformPacked(t) => Cow::Owned(t.dequantize()),
        }
    }

    /// (rows, cols) of a parameter regardless of representation.
    pub fn dims(&self, name: &str) -> (usize, usize) {
        self.repr(name).dims()
    }

    pub fn is_packed(&self, name: &str) -> bool {
        self.repr(name).is_packed()
    }

    pub fn is_transform_packed(&self, name: &str) -> bool {
        self.repr(name).is_transform_packed()
    }

    pub fn set(&mut self, name: &str, m: Matrix) {
        let i = self.idx(name);
        let old = self.params[i].repr.dims();
        assert_eq!(old, (m.rows, m.cols), "shape change for {name}");
        self.params[i].repr = WeightRepr::Dense(m);
    }

    /// Commit a packed 1-bit representation for a layer.
    pub fn set_packed(&mut self, name: &str, p: PackedBits) {
        let i = self.idx(name);
        let old = self.params[i].repr.dims();
        assert_eq!(old, (p.rows, p.cols), "shape change for {name}");
        self.params[i].repr = WeightRepr::Packed(p);
    }

    /// Commit a transform-domain exact representation for a layer.
    pub fn set_transform_packed(&mut self, name: &str, t: TransformPacked) {
        let i = self.idx(name);
        let old = self.params[i].repr.dims();
        assert_eq!(old, t.dims(), "shape change for {name}");
        self.params[i].repr = WeightRepr::TransformPacked(t);
    }

    pub fn set_repr(&mut self, name: &str, repr: WeightRepr) {
        match repr {
            WeightRepr::Dense(m) => self.set(name, m),
            WeightRepr::Packed(p) => self.set_packed(name, p),
            WeightRepr::TransformPacked(t) => self.set_transform_packed(name, t),
        }
    }

    pub fn contains(&self, name: &str) -> bool {
        self.index.contains_key(name)
    }

    /// Activation precision the packed-layer dispatch executes at.
    pub fn act_precision(&self) -> ActPrecision {
        self.act_precision
    }

    /// Set the activation precision for every packed layer in this store
    /// (dense layers are unaffected). Takes effect on the next forward —
    /// no repack, the sign planes and (α, μ) scales are shared by both
    /// kernels.
    pub fn set_act_precision(&mut self, p: ActPrecision) {
        self.act_precision = p;
    }

    /// Activation-scale policy the W1A8 dispatch reads.
    pub fn act_scale_mode(&self) -> ActScaleMode {
        self.act_scale_mode
    }

    /// Set the activation-scale policy (takes effect on the next
    /// forward; no repack, no scale recomputation).
    pub fn set_act_scale_mode(&mut self, m: ActScaleMode) {
        self.act_scale_mode = m;
    }

    /// Precision the attention core executes at.
    pub fn attn_precision(&self) -> AttnPrecision {
        self.attn_precision
    }

    /// Set the attention-core precision (takes effect on the next
    /// forward; attention has no packed weights, so nothing to repack).
    /// Note [`Self::set_act_precision`] deliberately does NOT touch this:
    /// the store-level knobs are independent — the `MiniVla` builder is
    /// where `*-a8` variants inherit INT8 attention.
    pub fn set_attn_precision(&mut self, p: AttnPrecision) {
        self.attn_precision = p;
    }

    /// Record a calibrated static activation scale for a layer (must be
    /// positive — non-positive calibration results are rejected so the
    /// kernels never divide by zero).
    pub fn set_static_act_scale(&mut self, name: &str, scale: f32) {
        assert!(scale > 0.0 && scale.is_finite(), "bad static scale {scale} for {name}");
        let i = self.idx(name);
        self.params[i].static_act_scale = Some(scale);
    }

    /// The calibrated static scale recorded for a layer, if any.
    pub fn static_act_scale(&self, name: &str) -> Option<f32> {
        self.params[self.idx(name)].static_act_scale
    }

    /// The static scale the W1A8 kernels should USE for this layer right
    /// now: `Some` only under [`ActScaleMode::Static`] AND when a
    /// calibrated scale exists (uncalibrated layers fall back to
    /// per-token, so a partially calibrated store still serves). This is
    /// the one accessor the `model::layers` dispatch reads.
    pub fn active_static_scale(&self, name: &str) -> Option<f32> {
        match self.act_scale_mode {
            ActScaleMode::PerToken => None,
            ActScaleMode::Static => self.static_act_scale(name),
        }
    }

    /// How many layers hold a calibrated static scale.
    pub fn static_scale_count(&self) -> usize {
        self.params.iter().filter(|p| p.static_act_scale.is_some()).count()
    }

    /// The thread budget the kernel dispatch should use: the pinned
    /// `--threads`-style budget when set, else the machine default.
    pub fn exec_threads(&self) -> usize {
        if self.exec_threads == 0 {
            crate::util::threadpool::default_threads()
        } else {
            self.exec_threads
        }
    }

    /// Pin the kernel thread budget (0 restores the machine default).
    pub fn set_exec_threads(&mut self, threads: usize) {
        self.exec_threads = threads;
    }

    pub fn params(&self) -> &[Param] {
        &self.params
    }

    /// Names of quantizable layers, optionally filtered to a component set.
    pub fn quantizable_layers(&self, components: Option<&[Component]>) -> Vec<String> {
        self.params
            .iter()
            .filter(|p| p.quantizable)
            .filter(|p| components.map(|cs| cs.contains(&p.component)).unwrap_or(true))
            .map(|p| p.name.clone())
            .collect()
    }

    pub fn component_of(&self, name: &str) -> Component {
        self.params[self.idx(name)].component
    }

    pub fn total_weights(&self) -> usize {
        self.params
            .iter()
            .map(|p| {
                let (r, c) = p.repr.dims();
                r * c
            })
            .sum()
    }

    /// Bytes the store actually keeps resident (packed layers at their
    /// sign-bitplane + f32 scale-metadata size, dense layers at f32).
    pub fn resident_weight_bytes(&self) -> usize {
        self.params.iter().map(|p| p.repr.resident_bytes()).sum()
    }

    /// Bytes an all-dense f32 store of the same shapes would take.
    pub fn dense_weight_bytes(&self) -> usize {
        self.total_weights() * 4
    }

    pub fn packed_layer_count(&self) -> usize {
        self.params.iter().filter(|p| p.repr.is_packed()).count()
    }

    /// Pack every quantizable dense layer in place (plain group
    /// binarization — the RTN deploy form). Returns how many layers were
    /// packed. Used by deploy tooling, perf drivers and parity tests.
    pub fn pack_quantizable(&mut self, group_size: usize) -> usize {
        let mut n = 0;
        for p in self.params.iter_mut() {
            if !p.quantizable {
                continue;
            }
            if let WeightRepr::Dense(w) = &p.repr {
                p.repr = WeightRepr::Packed(PackedBits::pack(w, group_size));
                n += 1;
            }
        }
        n
    }

    /// Replace every packed representation with its dense dequantization
    /// (the "dense twin" of a packed model — setup-time tool for parity
    /// tests and perf baselines, never the serve path).
    pub fn dequantize_all(&mut self) -> usize {
        let mut n = 0;
        for p in self.params.iter_mut() {
            match &p.repr {
                WeightRepr::Packed(pb) => {
                    p.repr = WeightRepr::Dense(pb.dequantize());
                    n += 1;
                }
                WeightRepr::TransformPacked(t) => {
                    p.repr = WeightRepr::Dense(t.dequantize());
                    n += 1;
                }
                WeightRepr::Dense(_) => {}
            }
        }
        n
    }

    /// Layers committed in the transform-domain exact representation.
    pub fn transform_packed_layer_count(&self) -> usize {
        self.params.iter().filter(|p| p.repr.is_transform_packed()).count()
    }

    /// Serialize to a binary format (magic, count, then per-param: name,
    /// component byte, quantizable byte, [v4+] static-act-scale field,
    /// repr tag, payload). Dense layers store rows/cols + f32 LE data;
    /// packed layers store the full bitplane chain bit-exactly
    /// ([`PackedBits::write_to`]); transform-packed layers (tag 2, v3+)
    /// store permutation + salient side-channel + the Haar-domain plane
    /// bit-exactly ([`TransformPacked::write_to`]). Format v4
    /// (`HBVLAPS4`) adds one per-param field: a presence byte + f32 LE
    /// calibrated static activation scale. v1/v2/v3 stores still load
    /// (scales default to `None`); v4 is always written.
    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(b"HBVLAPS4")?;
        f.write_all(&(self.params.len() as u32).to_le_bytes())?;
        for p in &self.params {
            let nb = p.name.as_bytes();
            f.write_all(&(nb.len() as u32).to_le_bytes())?;
            f.write_all(nb)?;
            let comp = match p.component {
                Component::Vision => 0u8,
                Component::Projector => 1,
                Component::Language => 2,
                Component::ActionHead => 3,
            };
            f.write_all(&[comp, p.quantizable as u8])?;
            match p.static_act_scale {
                Some(s) => {
                    f.write_all(&[1u8])?;
                    f.write_all(&s.to_le_bytes())?;
                }
                None => f.write_all(&[0u8])?,
            }
            match &p.repr {
                WeightRepr::Dense(m) => {
                    f.write_all(&[0u8])?;
                    f.write_all(&(m.rows as u32).to_le_bytes())?;
                    f.write_all(&(m.cols as u32).to_le_bytes())?;
                    for v in &m.data {
                        f.write_all(&v.to_le_bytes())?;
                    }
                }
                WeightRepr::Packed(pb) => {
                    f.write_all(&[1u8])?;
                    pb.write_to(&mut f)?;
                }
                WeightRepr::TransformPacked(t) => {
                    f.write_all(&[2u8])?;
                    t.write_to(&mut f)?;
                }
            }
        }
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> std::io::Result<Self> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        // Version gates: v1 has no repr tag (all dense), v2 adds tags 0/1
        // (dense/packed), v3 adds tag 2 (transform-packed), v4 adds the
        // per-param calibrated static activation scale.
        let version = match &magic {
            b"HBVLAPS4" => 4u8,
            b"HBVLAPS3" => 3,
            b"HBVLAPS2" => 2,
            b"HBVLAPS1" => 1,
            _ => return Err(std::io::Error::new(std::io::ErrorKind::InvalidData, "bad magic")),
        };
        let mut u32buf = [0u8; 4];
        f.read_exact(&mut u32buf)?;
        let count = u32::from_le_bytes(u32buf) as usize;
        let mut store = ParamStore::new();
        for _ in 0..count {
            f.read_exact(&mut u32buf)?;
            let nlen = u32::from_le_bytes(u32buf) as usize;
            let mut nb = vec![0u8; nlen];
            f.read_exact(&mut nb)?;
            let name = String::from_utf8(nb)
                .map_err(|_| std::io::Error::new(std::io::ErrorKind::InvalidData, "bad name"))?;
            let mut two = [0u8; 2];
            f.read_exact(&mut two)?;
            let component = match two[0] {
                0 => Component::Vision,
                1 => Component::Projector,
                2 => Component::Language,
                _ => Component::ActionHead,
            };
            let quantizable = two[1] != 0;
            let static_act_scale = if version >= 4 {
                let mut has = [0u8; 1];
                f.read_exact(&mut has)?;
                match has[0] {
                    0 => None,
                    1 => {
                        let mut sb = [0u8; 4];
                        f.read_exact(&mut sb)?;
                        let s = f32::from_le_bytes(sb);
                        if !(s > 0.0 && s.is_finite()) {
                            return Err(std::io::Error::new(
                                std::io::ErrorKind::InvalidData,
                                "bad static act scale",
                            ));
                        }
                        Some(s)
                    }
                    _ => {
                        return Err(std::io::Error::new(
                            std::io::ErrorKind::InvalidData,
                            "bad static-scale presence byte",
                        ))
                    }
                }
            } else {
                None
            };
            let tag = if version >= 2 {
                let mut t = [0u8; 1];
                f.read_exact(&mut t)?;
                t[0]
            } else {
                0
            };
            if tag == 2 && version < 3 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    "transform repr tag in pre-v3 store",
                ));
            }
            match tag {
                0 => {
                    f.read_exact(&mut u32buf)?;
                    let rows = u32::from_le_bytes(u32buf) as usize;
                    f.read_exact(&mut u32buf)?;
                    let cols = u32::from_le_bytes(u32buf) as usize;
                    let mut data = vec![0f32; rows * cols];
                    let mut fbuf = [0u8; 4];
                    for v in data.iter_mut() {
                        f.read_exact(&mut fbuf)?;
                        *v = f32::from_le_bytes(fbuf);
                    }
                    store.insert(&name, component, quantizable, Matrix::from_vec(rows, cols, data));
                }
                1 => {
                    let pb = PackedBits::read_from(&mut f)?;
                    store.insert_repr(&name, component, quantizable, WeightRepr::Packed(pb));
                }
                2 => {
                    let t = TransformPacked::read_from(&mut f)?;
                    store.insert_repr(&name, component, quantizable, WeightRepr::TransformPacked(t));
                }
                _ => {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::InvalidData,
                        "bad repr tag",
                    ))
                }
            }
            if let Some(s) = static_act_scale {
                store.set_static_act_scale(&name, s);
            }
        }
        Ok(store)
    }
}

/// Continuous residue fraction of the weight lattice: the part of each
/// weight that no 1-bit representation can capture. This is the
/// degradation-margin knob of the synthetic checkpoint (DESIGN.md §1):
/// real VLA weights are heavily quantization-compressible *given the right
/// structure model* (that is the premise of the paper), and ε controls how
/// much irreducible error every binarizer pays.
pub const WEIGHT_RESIDUE: f32 = 0.18;

/// Structured trunk-weight generator: a ±σ sign lattice (the information-
/// carrying random projection) plus irregular modality column levels plus
/// row offsets plus an ε·σ continuous residue.
pub fn structured_weight(
    rows: usize,
    cols: usize,
    gain: f32,
    structure: f32,
    rng: &mut Rng,
) -> Matrix {
    let sigma = gain / (cols as f32).sqrt();
    // Irregular modality grouping of input channels.
    let levels = [1.0f32, -1.0, 0.33, -0.33];
    let mut modality: Vec<usize> = (0..cols).map(|j| j % 4).collect();
    rng.shuffle(&mut modality);
    let col_mu: Vec<f32> = (0..cols).map(|j| structure * sigma * levels[modality[j]]).collect();
    let row_mu: Vec<f32> = (0..rows).map(|_| 0.5 * structure * sigma * rng.gauss() as f32).collect();
    Matrix::from_fn(rows, cols, |i, j| {
        col_mu[j] + row_mu[i] + sigma * rng.gauss() as f32
    })
}

/// Like [`structured_weight`] but the iid part is a ±σ sign lattice with
/// an ε·σ continuous residue (ε = [`WEIGHT_RESIDUE`]): the form a
/// structure-aware 1-bit quantizer can capture up to the residue. Used
/// for the language-backbone weights — the quantization subject of the
/// paper's main tables.
pub fn structured_weight_lattice(
    rows: usize,
    cols: usize,
    gain: f32,
    structure: f32,
    rng: &mut Rng,
) -> Matrix {
    let sigma = gain / (cols as f32).sqrt();
    let levels = [1.0f32, -1.0, 0.33, -0.33];
    let mut modality: Vec<usize> = (0..cols).map(|j| j % 4).collect();
    rng.shuffle(&mut modality);
    let col_mu: Vec<f32> = (0..cols).map(|j| structure * sigma * levels[modality[j]]).collect();
    let row_mu: Vec<f32> = (0..rows).map(|_| 0.5 * structure * sigma * rng.gauss() as f32).collect();
    Matrix::from_fn(rows, cols, |i, j| {
        let sign = if rng.flip(0.5) { 1.0 } else { -1.0 };
        col_mu[j] + row_mu[i] + sigma * (sign + WEIGHT_RESIDUE * rng.gauss() as f32)
    })
}

/// Binary-valued factor (±amp entries): factors of this form survive
/// sign-based 1-bit quantization with only the ε-residue lost.
pub fn binary_factor(rows: usize, cols: usize, amp: f32, rng: &mut Rng) -> Matrix {
    Matrix::from_fn(rows, cols, |_, _| if rng.flip(0.5) { amp } else { -amp })
}

/// Low-rank grounding projection: W = A · Sel(range) + ε·noise, where
/// Sel(range) selects `rank` input channels — queries/keys built from the
/// same A measure content-code agreement.
pub fn grounding_proj(
    rows: usize,
    cols: usize,
    range: std::ops::Range<usize>,
    a: &Matrix,
    noise: f32,
    rng: &mut Rng,
) -> Matrix {
    let rank = range.end - range.start;
    assert_eq!(a.rows, rows);
    assert_eq!(a.cols, rank);
    let sigma = noise / (cols as f32).sqrt();
    Matrix::from_fn(rows, cols, |i, j| {
        let structural = if range.contains(&j) { a.at(i, j - range.start) } else { 0.0 };
        structural + sigma * rng.gauss() as f32
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_roundtrip_through_disk() {
        let mut rng = Rng::new(161);
        let mut s = ParamStore::new();
        s.insert("a.w", Component::Vision, true, Matrix::gauss(4, 6, 1.0, &mut rng));
        s.insert("b.w", Component::ActionHead, false, Matrix::gauss(3, 3, 1.0, &mut rng));
        let dir = std::env::temp_dir().join("hbvla_test_store.bin");
        s.save(&dir).unwrap();
        let loaded = ParamStore::load(&dir).unwrap();
        assert_eq!(loaded.params().len(), 2);
        assert!(loaded.get("a.w").dist_sq(s.get("a.w")) < 1e-12);
        assert_eq!(loaded.component_of("b.w"), Component::ActionHead);
        assert_eq!(loaded.quantizable_layers(None), vec!["a.w".to_string()]);
        std::fs::remove_file(dir).ok();
    }

    #[test]
    fn packed_store_roundtrip_bit_exact() {
        let mut rng = Rng::new(166);
        let mut s = ParamStore::new();
        s.insert("q.w", Component::Language, true, Matrix::gauss(6, 70, 1.0, &mut rng));
        s.insert("fp.w", Component::Language, false, Matrix::gauss(4, 5, 1.0, &mut rng));
        assert_eq!(s.pack_quantizable(64), 1);
        assert!(s.is_packed("q.w"));
        assert!(!s.is_packed("fp.w"));
        let path = std::env::temp_dir().join("hbvla_test_packed_store.bin");
        s.save(&path).unwrap();
        let loaded = ParamStore::load(&path).unwrap();
        assert!(loaded.is_packed("q.w"));
        assert_eq!(loaded.packed_layer_count(), 1);
        let (d1, d2) = (s.dense_view("q.w"), loaded.dense_view("q.w"));
        assert_eq!(d1.data, d2.data, "packed round-trip must be bit-exact");
        assert_eq!(loaded.resident_weight_bytes(), s.resident_weight_bytes());
        assert!(loaded.resident_weight_bytes() < loaded.dense_weight_bytes());
        std::fs::remove_file(path).ok();
    }

    /// Build a transform-packed repr by the same pipeline HBVLA commits.
    fn sample_transform(rows: usize, cols: usize, rng: &mut Rng) -> TransformPacked {
        use crate::quant::permute::{pairing_and_chaining, permute_cols, NormKind};
        let w = Matrix::gauss(rows, cols, 1.0, rng);
        let pi = pairing_and_chaining(&w, None, NormKind::L2);
        let u = crate::haar::haar_rows(&permute_cols(&w, &pi));
        let bits = PackedBits::pack(&u, crate::quant::transform::transform_group_size(cols.div_ceil(2)));
        TransformPacked::new(cols, pi.iter().map(|&p| p as u32).collect(), bits, None)
    }

    #[test]
    fn transform_store_roundtrip_v3_bit_exact() {
        let mut rng = Rng::new(170);
        let mut s = ParamStore::new();
        s.insert("t.w", Component::Language, true, Matrix::gauss(6, 70, 1.0, &mut rng));
        s.insert("fp.w", Component::Language, false, Matrix::gauss(4, 5, 1.0, &mut rng));
        let t = sample_transform(6, 70, &mut rng);
        s.set_transform_packed("t.w", t);
        assert!(s.is_transform_packed("t.w"));
        assert!(s.is_packed("t.w"), "transform layers count as 1-bit committed");
        assert_eq!(s.transform_packed_layer_count(), 1);
        let path = std::env::temp_dir().join("hbvla_test_transform_store.bin");
        s.save(&path).unwrap();
        let loaded = ParamStore::load(&path).unwrap();
        assert!(loaded.is_transform_packed("t.w"));
        assert_eq!(
            loaded.dense_view("t.w").data,
            s.dense_view("t.w").data,
            "v3 round-trip must be bit-exact"
        );
        assert_eq!(loaded.resident_weight_bytes(), s.resident_weight_bytes());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn legacy_v1_and_v2_streams_still_load() {
        // Hand-rolled v2 store: magic, count=1, one packed param — the
        // byte layout PR 1 froze; v3 readers must keep accepting it.
        let mut rng = Rng::new(171);
        let w = Matrix::gauss(3, 64, 1.0, &mut rng);
        let pb = PackedBits::pack(&w, 64);
        let mut v2: Vec<u8> = Vec::new();
        v2.extend_from_slice(b"HBVLAPS2");
        v2.extend_from_slice(&1u32.to_le_bytes());
        v2.extend_from_slice(&3u32.to_le_bytes());
        v2.extend_from_slice(b"q.w");
        v2.extend_from_slice(&[2u8, 1u8, 1u8]); // Language, quantizable, tag=packed
        pb.write_to(&mut v2).unwrap();
        let path = std::env::temp_dir().join("hbvla_test_v2_store.bin");
        std::fs::write(&path, &v2).unwrap();
        let loaded = ParamStore::load(&path).unwrap();
        assert!(loaded.is_packed("q.w"));
        assert_eq!(loaded.dense_view("q.w").data, pb.dequantize().data);
        // v1: no repr tag, dense payload directly.
        let mut v1: Vec<u8> = Vec::new();
        v1.extend_from_slice(b"HBVLAPS1");
        v1.extend_from_slice(&1u32.to_le_bytes());
        v1.extend_from_slice(&3u32.to_le_bytes());
        v1.extend_from_slice(b"d.w");
        v1.extend_from_slice(&[0u8, 1u8]); // Vision, quantizable (no tag in v1)
        v1.extend_from_slice(&2u32.to_le_bytes());
        v1.extend_from_slice(&2u32.to_le_bytes());
        for v in [1.0f32, 2.0, 3.0, 4.0] {
            v1.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(&path, &v1).unwrap();
        let loaded = ParamStore::load(&path).unwrap();
        assert_eq!(loaded.get("d.w").data, vec![1.0, 2.0, 3.0, 4.0]);
        // A transform tag inside a v2 stream is corrupt, not silently read.
        let mut bad = v2.clone();
        let tag_pos = 8 + 4 + 4 + 3 + 2;
        bad[tag_pos] = 2;
        std::fs::write(&path, &bad).unwrap();
        assert!(ParamStore::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn pack_then_dequantize_all_round_trips_repr() {
        let mut rng = Rng::new(167);
        let mut s = ParamStore::new();
        s.insert("x.w", Component::Vision, true, Matrix::gauss(8, 32, 1.0, &mut rng));
        s.pack_quantizable(16);
        let packed_dense = s.dense_view("x.w").into_owned();
        assert_eq!(s.dequantize_all(), 1);
        assert!(!s.is_packed("x.w"));
        assert_eq!(s.get("x.w").data, packed_dense.data);
    }

    #[test]
    #[should_panic(expected = "is packed")]
    fn get_on_packed_param_panics() {
        let mut rng = Rng::new(168);
        let mut s = ParamStore::new();
        s.insert("p.w", Component::Language, true, Matrix::gauss(4, 16, 1.0, &mut rng));
        s.pack_quantizable(16);
        let _ = s.get("p.w");
    }

    #[test]
    fn act_precision_is_runtime_policy_not_weights() {
        let mut rng = Rng::new(169);
        let mut s = ParamStore::new();
        s.insert("p.w", Component::Language, true, Matrix::gauss(4, 64, 1.0, &mut rng));
        assert_eq!(s.act_precision(), ActPrecision::F32);
        s.pack_quantizable(64);
        s.set_act_precision(ActPrecision::Int8);
        assert_eq!(s.act_precision(), ActPrecision::Int8);
        // Serialization carries weights only: a reloaded store starts at
        // the F32 default, packed layers bit-exact.
        let path = std::env::temp_dir().join("hbvla_test_act_precision.bin");
        s.save(&path).unwrap();
        let loaded = ParamStore::load(&path).unwrap();
        assert_eq!(loaded.act_precision(), ActPrecision::F32);
        assert_eq!(loaded.dense_view("p.w").data, s.dense_view("p.w").data);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn attn_precision_is_independent_runtime_policy() {
        let mut s = ParamStore::new();
        assert_eq!(s.attn_precision(), AttnPrecision::F32);
        s.set_attn_precision(AttnPrecision::Int8);
        assert_eq!(s.attn_precision(), AttnPrecision::Int8);
        // The store-level activation knob does NOT drag attention along —
        // coupling lives in the MiniVla builder, so store-level tests and
        // tools can flip the linears' precision in isolation.
        s.set_act_precision(ActPrecision::Int8);
        s.set_act_precision(ActPrecision::F32);
        assert_eq!(s.attn_precision(), AttnPrecision::Int8);
    }

    #[test]
    fn static_scales_round_trip_v4_and_mode_gates_use() {
        let mut rng = Rng::new(172);
        let mut s = ParamStore::new();
        s.insert("a.w", Component::Language, true, Matrix::gauss(4, 64, 1.0, &mut rng));
        s.insert("b.w", Component::Language, true, Matrix::gauss(4, 64, 1.0, &mut rng));
        s.pack_quantizable(64);
        s.set_static_act_scale("a.w", 0.125);
        assert_eq!(s.static_scale_count(), 1);
        // Scales are stored regardless of mode; USE is gated by the mode,
        // and uncalibrated layers fall back to per-token (None).
        assert_eq!(s.active_static_scale("a.w"), None, "per-token mode ignores scales");
        s.set_act_scale_mode(ActScaleMode::Static);
        assert_eq!(s.active_static_scale("a.w"), Some(0.125));
        assert_eq!(s.active_static_scale("b.w"), None, "uncalibrated layer falls back");
        // v4 round-trips the scale bit-exactly; the MODE is runtime
        // policy and resets to the default.
        let path = std::env::temp_dir().join("hbvla_test_static_scale_store.bin");
        s.save(&path).unwrap();
        let loaded = ParamStore::load(&path).unwrap();
        assert_eq!(loaded.static_act_scale("a.w"), Some(0.125));
        assert_eq!(loaded.static_act_scale("b.w"), None);
        assert_eq!(loaded.act_scale_mode(), ActScaleMode::PerToken);
        assert_eq!(loaded.dense_view("a.w").data, s.dense_view("a.w").data);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn exec_threads_budget_defaults_and_pins() {
        let mut s = ParamStore::new();
        assert!(s.exec_threads() >= 1, "default budget must be usable");
        s.set_exec_threads(3);
        assert_eq!(s.exec_threads(), 3);
        s.set_exec_threads(0);
        assert!(s.exec_threads() >= 1, "0 restores the machine default");
    }

    #[test]
    fn legacy_v3_stream_still_loads_without_scales() {
        // Hand-rolled v3 store (the pre-static-scale byte layout PR 4
        // froze): magic, count=1, name, [comp, quantizable], tag=dense,
        // rows/cols/data — no scale field. v4 readers must keep
        // accepting it, with scales defaulting to None.
        let mut v3: Vec<u8> = Vec::new();
        v3.extend_from_slice(b"HBVLAPS3");
        v3.extend_from_slice(&1u32.to_le_bytes());
        v3.extend_from_slice(&3u32.to_le_bytes());
        v3.extend_from_slice(b"d.w");
        v3.extend_from_slice(&[2u8, 1u8, 0u8]); // Language, quantizable, tag=dense
        v3.extend_from_slice(&2u32.to_le_bytes());
        v3.extend_from_slice(&2u32.to_le_bytes());
        for v in [1.0f32, 2.0, 3.0, 4.0] {
            v3.extend_from_slice(&v.to_le_bytes());
        }
        let path = std::env::temp_dir().join("hbvla_test_v3_store.bin");
        std::fs::write(&path, &v3).unwrap();
        let loaded = ParamStore::load(&path).unwrap();
        assert_eq!(loaded.get("d.w").data, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(loaded.static_act_scale("d.w"), None);
        assert_eq!(loaded.static_scale_count(), 0);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn quantizable_filter_by_component() {
        let mut rng = Rng::new(162);
        let mut s = ParamStore::new();
        s.insert("v", Component::Vision, true, Matrix::gauss(2, 2, 1.0, &mut rng));
        s.insert("l", Component::Language, true, Matrix::gauss(2, 2, 1.0, &mut rng));
        let only_v = s.quantizable_layers(Some(&[Component::Vision]));
        assert_eq!(only_v, vec!["v".to_string()]);
    }

    #[test]
    fn structured_weight_has_modality_means() {
        let mut rng = Rng::new(163);
        let w = structured_weight(128, 64, 1.0, 3.0, &mut rng);
        // Column means should spread much wider than pure gaussian noise
        // would allow (σ/√rows).
        let mut col_means = vec![0.0f32; 64];
        for (j, cm) in col_means.iter_mut().enumerate() {
            *cm = (0..128).map(|i| w.at(i, j)).sum::<f32>() / 128.0;
        }
        let spread = col_means.iter().cloned().fold(f32::MIN, f32::max)
            - col_means.iter().cloned().fold(f32::MAX, f32::min);
        let sigma = 1.0 / (64.0f32).sqrt();
        assert!(spread > 3.0 * sigma / (128.0f32).sqrt() * 4.0, "spread={spread}");
    }

    #[test]
    fn grounding_proj_is_low_rank_plus_noise() {
        let mut rng = Rng::new(164);
        let a = Matrix::gauss(32, 8, 1.0, &mut rng);
        let w = grounding_proj(32, 40, 4..12, &a, 0.1, &mut rng);
        // Structural columns carry A; others are small noise.
        let norms = w.col_norms();
        let structural_avg: f32 = (4..12).map(|j| norms[j]).sum::<f32>() / 8.0;
        let noise_avg: f32 =
            (0..40).filter(|j| !(4..12).contains(j)).map(|j| norms[j]).sum::<f32>() / 32.0;
        assert!(structural_avg > 10.0 * noise_avg, "{structural_avg} vs {noise_avg}");
    }

    #[test]
    #[should_panic(expected = "duplicate param")]
    fn duplicate_insert_panics() {
        let mut rng = Rng::new(165);
        let mut s = ParamStore::new();
        s.insert("x", Component::Vision, true, Matrix::gauss(2, 2, 1.0, &mut rng));
        s.insert("x", Component::Vision, true, Matrix::gauss(2, 2, 1.0, &mut rng));
    }
}
