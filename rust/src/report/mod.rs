//! Paper-style table formatting: fixed-width text tables with a Δ column
//! relative to the FP row, matching the presentation of Tables 1–2 —
//! plus the realized-memory report over a (partially) packed
//! [`crate::model::ParamStore`].

use crate::model::ParamStore;

/// One table: header columns, rows of (label, cells), Δ computed against
/// the row labeled "FP" (by average).
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<(String, Vec<f64>)>,
    /// Decimal places for rendered percentages (ablation tables use 2).
    pub decimals: usize,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            decimals: 1,
        }
    }

    pub fn add_row(&mut self, label: &str, cells: Vec<f64>) {
        assert_eq!(cells.len(), self.columns.len(), "cell count mismatch");
        self.rows.push((label.to_string(), cells));
    }

    /// Average of a row's cells.
    pub fn avg(cells: &[f64]) -> f64 {
        if cells.is_empty() {
            0.0
        } else {
            cells.iter().sum::<f64>() / cells.len() as f64
        }
    }

    fn fp_avg(&self) -> Option<f64> {
        self.rows.iter().find(|(l, _)| l.contains("FP")).map(|(_, c)| Self::avg(c))
    }

    /// Render as fixed-width text (values as percents with one decimal).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("## {}\n", self.title));
        let label_w = self
            .rows
            .iter()
            .map(|(l, _)| l.len())
            .chain(std::iter::once(6))
            .max()
            .unwrap()
            + 2;
        let col_w = 12usize;
        out.push_str(&format!("{:label_w$}", "Method"));
        for c in &self.columns {
            out.push_str(&format!("{:>col_w$}", c));
        }
        out.push_str(&format!("{:>col_w$}{:>col_w$}\n", "Avg", "Δ"));
        let fp = self.fp_avg();
        for (label, cells) in &self.rows {
            out.push_str(&format!("{:label_w$}", label));
            for v in cells {
                out.push_str(&format!("{:>col_w$.*}", self.decimals, v * 100.0));
            }
            let avg = Self::avg(cells);
            out.push_str(&format!("{:>col_w$.*}", self.decimals, avg * 100.0));
            match fp {
                Some(f) if !label.contains("FP") => {
                    out.push_str(&format!("{:>col_w$.*}\n", self.decimals, (avg - f) * 100.0));
                }
                _ => out.push_str(&format!("{:>col_w$}\n", "-")),
            }
        }
        out
    }

    /// Render as a GitHub-markdown table (for EXPERIMENTS.md).
    pub fn render_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("### {}\n\n", self.title));
        out.push_str("| Method |");
        for c in &self.columns {
            out.push_str(&format!(" {c} |"));
        }
        out.push_str(" Avg | Δ |\n|---|");
        for _ in 0..self.columns.len() + 2 {
            out.push_str("---|");
        }
        out.push('\n');
        let fp = self.fp_avg();
        for (label, cells) in &self.rows {
            out.push_str(&format!("| {label} |"));
            for v in cells {
                out.push_str(&format!(" {:.*} |", self.decimals, v * 100.0));
            }
            let avg = Self::avg(cells);
            out.push_str(&format!(" {:.*} |", self.decimals, avg * 100.0));
            match fp {
                Some(f) if !label.contains("FP") => {
                    out.push_str(&format!(" {:+.*} |\n", self.decimals, (avg - f) * 100.0));
                }
                _ => out.push_str(" - |\n"),
            }
        }
        out
    }
}

/// One parameter's storage accounting.
#[derive(Clone, Debug)]
pub struct MemoryRow {
    pub name: String,
    /// Bytes of the dense f32 form.
    pub dense_bytes: usize,
    /// Bytes actually resident (packed layers at sign bitplanes + f32
    /// scale metadata, dense layers at f32).
    pub resident_bytes: usize,
    pub packed: bool,
}

/// Realized (not theoretical) memory savings of a whole model store:
/// aggregates [`crate::quant::packed::PackedBits::storage_bytes`] /
/// `compression_ratio` over every layer, FP layers included at f32, so
/// tables report what a deployment actually holds resident.
#[derive(Clone, Debug)]
pub struct MemoryReport {
    pub rows: Vec<MemoryRow>,
}

impl MemoryReport {
    pub fn from_store(store: &ParamStore) -> Self {
        let rows = store
            .params()
            .iter()
            .map(|p| {
                let (r, c) = p.repr.dims();
                MemoryRow {
                    name: p.name.clone(),
                    dense_bytes: r * c * 4,
                    resident_bytes: p.repr.resident_bytes(),
                    packed: p.repr.is_packed(),
                }
            })
            .collect();
        MemoryReport { rows }
    }

    pub fn total_dense(&self) -> usize {
        self.rows.iter().map(|r| r.dense_bytes).sum()
    }

    pub fn total_resident(&self) -> usize {
        self.rows.iter().map(|r| r.resident_bytes).sum()
    }

    pub fn packed_layers(&self) -> usize {
        self.rows.iter().filter(|r| r.packed).count()
    }

    /// Whole-model compression: dense f32 bytes / resident bytes.
    pub fn compression_ratio(&self) -> f64 {
        self.total_dense() as f64 / self.total_resident().max(1) as f64
    }

    /// Render as fixed-width text: totals first, then per-layer rows.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str("## Realized weight memory\n");
        out.push_str(&format!(
            "total: {} B dense → {} B resident (×{:.1} smaller), {}/{} layers packed\n",
            self.total_dense(),
            self.total_resident(),
            self.compression_ratio(),
            self.packed_layers(),
            self.rows.len()
        ));
        let label_w =
            self.rows.iter().map(|r| r.name.len()).chain(std::iter::once(6)).max().unwrap() + 2;
        out.push_str(&format!(
            "{:label_w$}{:>12}{:>12}{:>8}{:>8}\n",
            "Layer", "dense B", "resident B", "ratio", "repr"
        ));
        for r in &self.rows {
            out.push_str(&format!(
                "{:label_w$}{:>12}{:>12}{:>8.1}{:>8}\n",
                r.name,
                r.dense_bytes,
                r.resident_bytes,
                r.dense_bytes as f64 / r.resident_bytes.max(1) as f64,
                if r.packed { "packed" } else { "dense" }
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("Demo", &["A", "B"]);
        t.add_row("FP Model", vec![0.9, 0.8]);
        t.add_row("HBVLA", vec![0.85, 0.75]);
        t
    }

    #[test]
    fn render_contains_delta() {
        let r = sample().render();
        assert!(r.contains("Demo"));
        assert!(r.contains("85.0"));
        assert!(r.contains("-5.0"));
    }

    #[test]
    fn markdown_row_counts() {
        let md = sample().render_markdown();
        assert_eq!(md.matches("| FP Model |").count(), 1);
        assert!(md.contains("| HBVLA | 85.0 | 75.0 | 80.0 | -5.0 |"));
    }

    #[test]
    #[should_panic(expected = "cell count mismatch")]
    fn mismatched_cells_panic() {
        let mut t = Table::new("x", &["A"]);
        t.add_row("r", vec![0.1, 0.2]);
    }

    #[test]
    fn memory_report_aggregates_packed_savings() {
        use crate::methods::traits::Component;
        use crate::tensor::matrix::Matrix;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(31);
        let mut store = ParamStore::new();
        store.insert("q", Component::Language, true, Matrix::gauss(16, 128, 1.0, &mut rng));
        store.insert("fp", Component::Language, false, Matrix::gauss(8, 8, 1.0, &mut rng));
        store.pack_quantizable(64);
        let rep = MemoryReport::from_store(&store);
        assert_eq!(rep.rows.len(), 2);
        assert_eq!(rep.packed_layers(), 1);
        assert_eq!(rep.total_dense(), 16 * 128 * 4 + 8 * 8 * 4);
        assert!(rep.total_resident() < rep.total_dense());
        assert!(rep.compression_ratio() > 1.0);
        let txt = rep.render();
        assert!(txt.contains("packed"));
        assert!(txt.contains("layers packed"));
    }
}
