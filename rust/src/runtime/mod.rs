//! PJRT runtime: loads the AOT-lowered JAX/Pallas policy graph
//! (`artifacts/*.hlo.txt`, HLO **text** — see DESIGN.md §2) and executes
//! it from Rust. Python never runs on this path.

pub mod pjrt;

pub use pjrt::{artifacts_dir, HloExecutable, PolicyRuntime};
