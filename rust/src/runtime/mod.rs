//! PJRT runtime: loads the AOT-lowered JAX/Pallas policy graph
//! (`artifacts/*.hlo.txt`, HLO **text** — see DESIGN.md §2) and executes
//! it from Rust. Python never runs on this path.
//!
//! Gated behind the `xla-runtime` feature: the `xla` PJRT bindings (and
//! `anyhow`) come from the XLA toolchain image, not crates.io, so the
//! default build is dependency-free (see DESIGN.md §2 for enabling it).

#[cfg(feature = "xla-runtime")]
pub mod pjrt;

#[cfg(feature = "xla-runtime")]
pub use pjrt::{artifacts_dir, HloExecutable, PolicyRuntime};
