//! Thin wrapper over the `xla` crate's PJRT CPU client.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 emits HloModuleProtos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids and round-trips cleanly (see /opt/xla-example/README.md
//! and python/compile/aot.py).

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::model::MiniVla;
use crate::tensor::matrix::Matrix;

/// Default artifacts directory (repo-root relative).
pub fn artifacts_dir() -> PathBuf {
    std::env::var("HBVLA_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// A compiled HLO module ready to execute.
pub struct HloExecutable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl HloExecutable {
    /// Load HLO text from `path` and compile it on the CPU PJRT client.
    pub fn load(client: &xla::PjRtClient, path: &Path) -> Result<Self> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .map_err(|e| anyhow::anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).map_err(|e| anyhow::anyhow!("compile {path:?}: {e:?}"))?;
        Ok(HloExecutable { exe, name: path.file_stem().unwrap().to_string_lossy().into_owned() })
    }

    /// Execute with f32 tensor inputs ((data, dims) pairs); the module is
    /// lowered with `return_tuple=True`, so outputs are a tuple of f32
    /// buffers, returned flattened per element.
    pub fn run_f32(&self, inputs: &[(&[f32], Vec<i64>)]) -> Result<Vec<Vec<f32>>> {
        let mut lits = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            let lit = xla::Literal::vec1(data)
                .reshape(dims)
                .map_err(|e| anyhow::anyhow!("reshape input {dims:?}: {e:?}"))?;
            lits.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
        let parts = result.to_tuple().map_err(|e| anyhow::anyhow!("tuple: {e:?}"))?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(p.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))?);
        }
        Ok(out)
    }
}

/// The policy-serving runtime: the AOT policy-step graph plus the input
/// manifest (`policy_step.inputs.txt`) that fixes the weight feed order.
pub struct PolicyRuntime {
    pub exe: HloExecutable,
    /// Parameter names fed after the observation inputs, in order.
    pub weight_order: Vec<String>,
}

impl PolicyRuntime {
    pub fn load(dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu: {e:?}"))?;
        let exe = HloExecutable::load(&client, &dir.join("policy_step.hlo.txt"))?;
        let manifest = std::fs::read_to_string(dir.join("policy_step.inputs.txt"))
            .context("missing input manifest — run `make artifacts`")?;
        let weight_order: Vec<String> =
            manifest.lines().map(|l| l.trim().to_string()).filter(|l| !l.is_empty()).collect();
        Ok(PolicyRuntime { exe, weight_order })
    }

    /// One policy step through PJRT: observation + the model's weights
    /// (FP or quantized — whatever is in the store) → action chunk.
    pub fn step(
        &self,
        model: &MiniVla,
        visual_raw: &Matrix,
        instr_id: usize,
        proprio: &[f32],
    ) -> Result<Vec<Vec<f32>>> {
        let cfg = &model.cfg;
        let mut instr_onehot = vec![0.0f32; cfg.vocab];
        instr_onehot[instr_id] = 1.0;
        // The PJRT graph consumes dense f32 weights; packed layers are
        // dequantized into owned copies here (packed PJRT export is a
        // ROADMAP follow-on — the native serve path needs no such copy).
        let mats: Vec<std::borrow::Cow<Matrix>> =
            self.weight_order.iter().map(|n| model.store.dense_view(n)).collect();
        let mut inputs: Vec<(&[f32], Vec<i64>)> = vec![
            (&visual_raw.data, vec![cfg.d_vis_in as i64, cfg.n_visual as i64]),
            (&instr_onehot, vec![cfg.vocab as i64]),
            (proprio, vec![cfg.d_proprio as i64]),
        ];
        for w in mats.iter() {
            inputs.push((&w.data, vec![w.rows as i64, w.cols as i64]));
        }
        let outs = self.exe.run_f32(&inputs)?;
        let flat = &outs[0];
        anyhow::ensure!(flat.len() == cfg.chunk * cfg.act_dim, "unexpected output size {}", flat.len());
        Ok((0..cfg.chunk)
            .map(|c| flat[c * cfg.act_dim..(c + 1) * cfg.act_dim].to_vec())
            .collect())
    }
}
