//! # HBVLA — 1-bit post-training quantization for Vision-Language-Action models
//!
//! A production-grade Rust + JAX + Pallas reproduction of *"HBVLA: Pushing
//! 1-Bit Post-Training Quantization for Vision-Language-Action Models"*
//! (CS.LG 2026). The crate provides:
//!
//! - the **HBVLA binarizer** (policy-aware rectified Hessian saliency,
//!   sparse orthogonal (permutation) transform, Haar-domain group-wise
//!   1-bit quantization with residual salient correction) plus the
//!   BiLLM / HBLLM / BiVLM / RTN baselines ([`methods`]);
//! - a **MiniVLA** policy family (token / chunked / diffusion action heads)
//!   with every substrate built in-repo ([`model`], [`tensor`]);
//! - closed-loop **manipulation benchmarks** mirroring LIBERO, SimplerEnv
//!   and the Mobile-ALOHA suite ([`sim`]);
//! - a **coordinator** runtime: layer-parallel PTQ scheduling, batched
//!   rollout, and a policy-serving router ([`coordinator`]);
//! - a **PJRT runtime** executing the AOT-lowered JAX/Pallas policy graph
//!   from `artifacts/*.hlo.txt` ([`runtime`]).
//!
//! See `DESIGN.md` for the paper-to-module map and `EXPERIMENTS.md` for the
//! reproduced tables/figures. The PJRT runtime is gated behind the
//! `xla-runtime` feature (its bindings ship with the XLA toolchain image,
//! not crates.io); the default build is dependency-free.

// Style lints the codebase deliberately trades against: index loops that
// touch several parallel arrays at once read better than zipped iterators
// in the kernel code.
#![allow(clippy::needless_range_loop)]

pub mod calib;
pub mod coordinator;
pub mod eval;
pub mod fleet;
pub mod haar;
pub mod methods;
pub mod model;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod tensor;
pub mod train;
pub mod util;
