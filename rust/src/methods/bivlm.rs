//! Bi-VLM baseline (Wang et al., 2025): Gaussian-quantile weight
//! partitioning. The weight distribution of each row is modeled as a
//! Gaussian; quantile boundaries split entries into `groups` equal-mass
//! partitions, each binarized with its own (μ, α). A small per-modality
//! fraction of salient columns (5 % language, 1 % vision, per the paper's
//! adaptation) is kept at order-2 residual fidelity by column norm — the
//! method is calibration-free (no Hessian), which is exactly the weakness
//! the paper's Figure 1 analysis targets: it "fails to capture critical
//! activation columns".

use crate::methods::traits::{Binarizer, CalibData, Component, QuantizedLayer};
use crate::quant::group::QuantStats;
use crate::quant::obq::residual_binarize_col;
use crate::quant::packed::PackedBits;
use crate::tensor::matrix::Matrix;
use crate::tensor::stats::{mean, std_dev, top_k};

pub struct BiVlm {
    /// Number of Gaussian-quantile partitions per row.
    pub groups: usize,
}

impl BiVlm {
    pub fn new() -> Self {
        // Two quantile partitions: one membership bit per weight keeps the
        // storage near the 1-bit regime the paper's tables compare at.
        BiVlm { groups: 2 }
    }

    fn salient_fraction(component: Component) -> f64 {
        match component {
            Component::Vision => 0.01,
            Component::Language => 0.05,
            Component::Projector | Component::ActionHead => 0.05,
        }
    }
}

impl Default for BiVlm {
    fn default() -> Self {
        Self::new()
    }
}

/// Inverse standard-normal CDF (Acklam's rational approximation) — enough
/// precision for quantile boundaries.
fn inv_norm_cdf(p: f64) -> f64 {
    debug_assert!(p > 0.0 && p < 1.0);
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    let plow = 0.02425;
    if p < plow {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - plow {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        -inv_norm_cdf(1.0 - p)
    }
}

/// Quantile-partition binarization of one row.
fn quantile_binarize_row(row: &mut [f32], groups: usize) -> (u64, u64) {
    let mu = mean(row);
    let sigma = std_dev(row).max(1e-12);
    // Boundaries at Φ⁻¹(k/G)·σ + μ.
    let mut bounds = Vec::with_capacity(groups - 1);
    for k in 1..groups {
        bounds.push(mu + sigma * inv_norm_cdf(k as f64 / groups as f64) as f32);
    }
    let part_of = |v: f32| -> usize {
        bounds.iter().position(|&b| v <= b).unwrap_or(groups - 1)
    };
    let mut members: Vec<Vec<usize>> = vec![Vec::new(); groups];
    for (i, &v) in row.iter().enumerate() {
        members[part_of(v)].push(i);
    }
    let mut scales = 0u64;
    let mut means = 0u64;
    for part in &members {
        if part.is_empty() {
            continue;
        }
        let vals: Vec<f32> = part.iter().map(|&i| row[i]).collect();
        let m = mean(&vals);
        let a = vals.iter().map(|&v| (v - m).abs()).sum::<f32>() / vals.len() as f32;
        for &i in part {
            row[i] = m + a * if row[i] >= m { 1.0 } else { -1.0 };
        }
        scales += 1;
        means += 1;
    }
    (scales, means)
}

impl Binarizer for BiVlm {
    fn name(&self) -> &'static str {
        "BiVLM"
    }

    fn quantize(&self, w: &Matrix, calib: &CalibData) -> QuantizedLayer {
        // Salient columns by plain column norm (no Hessian — data-free).
        let norms = w.col_norms();
        let k = ((w.cols as f64 * Self::salient_fraction(calib.component)).round() as usize)
            .min(w.cols / 2);
        let salient = {
            let mut s = top_k(&norms, k);
            s.sort_unstable();
            s
        };
        let is_sal = {
            let mut v = vec![false; w.cols];
            for &j in &salient {
                v[j] = true;
            }
            v
        };

        let mut w_hat = w.clone();
        let mut stats = QuantStats { weights: (w.rows * w.cols) as u64, ..Default::default() };
        // Non-salient: quantile partitioning row-wise over non-salient cols.
        let ns_idx: Vec<usize> = (0..w.cols).filter(|&j| !is_sal[j]).collect();
        let mut ns = w.select_cols(&ns_idx);
        for i in 0..ns.rows {
            let (s, m) = quantile_binarize_row(ns.row_mut(i), self.groups);
            stats.scale_params += s;
            stats.mean_params += m;
        }
        stats.sign_bits += (ns.rows * ns.cols) as u64;
        // Partition membership: ⌈log2 G⌉ bits per weight.
        let gbits = (usize::BITS - (self.groups - 1).leading_zeros()) as u64;
        stats.mask_bits += (ns.rows * ns.cols) as u64 * gbits;
        w_hat.assign_cols(&ns_idx, &ns);

        // Salient: order-2 residual per column.
        for &j in &salient {
            let col = w.col(j);
            let q = residual_binarize_col(&col);
            w_hat.set_col(j, &q);
            stats.sign_bits += 2 * w.rows as u64;
            stats.scale_params += 2;
            stats.mean_params += 2;
            stats.index_params += 1;
        }

        // Deploy commitment: quantile-partition scales are scattered
        // across each row, so the packed form uses residual bitplanes
        // until Ŵ is captured.
        let packed = PackedBits::pack_deploy(&w_hat);
        QuantizedLayer::new(w, w_hat, stats).with_packed(packed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn inv_norm_cdf_known_values() {
        assert!((inv_norm_cdf(0.5) - 0.0).abs() < 1e-8);
        assert!((inv_norm_cdf(0.975) - 1.959964).abs() < 1e-4);
        assert!((inv_norm_cdf(0.025) + 1.959964).abs() < 1e-4);
        assert!((inv_norm_cdf(0.8413) - 1.0).abs() < 1e-3);
    }

    #[test]
    fn quantile_partition_beats_single_group_on_gaussian() {
        let mut rng = Rng::new(131);
        let orig: Vec<f32> = (0..512).map(|_| rng.gauss() as f32).collect();
        let mut q4 = orig.clone();
        quantile_binarize_row(&mut q4, 4);
        let mut q1 = orig.clone();
        quantile_binarize_row(&mut q1, 1);
        let err = |q: &[f32]| -> f64 {
            orig.iter().zip(q).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum()
        };
        assert!(err(&q4) < 0.5 * err(&q1), "{} vs {}", err(&q4), err(&q1));
    }

    #[test]
    fn vision_gets_fewer_salient_than_language() {
        let mut rng = Rng::new(132);
        let w = Matrix::gauss(64, 200, 1.0, &mut rng);
        let qv = BiVlm::new().quantize(&w, &CalibData::identity(200, Component::Vision));
        let ql = BiVlm::new().quantize(&w, &CalibData::identity(200, Component::Language));
        assert!(qv.stats.index_params < ql.stats.index_params);
    }

    #[test]
    fn output_finite_and_bounded_error() {
        let mut rng = Rng::new(133);
        let w = Matrix::gauss(128, 256, 1.0, &mut rng);
        let q = BiVlm::new().quantize(&w, &CalibData::identity(256, Component::Language));
        assert!(q.w_hat.is_finite());
        assert!(q.rel_frob_err < 0.6, "err={}", q.rel_frob_err);
        let bpw = q.stats.bits_per_weight();
        assert!(bpw > 1.0 && bpw < 3.0, "bpw={bpw}");
    }
}
