//! RTN-1b: round-to-nearest 1-bit baseline — per-row group binarization
//! with no calibration, no transform, no saliency. The sanity floor every
//! structured method must beat.

use crate::methods::traits::{Binarizer, CalibData, QuantizedLayer};
use crate::quant::group::{quantize_matrix, GroupSpec};
use crate::quant::packed::PackedBits;
use crate::tensor::matrix::Matrix;

pub struct Rtn {
    pub group: GroupSpec,
}

impl Rtn {
    pub fn new() -> Self {
        Rtn { group: GroupSpec { group_size: 128, shared_mean: false, adaptive_split: false } }
    }
}

impl Default for Rtn {
    fn default() -> Self {
        Self::new()
    }
}

impl Binarizer for Rtn {
    fn name(&self) -> &'static str {
        "RTN-1b"
    }

    fn quantize(&self, w: &Matrix, _calib: &CalibData) -> QuantizedLayer {
        let (w_hat, stats) = quantize_matrix(w, &self.group);
        // With the plain per-group spec, RTN's reconstruction IS the
        // single-bitplane group binarization, so the packed deploy form
        // is exact: one plane, same groups. A customized spec
        // (shared-mean / adaptive-split) is not PackedBits-expressible —
        // fall back to residual-plane packing of the reconstruction.
        let packed = if !self.group.shared_mean && !self.group.adaptive_split {
            PackedBits::pack(w, self.group.group_size)
        } else {
            PackedBits::pack_deploy(&w_hat)
        };
        QuantizedLayer::new(w, w_hat, stats).with_packed(packed)
    }
}

/// FP passthrough — the full-precision "method" used as the table baseline.
pub struct FullPrecision;

impl Binarizer for FullPrecision {
    fn name(&self) -> &'static str {
        "FP"
    }

    fn quantize(&self, w: &Matrix, _calib: &CalibData) -> QuantizedLayer {
        let stats = crate::quant::group::QuantStats {
            sign_bits: 16 * (w.rows * w.cols) as u64, // bf16 storage
            weights: (w.rows * w.cols) as u64,
            ..Default::default()
        };
        QuantizedLayer::new(w, w.clone(), stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::methods::traits::Component;
    use crate::util::rng::Rng;

    #[test]
    fn fp_is_lossless_16_bits() {
        let mut rng = Rng::new(141);
        let w = Matrix::gauss(8, 8, 1.0, &mut rng);
        let q = FullPrecision.quantize(&w, &CalibData::identity(8, Component::Vision));
        assert_eq!(q.rel_frob_err, 0.0);
        assert_eq!(q.stats.bits_per_weight(), 16.0);
    }

    #[test]
    fn rtn_error_in_expected_range() {
        let mut rng = Rng::new(142);
        let w = Matrix::gauss(64, 256, 1.0, &mut rng);
        let q = Rtn::new().quantize(&w, &CalibData::identity(256, Component::Language));
        // Gaussian 1-bit floor is 1 − 2/π ≈ 0.363.
        assert!((q.rel_frob_err - 0.363).abs() < 0.04, "err={}", q.rel_frob_err);
    }

    #[test]
    fn rtn_packed_commit_is_exact() {
        let mut rng = Rng::new(143);
        let w = Matrix::gauss(16, 200, 1.0, &mut rng);
        let q = Rtn::new().quantize(&w, &CalibData::identity(200, Component::Language));
        let p = q.packed.expect("RTN must commit packed weights");
        assert_eq!(p.order(), 1);
        // The packed dequantization is the reconstruction itself.
        assert!(p.dequantize().dist_sq(&q.w_hat) < 1e-9);
    }
}
