//! The common interface every binarization method implements, plus the
//! per-layer calibration context they consume.

use crate::quant::group::QuantStats;
use crate::quant::packed::PackedBits;
use crate::quant::transform::TransformPacked;
use crate::tensor::matrix::Matrix;

/// Which VLA component a layer belongs to — drives method-specific policy
/// (e.g. BiVLM's per-modality salient ratios) and the Figure-4 sensitivity
/// sweep.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Component {
    Vision,
    Projector,
    Language,
    ActionHead,
}

impl Component {
    pub fn label(&self) -> &'static str {
        match self {
            Component::Vision => "vision",
            Component::Projector => "projector",
            Component::Language => "language",
            Component::ActionHead => "action_head",
        }
    }
}

/// Per-layer calibration context.
///
/// `hessian` is the standard proxy H = XXᵀ/N; `hessian_rect` is the
/// policy-aware rectified H̃ = XSXᵀ/Σs when the gradient probe ran for this
/// layer. Both are normalized per token so their scales are comparable.
#[derive(Clone, Debug)]
pub struct CalibData {
    pub hessian: Matrix,
    pub hessian_rect: Option<Matrix>,
    pub component: Component,
}

impl CalibData {
    /// Data-free context: identity Hessian (all columns equal energy).
    pub fn identity(dim: usize, component: Component) -> Self {
        CalibData { hessian: Matrix::eye(dim), hessian_rect: None, component }
    }

    pub fn from_hessian(h: Matrix, component: Component) -> Self {
        CalibData { hessian: h, hessian_rect: None, component }
    }

    pub fn with_rectified(mut self, h_rect: Matrix) -> Self {
        self.hessian_rect = Some(h_rect);
        self
    }

    /// Diagonal of the Hessian a method wants: rectified if requested and
    /// available, standard otherwise.
    pub fn diag(&self, policy_aware: bool) -> Vec<f32> {
        if policy_aware {
            if let Some(hr) = &self.hessian_rect {
                return hr.diag();
            }
        }
        self.hessian.diag()
    }
}

/// Output of quantizing one layer.
#[derive(Clone, Debug)]
pub struct QuantizedLayer {
    /// Dense reconstruction Ŵ — the method's accuracy-analysis artifact
    /// (error metrics, ablations).
    pub w_hat: Matrix,
    /// Packed deploy representation, when the method commits one: the
    /// scheduler stores this in the [`crate::model::params::ParamStore`]
    /// as [`crate::model::params::WeightRepr::Packed`] so serving and
    /// rollouts execute on the 1-bit kernels. `None` means the layer is
    /// committed dense (e.g. the FP passthrough).
    pub packed: Option<PackedBits>,
    /// Transform-domain exact deploy representation, when the method
    /// quantizes in a transform domain and commits the bitplane it
    /// actually produced there ([`TransformPacked`]: permutation + Haar
    /// metadata + salient side-channel + ONE Haar-domain plane). Serving
    /// this form executes y = C·haar(Pᵀx) — exact by construction, no
    /// residual planes. `None` for direct-domain methods (RTN et al.).
    pub transform_packed: Option<TransformPacked>,
    /// Storage accounting (bits per weight ≈ 1.08 for the paper methods).
    pub stats: QuantStats,
    /// Relative Frobenius error ‖W − Ŵ‖²_F / ‖W‖²_F.
    pub rel_frob_err: f64,
}

impl QuantizedLayer {
    pub fn new(w: &Matrix, w_hat: Matrix, stats: QuantStats) -> Self {
        let denom = w.frob_norm_sq().max(1e-30);
        let rel = w.dist_sq(&w_hat) / denom;
        QuantizedLayer { w_hat, packed: None, transform_packed: None, stats, rel_frob_err: rel }
    }

    /// Attach the packed deploy form of this layer.
    pub fn with_packed(mut self, p: PackedBits) -> Self {
        assert_eq!((p.rows, p.cols), (self.w_hat.rows, self.w_hat.cols), "packed shape mismatch");
        self.packed = Some(p);
        self
    }

    /// Attach the transform-domain exact deploy form of this layer.
    pub fn with_transform_packed(mut self, t: TransformPacked) -> Self {
        assert_eq!(t.dims(), (self.w_hat.rows, self.w_hat.cols), "transform shape mismatch");
        self.transform_packed = Some(t);
        self
    }
}

/// A post-training binarization method. Implementations must be pure
/// functions of (W, calib) so the coordinator can quantize layers in
/// parallel.
pub trait Binarizer: Sync + Send {
    fn name(&self) -> &'static str;
    fn quantize(&self, w: &Matrix, calib: &CalibData) -> QuantizedLayer;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_calib_has_unit_diag() {
        let c = CalibData::identity(5, Component::Language);
        assert_eq!(c.diag(false), vec![1.0; 5]);
        assert_eq!(c.diag(true), vec![1.0; 5]); // falls back, no rectified
    }

    #[test]
    fn rectified_diag_selected_when_requested() {
        let h = Matrix::eye(3);
        let mut hr = Matrix::eye(3);
        hr.set(0, 0, 7.0);
        let c = CalibData::from_hessian(h, Component::Vision).with_rectified(hr);
        assert_eq!(c.diag(true)[0], 7.0);
        assert_eq!(c.diag(false)[0], 1.0);
    }

    #[test]
    fn quantized_layer_rel_err() {
        let w = Matrix::filled(2, 2, 2.0);
        let w_hat = Matrix::filled(2, 2, 1.0);
        let q = QuantizedLayer::new(&w, w_hat, QuantStats::default());
        assert!((q.rel_frob_err - 0.25).abs() < 1e-9);
        assert!(q.packed.is_none());
    }

    #[test]
    fn with_packed_attaches_deploy_form() {
        let w = Matrix::filled(2, 64, 1.0);
        let q = QuantizedLayer::new(&w, w.clone(), QuantStats::default())
            .with_packed(PackedBits::pack(&w, 64));
        assert!(q.packed.is_some());
    }

    #[test]
    #[should_panic(expected = "packed shape mismatch")]
    fn with_packed_rejects_wrong_shape() {
        let w = Matrix::filled(2, 64, 1.0);
        let other = Matrix::filled(3, 64, 1.0);
        let _ = QuantizedLayer::new(&w, w.clone(), QuantStats::default())
            .with_packed(PackedBits::pack(&other, 64));
    }

    #[test]
    fn component_labels() {
        assert_eq!(Component::Vision.label(), "vision");
        assert_eq!(Component::ActionHead.label(), "action_head");
    }
}
