//! BiLLM baseline (Huang et al., ICML 2024), adapted per the paper's
//! experimental setup.
//!
//! Faithful to the original method's structure:
//! - binarization is **sign-based with row-level scales and no mean
//!   restoration**: q = α_row · sign(w) — BiLLM's Eq. (1)-style primitive.
//!   (This is the property the HBVLA paper exploits: distribution-shifted
//!   weight columns/rows are unrepresentable, so BiLLM collapses on VLA
//!   layers, Table 1/2's −46 pp rows.)
//! - non-salient weights get the **bell-shaped split**: per row, |w| is
//!   split into a concentrated and a sparse group at an MSE-optimal
//!   threshold, each with its own α (membership costs 1 mask bit/weight);
//! - salient columns (Hessian-guided structured selection) get **order-2
//!   residual binarization**;
//! - the whole layer is swept with **OBQ/GPTQ error compensation** on the
//!   standard Hessian (block size 128 in the original; our layers are
//!   small enough for the exact column recursion).

use crate::methods::traits::{Binarizer, CalibData, QuantizedLayer};
use crate::quant::group::QuantStats;
use crate::quant::obq::obq_sweep;
use crate::quant::packed::PackedBits;
use crate::quant::saliency::select_salient;
use crate::tensor::matrix::Matrix;

pub struct BiLlm {
    /// Candidate salient columns (structured selection cap).
    pub max_candidates: usize,
}

impl BiLlm {
    pub fn new() -> Self {
        BiLlm { max_candidates: 40 }
    }
}

impl Default for BiLlm {
    fn default() -> Self {
        Self::new()
    }
}

/// Per-row bell-split scales frozen from the original weights:
/// (threshold, α_dense, α_sparse) per row over the given column subset.
fn bell_row_scales(w: &Matrix, cols: &[usize]) -> Vec<(f32, f32, f32)> {
    let mut out = Vec::with_capacity(w.rows);
    for i in 0..w.rows {
        let mags: Vec<f32> = cols.iter().map(|&j| w.at(i, j).abs()).collect();
        if mags.is_empty() {
            out.push((0.0, 0.0, 0.0));
            continue;
        }
        let mut sorted = mags.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = sorted.len();
        let mut best = (f64::INFINITY, sorted[n - 1], 0.0f32, 0.0f32);
        for q in [0.6f64, 0.75, 0.9] {
            let t = sorted[((q * (n - 1) as f64) as usize).min(n - 1)];
            let (mut sd, mut nd, mut ss, mut ns) = (0.0f64, 0usize, 0.0f64, 0usize);
            for &m in &mags {
                if m <= t {
                    sd += m as f64;
                    nd += 1;
                } else {
                    ss += m as f64;
                    ns += 1;
                }
            }
            let ad = if nd > 0 { (sd / nd as f64) as f32 } else { 0.0 };
            let as_ = if ns > 0 { (ss / ns as f64) as f32 } else { 0.0 };
            // MSE of |w| → α mapping: Σ (|w| − α_g)².
            let mut e = 0.0f64;
            for &m in &mags {
                let a = if m <= t { ad } else { as_ };
                e += ((m - a) as f64).powi(2);
            }
            if e < best.0 {
                best = (e, t, ad, as_);
            }
        }
        out.push((best.1, best.2, best.3));
    }
    out
}

/// Per-row order-2 scales for the salient columns: (α₁, α₂) with
/// α₁ = mean|w|, α₂ = mean|w − α₁·sign(w)| over the salient subset.
fn salient_row_scales(w: &Matrix, cols: &[usize]) -> Vec<(f32, f32)> {
    let mut out = Vec::with_capacity(w.rows);
    for i in 0..w.rows {
        if cols.is_empty() {
            out.push((0.0, 0.0));
            continue;
        }
        let vals: Vec<f32> = cols.iter().map(|&j| w.at(i, j)).collect();
        let a1 = vals.iter().map(|v| v.abs()).sum::<f32>() / vals.len() as f32;
        let a2 = vals
            .iter()
            .map(|&v| (v - a1 * v.signum()).abs())
            .sum::<f32>()
            / vals.len() as f32;
        out.push((a1, a2));
    }
    out
}

impl Binarizer for BiLlm {
    fn name(&self) -> &'static str {
        "BiLLM"
    }

    fn quantize(&self, w: &Matrix, calib: &CalibData) -> QuantizedLayer {
        let h_diag = calib.diag(false); // standard Hessian only
        let part = select_salient(w, &h_diag, self.max_candidates.min(w.cols / 4));
        let is_salient = {
            let mut s = vec![false; w.cols];
            for &j in &part.salient {
                s[j] = true;
            }
            s
        };
        let bell = bell_row_scales(w, &part.non_salient);
        let sal = salient_row_scales(w, &part.salient);
        // Bell membership frozen from the original magnitudes (the stored
        // mask); signs come from the OBQ-compensated working values.
        let orig = w.clone();
        let w_hat = obq_sweep(w, &calib.hessian, |j, col| {
            let mut q = vec![0.0f32; col.len()];
            if is_salient[j] {
                for i in 0..col.len() {
                    let (a1, a2) = sal[i];
                    let q1 = a1 * col[i].signum();
                    let r = col[i] - q1;
                    q[i] = q1 + a2 * r.signum();
                }
            } else {
                for i in 0..col.len() {
                    let (t, ad, asp) = bell[i];
                    let a = if orig.at(i, j).abs() <= t { ad } else { asp };
                    q[i] = a * col[i].signum();
                }
            }
            q
        });
        // Bit accounting: 1 sign + 1 bell mask bit per non-salient weight,
        // 2 sign bits per salient weight; per-row scales (2 bell + 2
        // salient) at fp16; salient column indices.
        let d = w.rows as u64;
        let n_sal = part.salient.len() as u64;
        let n_ns = (w.cols as u64) - n_sal;
        let stats = QuantStats {
            sign_bits: d * (n_ns + 2 * n_sal),
            mask_bits: d * n_ns,
            scale_params: 4 * d,
            mean_params: 0, // sign-based: no means stored
            index_params: n_sal,
            weights: d * w.cols as u64,
        };
        // Deploy commitment: bell-split scales and order-2 salient columns
        // are not two-level per contiguous group, so the packed form uses
        // residual bitplanes until Ŵ is captured.
        let packed = PackedBits::pack_deploy(&w_hat);
        QuantizedLayer::new(w, w_hat, stats).with_packed(packed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::methods::traits::Component;
    use crate::tensor::ops::gram;
    use crate::util::rng::Rng;

    fn calib_for(cols: usize, rng: &mut Rng) -> CalibData {
        let x = Matrix::gauss(cols, 4 * cols, 1.0, rng);
        let mut h = gram(&x);
        h.scale(1.0 / (4 * cols) as f32);
        CalibData::from_hessian(h, Component::Language)
    }

    #[test]
    fn reasonable_on_zero_mean_gaussian() {
        let mut rng = Rng::new(121);
        let w = Matrix::gauss(48, 64, 1.0, &mut rng);
        let calib = calib_for(64, &mut rng);
        let q = BiLlm::new().quantize(&w, &calib);
        assert!(q.w_hat.is_finite());
        // Bell split + salient + OBQ should beat the naive 0.363 floor.
        assert!(q.rel_frob_err < 0.40, "err={}", q.rel_frob_err);
    }

    #[test]
    fn collapses_on_mean_shifted_weights() {
        // Sign-based binarization cannot represent a distribution shift —
        // the failure mode the HBVLA paper exploits (Table 1/2 BiLLM rows).
        let mut rng = Rng::new(122);
        let w = Matrix::from_fn(32, 64, |_, _| 1.0 + 0.3 * rng.gauss() as f32);
        let calib = calib_for(64, &mut rng);
        let q_billm = BiLlm::new().quantize(&w, &calib);
        let q_hbvla = crate::methods::HbVla::new().quantize(&w, &calib);
        assert!(
            q_hbvla.rel_frob_err < 0.5 * q_billm.rel_frob_err,
            "hbvla {} vs billm {}",
            q_hbvla.rel_frob_err,
            q_billm.rel_frob_err
        );
    }

    #[test]
    fn bits_accounting_near_paper() {
        let mut rng = Rng::new(123);
        let w = Matrix::gauss(256, 256, 1.0, &mut rng);
        let calib = calib_for(256, &mut rng);
        let q = BiLlm::new().quantize(&w, &calib);
        let bpw = q.stats.bits_per_weight();
        assert!(bpw > 1.0 && bpw < 2.8, "bpw={bpw}");
    }

    #[test]
    fn bell_scales_split_small_and_large() {
        let mut w = Matrix::zeros(1, 100);
        for j in 0..100 {
            w.set(0, j, if j < 80 { 0.1 } else { 2.0 });
        }
        let cols: Vec<usize> = (0..100).collect();
        let s = bell_row_scales(&w, &cols);
        let (t, ad, asp) = s[0];
        assert!(t >= 0.1 && t < 2.0);
        assert!((ad - 0.1).abs() < 0.05, "ad={ad}");
        assert!((asp - 2.0).abs() < 0.1, "asp={asp}");
    }
}
