//! HBVLA — the paper's method (Figure 2), and, by configuration, the
//! HBLLM baseline (HBVLA minus the permutation minus the policy-aware
//! Hessian).
//!
//! Pipeline per layer W:
//! 1. choose the Hessian diagonal (policy-aware rectified H̃ or standard H);
//! 2. partition columns into salient / non-salient (two-stage selection);
//! 3. non-salient: fill salient columns with adjacent averages (Eq. 12),
//!    apply the sparse orthogonal transform P (Algorithm 1), row-wise
//!    one-level Haar (Eq. 10), group-wise 1-bit quantization per frequency
//!    band with shared means (Eq. 11/13), inverse Haar, inverse P;
//! 4. salient: residual R = W − Ŵ_nonsal (Eq. 15), column-wise Haar on
//!    R(:, I_sal) (Eq. 16), order-2 residual binarization in the Haar
//!    domain, inverse (Eq. 17);
//! 5. Ŵ = Ŵ_nonsal + Ŵ_sal (Eq. 18).

use crate::haar::{haar_rows, haar_rows_inv, half_len};
use crate::methods::traits::{Binarizer, CalibData, QuantizedLayer};
use crate::quant::group::{quantize_matrix_banded, GroupSpec, QuantStats};
use crate::quant::packed::PackedBits;
use crate::quant::permute::{pairing_and_chaining, permute_cols, unpermute_cols, NormKind};
use crate::quant::saliency::{fill_salient_adjacent, select_salient};
use crate::quant::transform::{transform_group_size, SalientCols, TransformPacked};
use crate::tensor::matrix::Matrix;

/// Configuration of the Haar-hybrid quantizer family.
#[derive(Clone, Debug)]
pub struct HaarHybridConfig {
    /// Use the policy-aware rectified Hessian when available (HBVLA: yes,
    /// HBLLM: no). Table 4 ablates this.
    pub policy_aware: bool,
    /// Apply Algorithm 1's permutation before the Haar transform (HBVLA:
    /// yes, HBLLM: no).
    pub permute: bool,
    /// Column-norm criterion for the permutation pivots (Table 3: ℓ2 wins).
    pub norm: NormKind,
    /// Restrict pairing to top-K neighbours (None = exhaustive).
    pub top_k: Option<usize>,
    /// Candidate salient columns considered (HBLLM convention: 40).
    pub max_candidates: usize,
    /// Group quantizer settings for the non-salient Haar coefficients.
    pub group: GroupSpec,
}

impl HaarHybridConfig {
    pub fn hbvla() -> Self {
        HaarHybridConfig {
            policy_aware: true,
            permute: true,
            norm: NormKind::L2,
            top_k: None,
            max_candidates: 40,
            group: GroupSpec { group_size: 128, shared_mean: true, adaptive_split: true },
        }
    }

    pub fn hbllm() -> Self {
        HaarHybridConfig { policy_aware: false, permute: false, ..Self::hbvla() }
    }
}

/// The HBVLA binarizer (also instantiates HBLLM via [`HaarHybridConfig`]).
pub struct HbVla {
    pub cfg: HaarHybridConfig,
    name: &'static str,
}

impl HbVla {
    pub fn new() -> Self {
        HbVla { cfg: HaarHybridConfig::hbvla(), name: "HBVLA" }
    }

    pub fn with_config(cfg: HaarHybridConfig, name: &'static str) -> Self {
        HbVla { cfg, name }
    }

    /// HBLLM baseline: Haar + shared-mean + ℓ2 saliency, no permutation,
    /// standard Hessian.
    pub fn hbllm() -> Self {
        HbVla { cfg: HaarHybridConfig::hbllm(), name: "HBLLM" }
    }
}

impl Default for HbVla {
    fn default() -> Self {
        Self::new()
    }
}

/// Quantize the salient residual via column-wise Haar + order-2 residual
/// group binarization (Eqs. 16–17). Returns (Ŵ_sal_cols, stats).
fn quantize_salient_residual(r_sal: &Matrix, group: &GroupSpec) -> (Matrix, QuantStats) {
    // Column-wise Haar = row-wise Haar on the transpose (Eq. 48); quantize
    // the transposed coefficients row-wise per band, order 2.
    let rt = r_sal.transpose(); // k_sal × d
    let c = haar_rows(&rt); // k_sal × 2⌈d/2⌉
    let j = half_len(rt.cols);
    let bands = [(0usize, j), (j, 2 * j)];
    // Salient path keeps per-group means (high fidelity): shared_mean off.
    let spec = GroupSpec { shared_mean: false, ..group.clone() };
    let (q1, mut stats) = quantize_matrix_banded(&c, &bands, &spec);
    let resid = c.sub(&q1);
    let (q2, s2) = quantize_matrix_banded(&resid, &bands, &spec);
    stats.add(&s2);
    let qc = q1.add(&q2);
    let rec_t = haar_rows_inv(&qc, rt.cols);
    (rec_t.transpose(), stats)
}

impl Binarizer for HbVla {
    fn name(&self) -> &'static str {
        self.name
    }

    fn quantize(&self, w: &Matrix, calib: &CalibData) -> QuantizedLayer {
        let cfg = &self.cfg;
        let h_diag = calib.diag(cfg.policy_aware);

        // --- Step 1–2: policy-aware partitioning ---
        let part = select_salient(w, &h_diag, cfg.max_candidates.min(w.cols / 2));

        // --- Step 3: non-salient Haar-domain binarization ---
        let filled = fill_salient_adjacent(w, &part.salient);
        let pi: Vec<usize> = if cfg.permute {
            pairing_and_chaining(&filled, cfg.top_k, cfg.norm)
        } else {
            (0..w.cols).collect()
        };
        let wp = permute_cols(&filled, &pi);
        let u = haar_rows(&wp);
        let j = half_len(w.cols);
        let bands = [(0usize, j), (j, 2 * j)];
        let (uq, mut stats) = quantize_matrix_banded(&u, &bands, &cfg.group);
        let rec = haar_rows_inv(&uq, w.cols);
        let w_nonsal_hat = unpermute_cols(&rec, &pi);

        // --- Step 4: salient residual, column-wise Haar, order-2 ---
        let mut w_hat = w_nonsal_hat;
        if !part.salient.is_empty() {
            let r = w.sub(&w_hat);
            let r_sal = r.select_cols(&part.salient);
            let (q_sal, s_sal) = quantize_salient_residual(&r_sal, &cfg.group);
            stats.add(&s_sal);
            stats.index_params += part.salient.len() as u64;
            // Ŵ(:, sal) += quantized residual (Eq. 18).
            let cur = w_hat.select_cols(&part.salient);
            w_hat.assign_cols(&part.salient, &cur.add(&q_sal));
        }

        // Deploy commitment, two forms:
        //
        // (1) Repacked (`hbvla-packed`): the inverse-Haar/-permutation
        //     reconstruction is multi-level per group, so the packed form
        //     uses residual bitplanes until it captures Ŵ to tolerance
        //     (see quant::packed::DEPLOY_*) — approximate serving.
        let packed = PackedBits::pack_deploy(&w_hat);

        // (2) Transform-exact (`hbvla-exact`): commit a SINGLE bitplane in
        //     the Haar domain itself — quantize the same transformed
        //     coefficients U with a PackedBits-expressible grouping
        //     (contiguous per-group (α, μ), boundaries on the band seam) —
        //     and serve it as y = C·haar(Pᵀx). Exact by construction: the
        //     plane IS the commitment, so there is no reconstruction error
        //     for residual planes to absorb. Salient columns ride the
        //     side-channel as the ORDER-2 residual binarization of
        //     W − Ŵ_nonsal at those columns (Eq. 15–17's high-fidelity
        //     salient path, committed packed — also exact by
        //     construction). Committing both forms unconditionally keeps
        //     the Binarizer interface pure and lets one quantize publish
        //     either variant; the extra work is minor next to the O(m²·d)
        //     pairing step above.
        let tbits = PackedBits::pack(&u, transform_group_size(j));
        let perm32: Vec<u32> = pi.iter().map(|&p| p as u32).collect();
        let salient_sc = if part.salient.is_empty() {
            None
        } else {
            let nonsal_exact = unpermute_cols(&haar_rows_inv(&tbits.dequantize(), w.cols), &pi);
            let resid = w.sub(&nonsal_exact).select_cols(&part.salient);
            Some(SalientCols {
                idx: part.salient.iter().map(|&c| c as u32).collect(),
                bits: PackedBits::pack_residual(&resid, crate::quant::packed::DEPLOY_GROUP_SIZE, 2, 0.0),
            })
        };
        let transform = TransformPacked::new(w.cols, perm32, tbits, salient_sc);

        QuantizedLayer::new(w, w_hat, stats).with_packed(packed).with_transform_packed(transform)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::methods::traits::Component;
    use crate::tensor::ops::{gram, matmul};
    use crate::util::rng::Rng;

    fn calib_for(w_cols: usize, rng: &mut Rng) -> CalibData {
        let x = Matrix::gauss(w_cols, 4 * w_cols, 1.0, rng);
        let mut h = gram(&x);
        h.scale(1.0 / (4 * w_cols) as f32);
        CalibData::from_hessian(h, Component::Language)
    }

    #[test]
    fn reconstruction_beats_rtn_on_structured_weights() {
        let mut rng = Rng::new(111);
        // Modality-structured weights: interleaved column groups with
        // different means — the regime HBVLA is built for.
        let m = 128;
        let w = Matrix::from_fn(64, m, |_, j| {
            let base = if j % 2 == 0 { 1.5 } else { -1.5 };
            base + 0.3 * rng.gauss() as f32
        });
        let calib = calib_for(m, &mut rng);
        let hb = HbVla::new().quantize(&w, &calib);
        let spec = GroupSpec { group_size: 128, shared_mean: false, adaptive_split: false };
        let (rtn, _) = crate::quant::group::quantize_matrix(&w, &spec);
        let rtn_err = w.dist_sq(&rtn) / w.frob_norm_sq();
        assert!(
            hb.rel_frob_err < 0.5 * rtn_err,
            "HBVLA {} vs RTN {}",
            hb.rel_frob_err,
            rtn_err
        );
    }

    #[test]
    fn permutation_helps_on_interleaved_modalities() {
        let mut rng = Rng::new(112);
        let m = 96;
        let w = Matrix::from_fn(48, m, |_, j| {
            let base = match j % 3 {
                0 => 2.0,
                1 => -2.0,
                _ => 0.0,
            };
            base + 0.2 * rng.gauss() as f32
        });
        let calib = calib_for(m, &mut rng);
        let with = HbVla::new().quantize(&w, &calib);
        let without = HbVla::with_config(
            HaarHybridConfig { permute: false, ..HaarHybridConfig::hbvla() },
            "noperm",
        )
        .quantize(&w, &calib);
        assert!(
            with.rel_frob_err < without.rel_frob_err,
            "permute {} !< no-permute {}",
            with.rel_frob_err,
            without.rel_frob_err
        );
    }

    #[test]
    fn hbvla_beats_hbllm_with_rectified_hessian() {
        let mut rng = Rng::new(113);
        let m = 64;
        let w = Matrix::gauss(32, m, 1.0, &mut rng);
        // Calibration where token 0 carries a distinct direction with a
        // large rectified weight.
        let x = Matrix::gauss(m, 256, 1.0, &mut rng);
        let mut s = vec![1.0f32; 256];
        for t in 0..32 {
            s[t] = 20.0;
        }
        let mut h = gram(&x);
        h.scale(1.0 / 256.0);
        let mut hr = crate::tensor::ops::gram_weighted(&x, &s);
        hr.scale(1.0 / s.iter().sum::<f32>());
        let calib = CalibData::from_hessian(h.clone(), Component::Language).with_rectified(hr.clone());
        let q_aware = HbVla::new().quantize(&w, &calib);
        let q_plain = HbVla::hbllm().quantize(&w, &calib);
        // Evaluate against the *rectified* objective — the policy-aware
        // method should win on the metric it optimizes.
        let err = |q: &QuantizedLayer, h: &Matrix| {
            crate::quant::hessian::hessian_weighted_error(&w, &q.w_hat, h)
        };
        assert!(err(&q_aware, &hr) <= err(&q_plain, &hr) * 1.05,
            "{} vs {}", err(&q_aware, &hr), err(&q_plain, &hr));
    }

    #[test]
    fn transform_commit_single_plane_and_forward_exact() {
        let mut rng = Rng::new(117);
        let m = 96;
        let w = Matrix::from_fn(48, m, |_, j| {
            (if j % 2 == 0 { 1.2 } else { -1.2 }) + 0.3 * rng.gauss() as f32
        });
        let calib = calib_for(m, &mut rng);
        let q = HbVla::new().quantize(&w, &calib);
        let t = q.transform_packed.expect("HBVLA must commit the transform-exact form");
        // Zero residual planes: the Haar-domain commitment is one plane.
        assert_eq!(t.bits.order(), 1);
        assert_eq!(t.dims(), (48, m));
        // The transform forward equals the dense product of its own
        // offline reconstruction within float roundoff.
        let deq = t.dequantize();
        let x: Vec<f32> = (0..m).map(|_| rng.gauss() as f32).collect();
        let y = t.matvec_owned(&x);
        let y_ref = crate::tensor::ops::matvec(&deq, &x);
        for r in 0..48 {
            assert!((y[r] - y_ref[r]).abs() < 1e-3 * (1.0 + y_ref[r].abs()), "row {r}");
        }
        // And the exact reconstruction stays in the same accuracy regime
        // as the analysis reconstruction (both far below the 1-bit
        // Gaussian floor on structured weights).
        let rel = w.dist_sq(&deq) / w.frob_norm_sq();
        assert!(rel < 0.25, "transform-exact reconstruction degraded: {rel}");
        // Exact serving drops memory vs the residual-plane repack whenever
        // the repack needed more than one plane.
        let p = q.packed.expect("repacked form");
        if p.order() > 1 {
            assert!(t.storage_bytes() < p.storage_bytes());
        }
    }

    #[test]
    fn bits_per_weight_close_to_paper() {
        let mut rng = Rng::new(114);
        let w = Matrix::gauss(256, 256, 1.0, &mut rng);
        let calib = calib_for(256, &mut rng);
        let q = HbVla::new().quantize(&w, &calib);
        let bpw = q.stats.bits_per_weight();
        // Paper reports ~1.08 bits; our accounting (masks + fp16 metadata
        // + 2-bit salient) should land in the same ballpark.
        assert!(bpw > 1.0 && bpw < 2.6, "bpw={bpw}");
    }

    #[test]
    fn handles_odd_and_small_shapes() {
        let mut rng = Rng::new(115);
        for &(r, c) in &[(8usize, 9usize), (3, 4), (16, 31)] {
            let w = Matrix::gauss(r, c, 1.0, &mut rng);
            let calib = CalibData::identity(c, Component::Vision);
            let q = HbVla::new().quantize(&w, &calib);
            assert_eq!((q.w_hat.rows, q.w_hat.cols), (r, c));
            assert!(q.w_hat.is_finite());
            assert!(q.rel_frob_err < 1.0);
        }
    }

    #[test]
    fn output_error_correlates_with_forward_error() {
        // The Frobenius objective is a proxy for ‖WX − ŴX‖ (Eq. 2): check
        // that the reconstruction also reduces *output* error vs RTN.
        let mut rng = Rng::new(116);
        let w = Matrix::from_fn(32, 64, |_, j| if j < 32 { 1.0 } else { -1.0 } + 0.2 * rng.gauss() as f32);
        let x = Matrix::gauss(64, 100, 1.0, &mut rng);
        let mut h = gram(&x);
        h.scale(1.0 / 100.0);
        let calib = CalibData::from_hessian(h, Component::Language);
        let q = HbVla::new().quantize(&w, &calib);
        let spec = GroupSpec { group_size: 64, shared_mean: false, adaptive_split: false };
        let (rtn, _) = crate::quant::group::quantize_matrix(&w, &spec);
        let out_err = |wh: &Matrix| matmul(&w.sub(wh), &x).frob_norm_sq();
        assert!(out_err(&q.w_hat) < out_err(&rtn));
    }
}
