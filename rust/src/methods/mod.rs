//! Binarization methods: HBVLA (the paper) and the published baselines it
//! is compared against, all behind the [`traits::Binarizer`] interface so
//! the coordinator, eval drivers and benches are method-agnostic.

pub mod billm;
pub mod bivlm;
pub mod hbvla;
pub mod rtn;
pub mod traits;

pub use billm::BiLlm;
pub use bivlm::BiVlm;
pub use hbvla::{HaarHybridConfig, HbVla};
pub use rtn::{FullPrecision, Rtn};
pub use traits::{Binarizer, CalibData, Component, QuantizedLayer};

/// The method roster of the paper's tables, in presentation order.
pub fn paper_methods() -> Vec<Box<dyn Binarizer>> {
    vec![
        Box::new(BiLlm::new()),
        Box::new(BiVlm::new()),
        Box::new(HbVla::hbllm()),
        Box::new(HbVla::new()),
    ]
}

/// Look a method up by (case-insensitive) name.
pub fn by_name(name: &str) -> Option<Box<dyn Binarizer>> {
    match name.to_ascii_lowercase().as_str() {
        "hbvla" => Some(Box::new(HbVla::new())),
        "hbllm" => Some(Box::new(HbVla::hbllm())),
        "billm" => Some(Box::new(BiLlm::new())),
        "bivlm" => Some(Box::new(BiVlm::new())),
        "rtn" | "rtn-1b" => Some(Box::new(Rtn::new())),
        "fp" | "full" | "fullprecision" => Some(Box::new(FullPrecision)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roster_matches_paper_order() {
        let names: Vec<&str> = paper_methods().iter().map(|m| m.name()).collect();
        assert_eq!(names, vec!["BiLLM", "BiVLM", "HBLLM", "HBVLA"]);
    }

    #[test]
    fn lookup_by_name() {
        for n in ["hbvla", "HBLLM", "BiLLM", "bivlm", "rtn", "fp"] {
            assert!(by_name(n).is_some(), "{n}");
        }
        assert!(by_name("nope").is_none());
    }

    /// The ordering the paper's tables rest on. On VLA-like weights —
    /// row-mean offsets + interleaved multi-level modality column
    /// structure + noise — HBVLA must have the lowest reconstruction
    /// error and BiLLM (sign-only, no transform) the highest.
    #[test]
    fn hbvla_best_billm_worst_on_vla_like_weights() {
        use crate::tensor::matrix::Matrix;
        use crate::tensor::ops::gram;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(151);
        let m = 128;
        let d = 64;
        // Per-row mean offsets (breaks sign-only quantizers), interleaved
        // 4-level modality structure (needs the permutation), iid noise.
        let row_mu: Vec<f32> = (0..d).map(|_| 0.6 * rng.gauss() as f32).collect();
        // Random (not periodic) modality assignment — real VLA columns are
        // irregularly interleaved, which is what the permutation exploits.
        let mut modality: Vec<usize> = (0..m).map(|j| j % 4).collect();
        rng.shuffle(&mut modality);
        let w = Matrix::from_fn(d, m, |i, j| {
            let base = match modality[j] {
                0 => 1.2,
                1 => -1.2,
                2 => 0.4,
                _ => -0.4,
            };
            row_mu[i] + base + 0.25 * rng.gauss() as f32
        });
        let x = Matrix::gauss(m, 512, 1.0, &mut rng);
        let mut h = gram(&x);
        h.scale(1.0 / 512.0);
        let calib = CalibData::from_hessian(h, Component::Language);
        let mut errs = std::collections::HashMap::new();
        for method in paper_methods() {
            let q = method.quantize(&w, &calib);
            errs.insert(method.name().to_string(), q.rel_frob_err);
        }
        let hbvla = errs["HBVLA"];
        let billm = errs["BiLLM"];
        for (name, &e) in &errs {
            if name != "HBVLA" {
                assert!(hbvla <= e * 1.02, "HBVLA ({hbvla}) should beat {name} ({e})");
            }
            if name != "BiLLM" {
                assert!(billm >= e, "BiLLM ({billm}) should trail {name} ({e})");
            }
        }
    }
}
