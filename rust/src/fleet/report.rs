//! Fleet report: per-variant aggregation, human-readable rendering, and
//! the machine-readable `fleet` section merged into the `hbvla-bench-v1`
//! JSON report.

use crate::coordinator::metrics::LatencyStats;
use crate::fleet::divergence::{DivergenceBin, DivergenceTracker};
use crate::fleet::drill::{Drill, DrillReport};
use crate::fleet::robot::{Fnv64, Robot, RobotCounters};

fn num(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.6}")
    } else {
        "0.0".to_string()
    }
}

/// Escape a string for inclusion inside a JSON string literal. Variant
/// names are user-controlled (`--variants`); a quote, backslash or
/// control character must not be able to corrupt the report.
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// One variant's fleet-wide outcome.
///
/// Attribution: episode-level outcomes (`robots`, `successes`,
/// `dropped`, `digest`) cover the robots whose FINAL assignment is this
/// variant, while traffic stats (`submits`…`errors`, `divergence`,
/// latency) cover every request/step this variant actually SERVED —
/// including the pre-switch history of robots the hotspot drill later
/// rehomed elsewhere. After a hotspot drill a drained variant can
/// therefore legitimately show `robots: 0` alongside nonzero traffic.
#[derive(Clone, Debug)]
pub struct FleetVariantRow {
    pub variant: String,
    /// Robots whose final assignment is this variant.
    pub robots: usize,
    pub successes: u64,
    /// Successes of the dense reference replays for the SAME robots
    /// (same seeds) — the retention denominator.
    pub reference_successes: u64,
    /// `successes / reference_successes` (1.0 when the reference also
    /// failed everywhere: no retention to lose).
    pub success_retention: f64,
    pub submits: u64,
    pub responses_ok: u64,
    pub retries: u64,
    pub admission_sheds: u64,
    pub deadline_misses: u64,
    pub errors: u64,
    /// Robots that aborted (retry cap / non-retryable error).
    pub dropped: u64,
    pub shed_rate: f64,
    pub miss_rate: f64,
    pub mean_us: f64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub p999_us: u64,
    /// ℓ2-vs-dense-reference by step-index bin (error accumulation),
    /// over the steps this variant served.
    pub divergence: Vec<DivergenceBin>,
    pub max_divergence: f64,
    /// Order-independent variant digest: FNV over `(robot_id, robot
    /// trajectory digest)` in robot-id order.
    pub digest: u64,
}

impl FleetVariantRow {
    /// Fold one variant's row: `members` are the robots whose final
    /// assignment is this variant (episode outcomes + digest), while
    /// `traffic` and `divergence` are the driver's served-variant sums
    /// for it and `latency` the client-observed stats of the responses
    /// it served (absent when no response ever landed).
    pub fn aggregate(
        variant: &str,
        members: &[&Robot],
        traffic: RobotCounters,
        divergence: DivergenceTracker,
        latency: Option<&LatencyStats>,
    ) -> Self {
        let mut successes = 0u64;
        let mut reference_successes = 0u64;
        let mut dropped = 0u64;
        let mut digest = Fnv64::new();
        // Robot-id order makes the digest independent of poll order.
        let mut ordered: Vec<&&Robot> = members.iter().collect();
        ordered.sort_by_key(|r| r.id);
        for r in ordered {
            successes += r.success() as u64;
            reference_successes += r.reference_success as u64;
            dropped += r.dropped as u64;
            digest.update_u64(r.id as u64);
            digest.update_u64(r.trajectory_digest());
        }
        let submits = traffic.submits;
        let rate = |n: u64| if submits > 0 { n as f64 / submits as f64 } else { 0.0 };
        // One sort serves all three ranks (was three clone+sort passes).
        let pcts = latency.map(|l| l.percentiles_us(&[0.50, 0.99, 0.999]));
        let pct = |i: usize| pcts.as_ref().map(|p| p[i]).unwrap_or(0);
        FleetVariantRow {
            variant: variant.to_string(),
            robots: members.len(),
            successes,
            reference_successes,
            success_retention: if reference_successes > 0 {
                successes as f64 / reference_successes as f64
            } else {
                1.0
            },
            submits,
            responses_ok: traffic.responses_ok,
            retries: traffic.retries,
            admission_sheds: traffic.admission_sheds,
            deadline_misses: traffic.deadline_misses,
            errors: traffic.errors,
            dropped,
            shed_rate: rate(traffic.admission_sheds),
            miss_rate: rate(traffic.deadline_misses),
            mean_us: latency.map(|l| l.mean_us()).unwrap_or(0.0),
            p50_us: pct(0),
            p99_us: pct(1),
            p999_us: pct(2),
            divergence: divergence.bins(),
            max_divergence: divergence.max_mean_l2(),
            digest: digest.digest(),
        }
    }

    fn to_json(&self) -> String {
        let bins: Vec<String> = self
            .divergence
            .iter()
            .map(|b| {
                format!(
                    "{{\"from\": {}, \"to\": {}, \"mean_l2\": {}, \"count\": {}}}",
                    b.from,
                    b.to,
                    num(b.mean_l2),
                    b.count
                )
            })
            .collect();
        format!(
            "{{\"variant\": \"{}\", \"robots\": {}, \"successes\": {}, \
             \"reference_successes\": {}, \"success_retention\": {}, \
             \"requests\": {}, \"responses_ok\": {}, \"retries\": {}, \
             \"admission_sheds\": {}, \"deadline_misses\": {}, \"errors\": {}, \
             \"dropped\": {}, \"shed_rate\": {}, \"miss_rate\": {}, \
             \"latency_us\": {{\"mean\": {}, \"p50\": {}, \"p99\": {}, \"p999\": {}}}, \
             \"max_divergence\": {}, \"divergence\": [{}], \"digest\": \"{:016x}\"}}",
            esc(&self.variant),
            self.robots,
            self.successes,
            self.reference_successes,
            num(self.success_retention),
            self.submits,
            self.responses_ok,
            self.retries,
            self.admission_sheds,
            self.deadline_misses,
            self.errors,
            self.dropped,
            num(self.shed_rate),
            num(self.miss_rate),
            num(self.mean_us),
            self.p50_us,
            self.p99_us,
            self.p999_us,
            num(self.max_divergence),
            bins.join(", "),
            self.digest
        )
    }
}

/// The whole run, one row per variant (any variant that held an
/// assignment or served traffic gets a row).
#[derive(Clone, Debug)]
pub struct FleetReport {
    pub robots: usize,
    pub horizon: usize,
    pub seed: u64,
    pub reference: String,
    pub drills: Vec<Drill>,
    pub live_workers_at_end: usize,
    pub total_responses: u64,
    pub wall_secs: f64,
    /// Routing-layer self-healing over the run: successful host re-dials
    /// and requests failed over to a replica (zeros for in-process runs).
    pub router_redials: u64,
    pub router_failovers: u64,
    pub rows: Vec<FleetVariantRow>,
    pub drill_report: DrillReport,
}

impl FleetReport {
    /// Human-readable table.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let drills: Vec<&str> = self.drills.iter().map(|d| d.label()).collect();
        out.push_str(&format!(
            "fleet: {} robots, horizon {}, seed {}, reference {}, drills [{}], {:.1}s, {} workers live, {} responses\n",
            self.robots,
            self.horizon,
            self.seed,
            self.reference,
            drills.join(","),
            self.wall_secs,
            self.live_workers_at_end,
            self.total_responses
        ));
        out.push_str(&format!(
            "{:<18} {:>6} {:>5} {:>5} {:>6} {:>7} {:>7} {:>6} {:>5} {:>5} {:>4} {:>5} {:>7} {:>7} {:>8} {:>9}\n",
            "variant", "robots", "succ", "ref", "reten", "reqs", "ok", "retry", "shed", "miss",
            "err", "drop", "p50us", "p99us", "p999us", "max_div"
        ));
        for r in &self.rows {
            out.push_str(&format!(
                "{:<18} {:>6} {:>5} {:>5} {:>6.3} {:>7} {:>7} {:>6} {:>5} {:>5} {:>4} {:>5} {:>7} {:>7} {:>8} {:>9.4}\n",
                r.variant,
                r.robots,
                r.successes,
                r.reference_successes,
                r.success_retention,
                r.submits,
                r.responses_ok,
                r.retries,
                r.admission_sheds,
                r.deadline_misses,
                r.errors,
                r.dropped,
                r.p50_us,
                r.p99_us,
                r.p999_us,
                r.max_divergence
            ));
            let curve: Vec<String> = r
                .divergence
                .iter()
                .map(|b| format!("[{}-{}) {:.4}", b.from, b.to, b.mean_l2))
                .collect();
            out.push_str(&format!("  divergence-vs-horizon: {}\n", curve.join("  ")));
        }
        let d = &self.drill_report;
        if !self.drills.is_empty() {
            out.push_str(&format!(
                "drills: overload bursts={} (max {}), hotspot switched={}{}, workers {} -> {}{}\n",
                d.overload_bursts,
                d.max_burst_size,
                d.hotspot_switched,
                d.hotspot_variant.as_deref().map(|v| format!(" to {v}")).unwrap_or_default(),
                d.workers_before_loss,
                d.workers_after_loss,
                d.host_killed
                    .as_deref()
                    .map(|h| format!(
                        ", hosts {} -> {} (killed {h})",
                        d.hosts_before_loss, d.hosts_after_loss
                    ))
                    .unwrap_or_default()
            ));
            if let Some(v) = d.variant_killed.as_deref() {
                out.push_str(&format!(
                    "  variant-kill: deregistered {v} mid-run, variants {} -> {}\n",
                    d.variants_before_kill, d.variants_after_kill
                ));
            }
        }
        if self.router_redials > 0 || self.router_failovers > 0 {
            out.push_str(&format!(
                "self-heal: {} host rejoins, {} requests failed over\n",
                self.router_redials, self.router_failovers
            ));
        }
        out
    }

    /// The `fleet` JSON object (schema `hbvla-fleet-v1`) — standalone or
    /// merged into a bench report via [`merge_fleet_json`].
    pub fn to_json(&self) -> String {
        let drills: Vec<String> =
            self.drills.iter().map(|d| format!("\"{}\"", d.label())).collect();
        let rows: Vec<String> = self.rows.iter().map(|r| r.to_json()).collect();
        let d = &self.drill_report;
        format!(
            "{{\"schema\": \"hbvla-fleet-v1\", \"robots\": {}, \"horizon\": {}, \
             \"seed\": {}, \"reference\": \"{}\", \"drills\": [{}], \
             \"live_workers_at_end\": {}, \"total_responses\": {}, \"wall_secs\": {}, \
             \"router\": {{\"redials\": {}, \"failovers\": {}}}, \
             \"variants\": [{}], \
             \"drill_report\": {{\"overload_bursts\": {}, \"max_burst_size\": {}, \
             \"hotspot_switched\": {}, \"hotspot_variant\": {}, \
             \"workers_before_loss\": {}, \"workers_after_loss\": {}, \
             \"hosts_before_loss\": {}, \"hosts_after_loss\": {}, \
             \"host_killed\": {}, \
             \"variant_kill\": {{\"variant\": {}, \"variants_before\": {}, \
             \"variants_after\": {}}}}}}}",
            self.robots,
            self.horizon,
            self.seed,
            esc(&self.reference),
            drills.join(", "),
            self.live_workers_at_end,
            self.total_responses,
            num(self.wall_secs),
            self.router_redials,
            self.router_failovers,
            rows.join(", "),
            d.overload_bursts,
            d.max_burst_size,
            d.hotspot_switched,
            d.hotspot_variant
                .as_deref()
                .map_or_else(|| "null".to_string(), |v| format!("\"{}\"", esc(v))),
            d.workers_before_loss,
            d.workers_after_loss,
            d.hosts_before_loss,
            d.hosts_after_loss,
            d.host_killed
                .as_deref()
                .map_or_else(|| "null".to_string(), |v| format!("\"{}\"", esc(v))),
            d.variant_killed
                .as_deref()
                .map_or_else(|| "null".to_string(), |v| format!("\"{}\"", esc(v))),
            d.variants_before_kill,
            d.variants_after_kill
        )
    }
}

/// Merge a fleet JSON object into an `hbvla-bench-v1` report string as a
/// top-level `"fleet"` key (replacing any previous fleet section). The
/// bench report is the hand-rolled writer's output — last key, two-space
/// indent — so this is deliberately dumb string surgery, not a parser.
pub fn merge_fleet_json(bench: &str, fleet_obj: &str) -> String {
    let trimmed = bench.trim_end();
    let Some(body) = trimmed.strip_suffix('}') else {
        // Not a JSON object at all: emit a standalone wrapper.
        return format!("{{\n  \"fleet\": {fleet_obj}\n}}\n");
    };
    // Drop a previous fleet section; it is always the key we appended
    // last, so truncating at its comma removes exactly that section.
    let body = match body.find(",\n  \"fleet\":") {
        Some(i) => &body[..i],
        None => body,
    };
    let body = body.trim_end();
    format!("{body},\n  \"fleet\": {fleet_obj}\n}}\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_appends_fleet_as_last_key() {
        let bench = "{\n  \"schema\": \"hbvla-bench-v1\",\n  \"pr\": 7,\n  \"act_scale\": [1]\n}\n";
        let merged = merge_fleet_json(bench, "{\"schema\": \"hbvla-fleet-v1\", \"robots\": 4}");
        assert!(merged.contains("\"schema\": \"hbvla-bench-v1\""));
        assert!(merged.ends_with("}\n"));
        assert!(merged.contains(",\n  \"fleet\": {\"schema\": \"hbvla-fleet-v1\", \"robots\": 4}\n}"));
        // Re-merging replaces, never duplicates.
        let again = merge_fleet_json(&merged, "{\"schema\": \"hbvla-fleet-v1\", \"robots\": 8}");
        assert_eq!(again.matches("\"fleet\":").count(), 1);
        assert!(again.contains("\"robots\": 8"));
        assert!(!again.contains("\"robots\": 4"));
        assert!(again.contains("\"act_scale\": [1]"));
    }

    #[test]
    fn merge_tolerates_non_json_input() {
        let out = merge_fleet_json("not json", "{\"robots\": 1}");
        assert!(out.contains("\"fleet\": {\"robots\": 1}"));
        assert!(out.starts_with('{') && out.ends_with("}\n"));
    }

    #[test]
    fn variant_row_digest_is_poll_order_independent() {
        use crate::sim::tasks::libero_suite;
        let task = &libero_suite("object")[0];
        let mk = |id: usize| {
            let mut r = Robot::new(id, "dense".into(), task.clone(), 7, 16, Vec::new(), true);
            // Execute a few steps locally so the digest is non-trivial.
            r.accept_chunk(vec![vec![0.1; 7]; 4]);
            r.advance();
            r
        };
        let mk_row = |robots: &[&Robot]| {
            FleetVariantRow::aggregate(
                "dense",
                robots,
                RobotCounters::default(),
                DivergenceTracker::new(16),
                None,
            )
        };
        let (a, b) = (mk(0), mk(1));
        let fwd = mk_row(&[&a, &b]);
        let rev = mk_row(&[&b, &a]);
        assert_eq!(fwd.digest, rev.digest);
        assert_eq!(fwd.robots, 2);
        // Zero reference successes -> retention defined as 1.0.
        let c = Robot::new(2, "dense".into(), task.clone(), 8, 16, Vec::new(), false);
        let row = mk_row(&[&c]);
        assert_eq!(row.reference_successes, 0);
        assert_eq!(row.success_retention, 1.0);
    }

    #[test]
    fn json_strings_are_escaped() {
        assert_eq!(esc("plain-name"), "plain-name");
        assert_eq!(esc("a\"b\\c"), "a\\\"b\\\\c");
        assert_eq!(esc("tab\there\nnl\u{1}"), "tab\\there\\nnl\\u0001");
        // A hostile --variants name must not break the report's JSON.
        let row = FleetVariantRow::aggregate(
            "evil\"variant\\",
            &[],
            RobotCounters::default(),
            DivergenceTracker::new(8),
            None,
        );
        let json = row.to_json();
        assert!(json.contains("\"variant\": \"evil\\\"variant\\\\\""), "{json}");
    }
}
