//! Action divergence vs horizon — the paper's error-accumulation claim,
//! measured closed-loop.
//!
//! For every executed step of a served episode, the ℓ2 distance to the
//! dense reference trajectory's action *at the same step index* (same
//! seed, same scene, same observation noise stream) is accumulated into
//! one of [`DIVERGENCE_BINS`] step-index bins. A quantized variant whose
//! error compounds shows monotonically growing `mean_l2` across bins;
//! a variant serving the reference model exactly shows all-zero bins —
//! which is precisely the fleet determinism test's anchor.

/// Step-index bins per horizon. Eight is enough to see the shape of the
/// accumulation curve without drowning the JSON report.
pub const DIVERGENCE_BINS: usize = 8;

/// One rendered bin: steps in `[from, to)`.
#[derive(Clone, Debug, PartialEq)]
pub struct DivergenceBin {
    pub from: usize,
    pub to: usize,
    pub mean_l2: f64,
    pub count: u64,
}

/// Accumulates per-step ℓ2 divergence, binned by step index over a fixed
/// horizon. Merging is exact (sums + counts), so per-robot trackers fold
/// into per-variant ones without approximation.
#[derive(Clone, Debug)]
pub struct DivergenceTracker {
    horizon: usize,
    sum_l2: [f64; DIVERGENCE_BINS],
    count: [u64; DIVERGENCE_BINS],
}

impl DivergenceTracker {
    pub fn new(horizon: usize) -> Self {
        DivergenceTracker {
            horizon: horizon.max(1),
            sum_l2: [0.0; DIVERGENCE_BINS],
            count: [0; DIVERGENCE_BINS],
        }
    }

    fn bin_of(&self, step: usize) -> usize {
        (step * DIVERGENCE_BINS / self.horizon).min(DIVERGENCE_BINS - 1)
    }

    /// Record one executed step: ℓ2 between the served action and the
    /// reference action at the same step index.
    pub fn record(&mut self, step: usize, served: &[f32], reference: &[f32]) {
        let mut s = 0.0f64;
        for (a, b) in served.iter().zip(reference) {
            let d = (*a - *b) as f64;
            s += d * d;
        }
        let b = self.bin_of(step);
        self.sum_l2[b] += s.sqrt();
        self.count[b] += 1;
    }

    /// Fold another tracker (same horizon) into this one.
    pub fn merge(&mut self, other: &DivergenceTracker) {
        debug_assert_eq!(self.horizon, other.horizon);
        for i in 0..DIVERGENCE_BINS {
            self.sum_l2[i] += other.sum_l2[i];
            self.count[i] += other.count[i];
        }
    }

    /// Steps recorded across all bins.
    pub fn total_steps(&self) -> u64 {
        self.count.iter().sum()
    }

    /// Largest per-bin mean — a quick "is anything diverging" scalar.
    pub fn max_mean_l2(&self) -> f64 {
        self.bins().iter().map(|b| b.mean_l2).fold(0.0, f64::max)
    }

    pub fn bins(&self) -> Vec<DivergenceBin> {
        (0..DIVERGENCE_BINS)
            .map(|i| {
                let from = i * self.horizon / DIVERGENCE_BINS;
                let to = if i + 1 == DIVERGENCE_BINS {
                    self.horizon
                } else {
                    (i + 1) * self.horizon / DIVERGENCE_BINS
                };
                DivergenceBin {
                    from,
                    to,
                    mean_l2: if self.count[i] > 0 {
                        self.sum_l2[i] / self.count[i] as f64
                    } else {
                        0.0
                    },
                    count: self.count[i],
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bins_partition_the_horizon() {
        let t = DivergenceTracker::new(64);
        let bins = t.bins();
        assert_eq!(bins.len(), DIVERGENCE_BINS);
        assert_eq!(bins[0].from, 0);
        assert_eq!(bins.last().unwrap().to, 64);
        for w in bins.windows(2) {
            assert_eq!(w[0].to, w[1].from);
        }
    }

    #[test]
    fn records_into_step_bins_and_merges_exactly() {
        let mut a = DivergenceTracker::new(8);
        // Step 0 (bin 0): l2 = 5 (3-4-5 triangle); step 7 (bin 7): l2 = 1.
        a.record(0, &[3.0, 0.0], &[0.0, 4.0]);
        a.record(7, &[1.0, 0.0], &[0.0, 0.0]);
        let mut b = DivergenceTracker::new(8);
        b.record(0, &[0.0, 0.0], &[0.0, 0.0]);
        a.merge(&b);
        let bins = a.bins();
        assert_eq!(bins[0].count, 2);
        assert!((bins[0].mean_l2 - 2.5).abs() < 1e-12);
        assert_eq!(bins[7].count, 1);
        assert!((bins[7].mean_l2 - 1.0).abs() < 1e-12);
        assert_eq!(a.total_steps(), 3);
        assert!((a.max_mean_l2() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn short_horizon_clamps_into_last_bin() {
        // Horizon shorter than the bin count: every step still lands in a
        // valid bin.
        let mut t = DivergenceTracker::new(3);
        for step in 0..3 {
            t.record(step, &[1.0], &[0.0]);
        }
        assert_eq!(t.total_steps(), 3);
        // Past-horizon steps (defensive) clamp instead of panicking.
        t.record(99, &[1.0], &[0.0]);
        assert_eq!(t.total_steps(), 4);
    }

    #[test]
    fn identical_trajectories_are_zero() {
        let mut t = DivergenceTracker::new(16);
        for step in 0..16 {
            let a = [0.25f32, -0.5, 1.0];
            t.record(step, &a, &a);
        }
        assert_eq!(t.max_mean_l2(), 0.0);
        assert_eq!(t.total_steps(), 16);
    }
}
