//! Scripted fault drills: controlled failure injection while the fleet
//! is live, verifying graceful degradation (typed errors only — never a
//! hang, never a panic, never a silently-dropped request).

/// The fault families the harness can inject mid-run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Drill {
    /// N robots synchronize their submits into one burst: the driver
    /// parks ready-to-submit robots until the gather target is reached,
    /// then releases them back-to-back — a queue-depth spike that must
    /// surface as `Overloaded`/`DeadlineExceeded`, not as stalls.
    Overload,
    /// Traffic skews to one variant mid-run: every other robot not
    /// already on the hot variant (the first non-reference entry of the
    /// variant menu — never the divergence anchor) permanently switches
    /// to it, collapsing the server's variant mix. Rehomed robots keep
    /// their pre-switch serving history attributed to the old variant.
    Hotspot,
    /// The server loses workers mid-run (`shrink_workers`): capacity
    /// halves, in-flight requests must still all be answered.
    WorkerLoss,
    /// Multi-host fleets only: a live host is killed mid-run. In-flight
    /// requests on it surface as typed `WorkerDropped` (or fail over to a
    /// replica when the router runs with `--replicas > 1`), the router
    /// re-homes its variants along the placement probe sequence, and the
    /// fleet must drain with zero hangs. Requires a client with more
    /// than one host (`fleet --hosts N`); a single-process fleet rejects
    /// it at config parse.
    HostLoss,
    /// A hot model variant is DEREGISTERED mid-run (registry hot-swap's
    /// remove path): in-flight batches finish on the weights they hold,
    /// every later resolve fails with a typed `UnknownVariant`, and the
    /// fleet's accounting invariant must still close — no panics, no
    /// hangs. The victim is the first non-reference variant (never the
    /// divergence anchor). Works at every deployment shape.
    VariantKill,
}

impl Drill {
    pub fn label(&self) -> &'static str {
        match self {
            Drill::Overload => "overload",
            Drill::Hotspot => "hotspot",
            Drill::WorkerLoss => "worker-loss",
            Drill::HostLoss => "host-loss",
            Drill::VariantKill => "variant-kill",
        }
    }
}

/// Why a `--drill` spec was rejected — typed, so the CLI can explain the
/// failure instead of silently dropping a drill.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DrillParseError {
    /// A token named no known drill.
    Unknown(String),
    /// The drill is real but invalid for this deployment shape (e.g.
    /// `host-loss` on a single-process fleet).
    NeedsHosts(Drill),
}

impl std::fmt::Display for DrillParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DrillParseError::Unknown(tok) => write!(f, "unknown drill '{tok}'"),
            DrillParseError::NeedsHosts(d) => {
                write!(f, "drill '{}' needs a multi-host fleet (--hosts 2 or more)", d.label())
            }
        }
    }
}

impl std::error::Error for DrillParseError {}

/// Parse a `--drill` spec against the deployment shape (`hosts` = live
/// host count; single-process fleets pass 1): `none`, a drill name, a
/// comma list, or `all`. `all` expands to EVERY drill valid at this
/// shape — `host-loss` joins it when the fleet is multi-host, and is a
/// typed [`DrillParseError::NeedsHosts`] (never a silent omission) when
/// named explicitly without one.
pub fn parse_drills(spec: &str, hosts: usize) -> Result<Vec<Drill>, DrillParseError> {
    let spec = spec.trim().to_ascii_lowercase();
    if spec.is_empty() || spec == "none" {
        return Ok(Vec::new());
    }
    if spec == "all" {
        let mut all = vec![Drill::Overload, Drill::Hotspot, Drill::WorkerLoss];
        if hosts >= 2 {
            all.push(Drill::HostLoss);
        }
        all.push(Drill::VariantKill);
        return Ok(all);
    }
    let mut out = Vec::new();
    for tok in spec.split(',') {
        let d = match tok.trim() {
            "overload" => Drill::Overload,
            "hotspot" => Drill::Hotspot,
            "worker-loss" | "workerloss" | "worker_loss" => Drill::WorkerLoss,
            "host-loss" | "hostloss" | "host_loss" => Drill::HostLoss,
            "variant-kill" | "variantkill" | "variant_kill" => Drill::VariantKill,
            other => return Err(DrillParseError::Unknown(other.to_string())),
        };
        if d == Drill::HostLoss && hosts < 2 {
            return Err(DrillParseError::NeedsHosts(d));
        }
        if !out.contains(&d) {
            out.push(d);
        }
    }
    Ok(out)
}

/// What actually happened when the drills fired — rendered into the
/// fleet report so a run is auditable after the fact.
#[derive(Clone, Debug, Default)]
pub struct DrillReport {
    /// Overload bursts released, and the size of the largest one.
    pub overload_bursts: u64,
    pub max_burst_size: u64,
    /// Robots whose assignment switched to the hot variant.
    pub hotspot_switched: u64,
    pub hotspot_variant: Option<String>,
    /// Live workers observed immediately before / after the loss drill
    /// (after = the shrink target; convergence is asserted by tests).
    pub workers_before_loss: usize,
    pub workers_after_loss: usize,
    /// Live hosts observed immediately before / after the host-loss
    /// drill, and the address of the host it killed (multi-host fleets).
    pub hosts_before_loss: usize,
    pub hosts_after_loss: usize,
    pub host_killed: Option<String>,
    /// The variant the variant-kill drill deregistered (`None` = drill
    /// not run or nothing killable), and the registry size around it.
    pub variant_killed: Option<String>,
    pub variants_before_kill: usize,
    pub variants_after_kill: usize,
}

/// One drill armed at a progress trigger point.
#[derive(Clone, Debug)]
pub struct Scheduled {
    pub drill: Drill,
    /// Fires once fleet progress (responses-received or robots-done
    /// fraction, whichever leads) crosses this fraction. Progress-based,
    /// not time-based, so drill timing is reproducible across machines.
    pub at_progress: f64,
    pub fired: bool,
}

/// Spreads the requested drills across the run (a single drill fires
/// mid-run; several fire at evenly spaced progress points).
pub fn schedule(drills: &[Drill]) -> Vec<Scheduled> {
    let n = drills.len();
    drills
        .iter()
        .enumerate()
        .map(|(i, &d)| Scheduled {
            drill: d,
            at_progress: (i + 1) as f64 / (n + 1) as f64,
            fired: false,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_specs() {
        assert_eq!(parse_drills("none", 1), Ok(vec![]));
        assert_eq!(parse_drills("", 1), Ok(vec![]));
        assert_eq!(parse_drills("overload", 1), Ok(vec![Drill::Overload]));
        assert_eq!(
            parse_drills("worker-loss,hotspot", 1),
            Ok(vec![Drill::WorkerLoss, Drill::Hotspot])
        );
        // Duplicates collapse; unknown tokens are a typed parse failure.
        assert_eq!(parse_drills("overload,overload", 1), Ok(vec![Drill::Overload]));
        assert_eq!(
            parse_drills("chaos-monkey", 1),
            Err(DrillParseError::Unknown("chaos-monkey".to_string()))
        );
        assert_eq!(parse_drills("variant-kill", 1), Ok(vec![Drill::VariantKill]));
        assert_eq!(
            parse_drills("variant_kill,overload", 1),
            Ok(vec![Drill::VariantKill, Drill::Overload])
        );
    }

    #[test]
    fn all_expands_to_every_drill_valid_for_the_shape() {
        // Single-process: every single-process drill — including the new
        // variant-kill — but NOT host-loss (no hosts to kill).
        assert_eq!(
            parse_drills("all", 1),
            Ok(vec![Drill::Overload, Drill::Hotspot, Drill::WorkerLoss, Drill::VariantKill])
        );
        // Multi-host: host-loss joins the expansion instead of being
        // silently omitted.
        assert_eq!(
            parse_drills("all", 3),
            Ok(vec![
                Drill::Overload,
                Drill::Hotspot,
                Drill::WorkerLoss,
                Drill::HostLoss,
                Drill::VariantKill,
            ])
        );
    }

    #[test]
    fn host_loss_without_hosts_is_a_typed_error_not_an_omission() {
        assert_eq!(
            parse_drills("host-loss", 1),
            Err(DrillParseError::NeedsHosts(Drill::HostLoss))
        );
        assert_eq!(
            parse_drills("overload,host-loss", 1),
            Err(DrillParseError::NeedsHosts(Drill::HostLoss))
        );
        assert_eq!(parse_drills("host-loss", 2), Ok(vec![Drill::HostLoss]));
        assert_eq!(
            parse_drills("host_loss,overload", 2),
            Ok(vec![Drill::HostLoss, Drill::Overload])
        );
        let msg = DrillParseError::NeedsHosts(Drill::HostLoss).to_string();
        assert!(msg.contains("host-loss") && msg.contains("--hosts"), "{msg}");
    }

    #[test]
    fn schedule_spreads_progress_points() {
        let s = schedule(&[Drill::Overload, Drill::Hotspot, Drill::WorkerLoss]);
        assert_eq!(s.len(), 3);
        assert!((s[0].at_progress - 0.25).abs() < 1e-12);
        assert!((s[1].at_progress - 0.50).abs() < 1e-12);
        assert!((s[2].at_progress - 0.75).abs() < 1e-12);
        let single = schedule(&[Drill::WorkerLoss]);
        assert!((single[0].at_progress - 0.5).abs() < 1e-12);
        assert!(schedule(&[]).is_empty());
    }
}
