//! Scripted fault drills: controlled failure injection while the fleet
//! is live, verifying graceful degradation (typed errors only — never a
//! hang, never a panic, never a silently-dropped request).

/// The fault families the harness can inject mid-run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Drill {
    /// N robots synchronize their submits into one burst: the driver
    /// parks ready-to-submit robots until the gather target is reached,
    /// then releases them back-to-back — a queue-depth spike that must
    /// surface as `Overloaded`/`DeadlineExceeded`, not as stalls.
    Overload,
    /// Traffic skews to one variant mid-run: every other robot not
    /// already on the hot variant (the first non-reference entry of the
    /// variant menu — never the divergence anchor) permanently switches
    /// to it, collapsing the server's variant mix. Rehomed robots keep
    /// their pre-switch serving history attributed to the old variant.
    Hotspot,
    /// The server loses workers mid-run (`shrink_workers`): capacity
    /// halves, in-flight requests must still all be answered.
    WorkerLoss,
    /// Multi-host fleets only: a live host is killed mid-run. In-flight
    /// requests on it surface as typed `WorkerDropped`, the router
    /// re-homes its variants along the placement probe sequence, and the
    /// fleet must drain with zero hangs. Requires a client with more
    /// than one host (`fleet --hosts N`); a single-process fleet rejects
    /// it at config parse.
    HostLoss,
}

impl Drill {
    pub fn label(&self) -> &'static str {
        match self {
            Drill::Overload => "overload",
            Drill::Hotspot => "hotspot",
            Drill::WorkerLoss => "worker-loss",
            Drill::HostLoss => "host-loss",
        }
    }
}

/// Parse a `--drill` spec: `none`, `overload`, `hotspot`, `worker-loss`,
/// `host-loss`, `all`, or a comma list of the named drills. `None` =
/// unknown token. `all` stays the three single-process drills —
/// `host-loss` is opted into explicitly because it needs `--hosts`.
pub fn parse_drills(spec: &str) -> Option<Vec<Drill>> {
    let spec = spec.trim().to_ascii_lowercase();
    if spec.is_empty() || spec == "none" {
        return Some(Vec::new());
    }
    if spec == "all" {
        return Some(vec![Drill::Overload, Drill::Hotspot, Drill::WorkerLoss]);
    }
    let mut out = Vec::new();
    for tok in spec.split(',') {
        let d = match tok.trim() {
            "overload" => Drill::Overload,
            "hotspot" => Drill::Hotspot,
            "worker-loss" | "workerloss" | "worker_loss" => Drill::WorkerLoss,
            "host-loss" | "hostloss" | "host_loss" => Drill::HostLoss,
            _ => return None,
        };
        if !out.contains(&d) {
            out.push(d);
        }
    }
    Some(out)
}

/// What actually happened when the drills fired — rendered into the
/// fleet report so a run is auditable after the fact.
#[derive(Clone, Debug, Default)]
pub struct DrillReport {
    /// Overload bursts released, and the size of the largest one.
    pub overload_bursts: u64,
    pub max_burst_size: u64,
    /// Robots whose assignment switched to the hot variant.
    pub hotspot_switched: u64,
    pub hotspot_variant: Option<String>,
    /// Live workers observed immediately before / after the loss drill
    /// (after = the shrink target; convergence is asserted by tests).
    pub workers_before_loss: usize,
    pub workers_after_loss: usize,
    /// Live hosts observed immediately before / after the host-loss
    /// drill, and the address of the host it killed (multi-host fleets).
    pub hosts_before_loss: usize,
    pub hosts_after_loss: usize,
    pub host_killed: Option<String>,
}

/// One drill armed at a progress trigger point.
#[derive(Clone, Debug)]
pub struct Scheduled {
    pub drill: Drill,
    /// Fires once fleet progress (responses-received or robots-done
    /// fraction, whichever leads) crosses this fraction. Progress-based,
    /// not time-based, so drill timing is reproducible across machines.
    pub at_progress: f64,
    pub fired: bool,
}

/// Spreads the requested drills across the run (a single drill fires
/// mid-run; several fire at evenly spaced progress points).
pub fn schedule(drills: &[Drill]) -> Vec<Scheduled> {
    let n = drills.len();
    drills
        .iter()
        .enumerate()
        .map(|(i, &d)| Scheduled {
            drill: d,
            at_progress: (i + 1) as f64 / (n + 1) as f64,
            fired: false,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_specs() {
        assert_eq!(parse_drills("none"), Some(vec![]));
        assert_eq!(parse_drills(""), Some(vec![]));
        assert_eq!(parse_drills("overload"), Some(vec![Drill::Overload]));
        assert_eq!(
            parse_drills("all"),
            Some(vec![Drill::Overload, Drill::Hotspot, Drill::WorkerLoss])
        );
        assert_eq!(
            parse_drills("worker-loss,hotspot"),
            Some(vec![Drill::WorkerLoss, Drill::Hotspot])
        );
        // Duplicates collapse; unknown tokens are a typed parse failure.
        assert_eq!(parse_drills("overload,overload"), Some(vec![Drill::Overload]));
        assert_eq!(parse_drills("chaos-monkey"), None);
        // host-loss is explicit opt-in — never part of `all` (it needs a
        // multi-host client).
        assert_eq!(parse_drills("host-loss"), Some(vec![Drill::HostLoss]));
        assert_eq!(parse_drills("host_loss,overload"), Some(vec![Drill::HostLoss, Drill::Overload]));
        assert!(!parse_drills("all").unwrap().contains(&Drill::HostLoss));
    }

    #[test]
    fn schedule_spreads_progress_points() {
        let s = schedule(&[Drill::Overload, Drill::Hotspot, Drill::WorkerLoss]);
        assert_eq!(s.len(), 3);
        assert!((s[0].at_progress - 0.25).abs() < 1e-12);
        assert!((s[1].at_progress - 0.50).abs() < 1e-12);
        assert!((s[2].at_progress - 0.75).abs() < 1e-12);
        let single = schedule(&[Drill::WorkerLoss]);
        assert!((single[0].at_progress - 0.5).abs() < 1e-12);
        assert!(schedule(&[]).is_empty());
    }
}
