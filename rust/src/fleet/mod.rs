//! Fleet-scale closed-loop replay harness.
//!
//! HBVLA's central claim is that binarization error *accumulates under
//! long-horizon closed-loop execution* — single-episode evals and
//! synthetic request streams never exercise that claim at serving scale.
//! This subsystem does: a [`driver::run_fleet`] drives hundreds to
//! thousands of concurrent simulated robots, each owning a seeded
//! [`crate::sim::episode::EpisodeCursor`] over a heterogeneous task mix,
//! stepping its environment locally and submitting observations to a
//! shared [`crate::coordinator::server::PolicyServer`] with a per-robot
//! variant assignment and deadline budget.
//!
//! Per variant, the harness tracks:
//! - **success-rate retention** vs a locally-replayed dense reference of
//!   the same seeds,
//! - **action divergence vs horizon** — per-step ℓ2 between the served
//!   trajectory and the dense closed-loop trajectory, binned by step
//!   index ([`divergence`]),
//! - shed / deadline-miss / drop rates and client-observed latency
//!   percentiles (p50/p99/p99.9),
//!
//! emitted as a `fleet` section merged into the `hbvla-bench-v1` JSON
//! report ([`report`]). Scripted **fault drills** ([`drill`]) exercise
//! overload bursts, variant hot-spots, worker loss, mid-run variant
//! deregistration (registry hot-swap) and (on multi-host fleets)
//! whole-host loss; the contract is graceful degradation — no hangs,
//! typed errors only.
//!
//! The serving surface is abstracted behind [`driver::FleetClient`]: the
//! same robot loop drives an in-process `PolicyServer` or a
//! [`crate::coordinator::router::LocalCluster`] whose every request
//! crosses the TCP wire router (`fleet --hosts N`).
//!
//! Determinism: with the chunk action head, served decodes consume no
//! server-side randomness and batched execution is bit-identical to
//! sequential, so a fixed fleet seed reproduces identical per-robot
//! trajectories (and fleet report counters) across worker counts.

pub mod divergence;
pub mod drill;
pub mod driver;
pub mod report;
pub mod robot;

pub use divergence::{DivergenceBin, DivergenceTracker, DIVERGENCE_BINS};
pub use drill::{parse_drills, Drill, DrillParseError, DrillReport};
pub use driver::{run_fleet, run_fleet_on, FleetClient, FleetConfig, FleetError};
pub use report::{merge_fleet_json, FleetReport, FleetVariantRow};
pub use robot::{Fnv64, Robot, RobotCounters, ServedStats};
