//! One simulated robot: a seeded closed-loop episode, a serving variant
//! assignment, and the bookkeeping (digest, divergence, typed-error
//! counters) the fleet report aggregates.

use std::time::Instant;

use crate::coordinator::server::ResponseHandle;
use crate::fleet::divergence::DivergenceTracker;
use crate::model::MiniVla;
use crate::sim::episode::{CursorState, EpisodeCursor, EpisodeResult};
use crate::sim::observe::{Observation, ObsParams};
use crate::sim::tasks::Task;

/// FNV-1a 64-bit over executed-action f32 bit patterns: a trajectory
/// identity cheap enough to compute per step and stable across platforms
/// (bit patterns, not formatted floats).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Fnv64(u64);

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv64 {
    pub fn new() -> Self {
        Fnv64(0xcbf2_9ce4_8422_2325)
    }

    #[inline]
    pub fn update_u64(&mut self, v: u64) {
        for b in v.to_le_bytes() {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    pub fn update_f32s(&mut self, xs: &[f32]) {
        for &x in xs {
            self.update_u64(x.to_bits() as u64);
        }
    }

    pub fn digest(&self) -> u64 {
        self.0
    }
}

/// Where a robot is in its submit/serve cycle.
pub enum Phase {
    /// May advance its episode; submits when the queue runs dry.
    Ready,
    /// Parked by the overload drill, observation cached, submit withheld.
    Gathered,
    /// A request is in flight.
    Waiting(ResponseHandle),
    /// Backing off after a typed serving error; resubmits at `until`.
    BackOff { until: Instant },
    /// Holding a fresh decode until the robot's next control-period tick
    /// (`fleet --control-hz`): the observation is cached, the submit is
    /// withheld until `until`. Unlike `BackOff` this is pacing, not
    /// error recovery — it touches no retry bookkeeping.
    Paced { until: Instant },
    /// Episode over (outcome recorded) or aborted (dropped counted).
    Done,
}

/// Typed-error accounting. The accounting invariant the worker-loss
/// drill test pins: every submit attempt is either answered OK or lands
/// in exactly one error counter —
/// `submits == responses_ok + admission_sheds + deadline_misses + errors`
/// once the fleet drains (nothing in flight, nothing silent).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RobotCounters {
    /// Submit attempts (including ones shed at admission).
    pub submits: u64,
    /// Served responses.
    pub responses_ok: u64,
    /// Shed at submit with `Overloaded`.
    pub admission_sheds: u64,
    /// Failed at dispatch with `DeadlineExceeded`.
    pub deadline_misses: u64,
    /// Every other typed error (`Stopped`, `WorkerDropped`, …).
    pub errors: u64,
    /// Resubmits of the same decode (after shed/miss/error).
    pub retries: u64,
}

impl RobotCounters {
    /// Elementwise sum — folds per-robot counters into a variant row.
    pub fn add(&mut self, other: &RobotCounters) {
        self.submits += other.submits;
        self.responses_ok += other.responses_ok;
        self.admission_sheds += other.admission_sheds;
        self.deadline_misses += other.deadline_misses;
        self.errors += other.errors;
        self.retries += other.retries;
    }
}

/// One robot's traffic against one serving variant: request counters and
/// the divergence of the steps that variant actually served. Kept per
/// served variant (not per robot) so a robot the hotspot drill rehomes
/// leaves its pre-switch history on the old variant instead of polluting
/// the new one's row — in particular the reference row stays the
/// zero-divergence anchor no matter which drills ran.
#[derive(Clone, Debug)]
pub struct ServedStats {
    pub counters: RobotCounters,
    pub divergence: DivergenceTracker,
}

impl ServedStats {
    fn new(horizon: usize) -> Self {
        ServedStats {
            counters: RobotCounters::default(),
            divergence: DivergenceTracker::new(horizon),
        }
    }
}

/// A fleet robot: episode cursor + serving assignment + stats.
pub struct Robot {
    pub id: usize,
    /// Current serving assignment: where the NEXT submit routes, and the
    /// row this robot's episode-level outcome (success, digest, drop) is
    /// reported under. The hotspot drill rewrites it via [`Robot::rehome`].
    pub variant: String,
    pub phase: Phase,
    cursor: EpisodeCursor,
    horizon: usize,
    /// The pending decode's observation. Built exactly once per decode
    /// and REUSED on every retry — rebuilding would consume the episode
    /// rng again and silently fork the trajectory off its seed.
    pending_obs: Option<Observation>,
    /// Dense closed-loop reference of the same seed: executed actions by
    /// step index, and whether the reference episode succeeded.
    reference_actions: Vec<Vec<f32>>,
    pub reference_success: bool,
    /// Traffic stats keyed by the variant that actually served them:
    /// counters by the variant targeted at submit time, divergence by
    /// the variant that served the executed chunk.
    served: Vec<(String, ServedStats)>,
    /// Variant targeted by the pending/in-flight decode (set at submit,
    /// so a mid-flight rehome never re-attributes the response).
    active_variant: String,
    /// Variant that served the chunk currently being executed.
    chunk_variant: String,
    /// Consecutive failures of the current decode (resets on success).
    pub retries_this_decode: u32,
    /// True if the episode was aborted (retry cap / non-retryable error).
    pub dropped: bool,
    digest: Fnv64,
    outcome: Option<EpisodeResult>,
}

impl Robot {
    pub fn new(
        id: usize,
        variant: String,
        task: Task,
        seed: u64,
        horizon: usize,
        reference_actions: Vec<Vec<f32>>,
        reference_success: bool,
    ) -> Self {
        Robot {
            id,
            active_variant: variant.clone(),
            chunk_variant: variant.clone(),
            variant,
            phase: Phase::Ready,
            cursor: EpisodeCursor::new(task, seed, Some(horizon)),
            horizon,
            pending_obs: None,
            reference_actions,
            reference_success,
            served: Vec::new(),
            retries_this_decode: 0,
            dropped: false,
            digest: Fnv64::new(),
            outcome: None,
        }
    }

    /// Find-or-insert the stats slot for a served variant.
    fn stats_index(&mut self, variant: &str) -> usize {
        match self.served.iter().position(|(v, _)| v == variant) {
            Some(i) => i,
            None => {
                self.served.push((variant.to_string(), ServedStats::new(self.horizon)));
                self.served.len() - 1
            }
        }
    }

    /// Route the pending decode to the current assignment and count the
    /// submit attempt against it. Must precede every `submit_async`.
    pub fn begin_submit(&mut self) {
        if self.active_variant != self.variant {
            self.active_variant = self.variant.clone();
        }
        self.serving_counters_mut().submits += 1;
    }

    /// The variant serving (or last targeted by) the pending decode.
    pub fn serving_variant(&self) -> &str {
        &self.active_variant
    }

    /// Counters of the variant serving the pending/in-flight decode —
    /// where submit/response events are attributed, even if the robot
    /// was rehomed while the request was in flight.
    pub fn serving_counters_mut(&mut self) -> &mut RobotCounters {
        let v = self.active_variant.clone();
        let i = self.stats_index(&v);
        &mut self.served[i].1.counters
    }

    /// Per-served-variant traffic stats, in first-served order.
    pub fn served(&self) -> &[(String, ServedStats)] {
        &self.served
    }

    /// Traffic stats for one served variant, if any traffic went there.
    pub fn served_stats(&self, variant: &str) -> Option<&ServedStats> {
        self.served.iter().find(|(v, _)| v == variant).map(|(_, s)| s)
    }

    /// Hotspot drill: permanently reassign this robot. Only future
    /// submits route to the new variant — traffic already attributed
    /// (including any in-flight request) stays with the variant that
    /// served it.
    pub fn rehome(&mut self, variant: String) {
        self.variant = variant;
    }

    /// Execute queued actions, folding each into the trajectory digest
    /// and the serving variant's divergence-vs-reference bins.
    pub fn advance(&mut self) -> CursorState {
        let idx = {
            let v = self.chunk_variant.clone();
            self.stats_index(&v)
        };
        let Robot { cursor, reference_actions, digest, served, .. } = self;
        let divergence = &mut served[idx].1.divergence;
        let state = cursor.advance(|step, action| {
            digest.update_f32s(action);
            if let Some(reference) = reference_actions.get(step) {
                divergence.record(step, action, reference);
            }
        });
        if state == CursorState::Done {
            self.outcome = cursor.outcome();
        }
        state
    }

    /// The cached observation for the pending decode, building it (one
    /// rng consumption) only if absent.
    pub fn obs_for_decode(&mut self, model: &MiniVla, params: &ObsParams) -> &Observation {
        if self.pending_obs.is_none() {
            self.pending_obs = Some(self.cursor.observation(model, params));
        }
        self.pending_obs.as_ref().expect("just set")
    }

    /// The cached pending observation, if a decode is outstanding.
    pub fn pending_obs(&self) -> Option<&Observation> {
        self.pending_obs.as_ref()
    }

    /// A served chunk arrived: feed it to the episode and clear the
    /// pending decode. The chunk's steps will be attributed to the
    /// variant that served it (the submit-time target), not to any
    /// assignment a drill applied while the request was in flight.
    pub fn accept_chunk(&mut self, actions: Vec<Vec<f32>>) {
        if self.chunk_variant != self.active_variant {
            self.chunk_variant = self.active_variant.clone();
        }
        self.cursor.push_chunk(actions);
        self.pending_obs = None;
        self.retries_this_decode = 0;
    }

    /// Abort the episode (retry cap exhausted or non-retryable error):
    /// counts as dropped, never as a success.
    pub fn abort(&mut self) {
        self.dropped = true;
        self.phase = Phase::Done;
    }

    pub fn finished(&self) -> bool {
        matches!(self.phase, Phase::Done)
    }

    pub fn success(&self) -> bool {
        !self.dropped && self.outcome.as_ref().map(|o| o.success).unwrap_or(false)
    }

    pub fn steps_executed(&self) -> usize {
        self.cursor.step_index()
    }

    pub fn task_name(&self) -> &str {
        &self.cursor.task().name
    }

    pub fn trajectory_digest(&self) -> u64 {
        self.digest.digest()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_is_order_sensitive_and_stable() {
        let mut a = Fnv64::new();
        a.update_f32s(&[1.0, 2.0]);
        let mut b = Fnv64::new();
        b.update_f32s(&[2.0, 1.0]);
        assert_ne!(a.digest(), b.digest());
        let mut c = Fnv64::new();
        c.update_f32s(&[1.0, 2.0]);
        assert_eq!(a.digest(), c.digest());
        // ±0.0 have different bit patterns — digests must see that.
        let mut p = Fnv64::new();
        p.update_f32s(&[0.0]);
        let mut n = Fnv64::new();
        n.update_f32s(&[-0.0]);
        assert_ne!(p.digest(), n.digest());
    }

    #[test]
    fn fnv_matches_reference_vector() {
        // FNV-1a 64 of the bytes of 0u64 (eight 0x00 bytes), from the
        // canonical offset basis and prime.
        let mut h = Fnv64::new();
        h.update_u64(0);
        let mut expect = 0xcbf2_9ce4_8422_2325u64;
        for _ in 0..8 {
            expect ^= 0;
            expect = expect.wrapping_mul(0x0000_0100_0000_01b3);
        }
        assert_eq!(h.digest(), expect);
    }
}
