//! The fleet driver: builds N seeded robots over a heterogeneous task
//! mix, replays their dense references locally, then drives every robot
//! against a shared serving surface — an in-process [`PolicyServer`] or
//! a multi-host [`LocalCluster`] behind the wire router — until all
//! episodes finish, while a drill scheduler injects scripted faults at
//! fixed progress points.
//!
//! The driver is a single-threaded poll loop over robot state machines;
//! all concurrency lives server-side. That keeps the client determinism
//! argument trivial: robot trajectories depend only on their episode
//! seeds and the served actions (bit-identical across batch compositions
//! and worker counts for deterministic heads), never on poll timing.
//! Timing only moves *latency* samples and, under deadline budgets, the
//! shed/miss split — which is exactly what the fault drills probe.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::metrics::LatencyStats;
use crate::coordinator::registry::ModelRegistry;
use crate::coordinator::router::LocalCluster;
use crate::coordinator::server::{PolicyServer, ResponseHandle, ServeError, ServeRequest};
use crate::fleet::divergence::DivergenceTracker;
use crate::fleet::drill::{schedule, Drill, DrillReport};
use crate::fleet::report::{FleetReport, FleetVariantRow};
use crate::fleet::robot::{Phase, Robot, RobotCounters};
use crate::model::MiniVla;
use crate::sim::episode::{CursorState, EpisodeCursor};
use crate::sim::observe::ObsParams;
use crate::sim::tasks::{libero_suite, simpler_suite, Task};

/// Floor/ceiling on error backoff, and the fixed backoff for transient
/// errors that carry no retry hint.
const BACKOFF_MIN_US: u64 = 50;
const BACKOFF_MAX_US: u64 = 20_000;
const ERROR_BACKOFF_US: u64 = 500;
/// Largest overload-drill burst (robots gathered before release).
const OVERLOAD_BURST_MAX: usize = 64;
/// Poll-loop idle sleep when no robot made progress.
const IDLE_SLEEP: Duration = Duration::from_micros(100);

#[derive(Clone, Debug)]
pub struct FleetConfig {
    pub robots: usize,
    /// Per-episode step cap (tasks with shorter horizons keep their own).
    pub horizon: usize,
    /// Variant assignment pool, round-robin over robots. The first
    /// NON-reference entry doubles as the hotspot drill's hot variant
    /// (so the drill never skews traffic onto the divergence anchor).
    pub variants: Vec<String>,
    pub seed: u64,
    /// Per-request deadline budget; `Some` arms deadline triage and (if
    /// the server's admission control is on) admission shedding.
    pub deadline: Option<Duration>,
    pub drills: Vec<Drill>,
    /// Resubmits of one decode before the robot aborts as dropped.
    pub max_retries: u32,
    /// Registry variant replayed locally as the closed-loop reference.
    pub reference: String,
    /// Robot control period (`fleet --control-hz`): each robot starts at
    /// most one decode per period, parking early arrivals in
    /// [`Phase::Paced`]. Retries of an already-started decode bypass the
    /// pace (the decode is late, not early). `None` = free-running.
    pub control_period: Option<Duration>,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            robots: 200,
            horizon: 64,
            variants: Vec::new(),
            seed: 1,
            deadline: None,
            drills: Vec::new(),
            max_retries: 64,
            reference: "dense".to_string(),
            control_period: None,
        }
    }
}

/// The serving surface the fleet drives. One robot loop, two backends:
/// the in-process [`PolicyServer`] (direct function calls) and the
/// multi-host [`LocalCluster`] (every request crosses the wire router).
/// The trait is exactly the submit/health/fault surface the driver
/// touches, so fleet semantics — typed errors, accounting invariants,
/// drill behavior — are backend-independent by construction.
pub trait FleetClient {
    fn submit_async(&self, req: ServeRequest) -> Result<ResponseHandle, ServeError>;
    fn live_workers(&self) -> usize;
    fn shrink_workers(&self, target: usize);
    /// Live host processes behind this client (1 for in-process serving).
    fn live_hosts(&self) -> usize {
        1
    }
    /// Kill one live host (the `host-loss` drill primitive), returning
    /// its address. `None` when there is no host to spare.
    fn kill_host(&self) -> Option<String> {
        None
    }
    /// Self-healing counters from the routing layer: `(redials,
    /// failovers)`. In-process serving has no router, so zeros.
    fn self_heal_counters(&self) -> (u64, u64) {
        (0, 0)
    }
}

impl FleetClient for PolicyServer {
    fn submit_async(&self, req: ServeRequest) -> Result<ResponseHandle, ServeError> {
        PolicyServer::submit_async(self, req)
    }

    fn live_workers(&self) -> usize {
        PolicyServer::live_workers(self)
    }

    fn shrink_workers(&self, target: usize) {
        PolicyServer::shrink_workers(self, target);
    }
}

impl FleetClient for LocalCluster {
    fn submit_async(&self, req: ServeRequest) -> Result<ResponseHandle, ServeError> {
        self.router.submit_async(req)
    }

    fn live_workers(&self) -> usize {
        self.router.live_workers()
    }

    fn shrink_workers(&self, target: usize) {
        // The worker-loss drill asks for a FLEET-wide target; spread it
        // evenly so every live host keeps at least one worker.
        let hosts = self.live_hosts().max(1);
        self.router.broadcast_shrink((target / hosts).max(1));
    }

    fn live_hosts(&self) -> usize {
        LocalCluster::live_hosts(self)
    }

    fn kill_host(&self) -> Option<String> {
        LocalCluster::kill_host(self)
    }

    fn self_heal_counters(&self) -> (u64, u64) {
        (self.router.redials_total(), self.router.failovers_total())
    }
}

/// Typed fleet-harness failures (configuration errors; serving errors
/// are per-robot counters, never a `run_fleet` failure).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FleetError {
    NoRobots,
    NoVariants,
    UnknownVariant(String),
    /// `--drill host-loss` against a client without a host to spare.
    DrillNeedsHosts,
}

impl std::fmt::Display for FleetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FleetError::NoRobots => write!(f, "fleet needs at least one robot"),
            FleetError::NoVariants => write!(f, "fleet needs at least one serving variant"),
            FleetError::UnknownVariant(v) => {
                write!(f, "variant '{v}' is not in the model registry")
            }
            FleetError::DrillNeedsHosts => {
                write!(f, "the host-loss drill needs a multi-host fleet (--hosts >= 2)")
            }
        }
    }
}

impl std::error::Error for FleetError {}

/// The heterogeneous episode mix: LIBERO object + spatial and the
/// SimplerEnv-like suite (pick/place, drawers, two-stage tasks).
pub fn fleet_task_pool() -> Vec<Task> {
    let mut tasks = libero_suite("object");
    tasks.extend(libero_suite("spatial"));
    tasks.extend(simpler_suite());
    tasks
}

/// Per-robot episode seed: decorrelated by the golden-ratio increment so
/// neighboring robots don't share scene jitter.
fn robot_seed(fleet_seed: u64, robot: usize) -> u64 {
    fleet_seed.wrapping_add((robot as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// Replay one episode closed-loop on a local model, recording executed
/// actions by step index — the divergence baseline.
fn reference_trajectory(
    model: &MiniVla,
    task: &Task,
    seed: u64,
    horizon: usize,
    obs_params: &ObsParams,
) -> (Vec<Vec<f32>>, bool) {
    let mut cursor = EpisodeCursor::new(task.clone(), seed, Some(horizon));
    let mut actions: Vec<Vec<f32>> = Vec::new();
    loop {
        match cursor.advance(|_, a| actions.push(a.to_vec())) {
            CursorState::Done => break,
            CursorState::NeedsDecode => {
                let obs = cursor.observation(model, obs_params);
                let feat = model.features(&obs.visual_raw, obs.instr_id, &obs.proprio, &mut None);
                let chunk = model.decode(&feat, cursor.decode_rng());
                cursor.push_chunk(chunk);
            }
        }
    }
    let success = cursor.outcome().map(|o| o.success).unwrap_or(false);
    (actions, success)
}

/// Deterministic per-robot backoff jitter: splitmix64-style hash of
/// (robot id, attempt number), bounded to half the base backoff.
///
/// Without this, every robot shed by the same overload burst computed
/// the SAME backoff and re-arrived as the same synchronized burst —
/// lockstep retry storms that re-triggered admission shedding for
/// rounds. The jitter depends only on (robot, attempt), never on wall
/// time or thread count, so fleet reports stay bit-identical across
/// `--workers` settings; only the retry *timing* decorrelates. The mix
/// itself lives in [`crate::util::rng::backoff_jitter_us`] — the
/// router's host re-dials share the exact same discipline.
fn backoff_jitter_us(robot_id: usize, attempt: u32, base_us: u64) -> u64 {
    crate::util::rng::backoff_jitter_us(robot_id as u64, attempt, base_us)
}

/// Retry bookkeeping shared by submit-side and response-side failures:
/// back off (clamped base + deterministic per-robot jitter) or abort
/// once the per-decode cap is spent.
fn retry_or_abort(robot: &mut Robot, now: Instant, backoff_us: u64, max_retries: u32) -> Phase {
    robot.retries_this_decode += 1;
    robot.serving_counters_mut().retries += 1;
    if robot.retries_this_decode > max_retries {
        robot.dropped = true;
        Phase::Done
    } else {
        let base = backoff_us.clamp(BACKOFF_MIN_US, BACKOFF_MAX_US);
        let jitter = backoff_jitter_us(robot.id, robot.retries_this_decode, base);
        Phase::BackOff { until: now + Duration::from_micros(base + jitter) }
    }
}

/// Submit the robot's pending decode. Every failure is a typed counter
/// plus either a backoff or an abort — nothing is retried blind, nothing
/// disappears.
fn submit_decode<C: FleetClient>(
    robot: &mut Robot,
    client: &C,
    cfg: &FleetConfig,
    now: Instant,
) -> Phase {
    let obs = robot.pending_obs().expect("observation cached before submit").clone();
    let mut req = ServeRequest::new(obs).with_variant(&robot.variant);
    if let Some(d) = cfg.deadline {
        req = req.with_deadline(d);
    }
    robot.begin_submit();
    match client.submit_async(req) {
        Ok(handle) => Phase::Waiting(handle),
        Err(ServeError::Overloaded { retry_after_us, .. }) => {
            robot.serving_counters_mut().admission_sheds += 1;
            // The server predicted how long past the deadline the queue
            // runs — backing off exactly that long is the intelligent
            // retry the satellite task asks for.
            retry_or_abort(robot, now, retry_after_us, cfg.max_retries)
        }
        Err(ServeError::Stopped) | Err(ServeError::WorkerDropped) => {
            robot.serving_counters_mut().errors += 1;
            retry_or_abort(robot, now, ERROR_BACKOFF_US, cfg.max_retries)
        }
        Err(_) => {
            // UnknownVariant / InvalidObservation / NoVariants: config
            // errors that no retry fixes — abort loudly via the counters.
            robot.serving_counters_mut().errors += 1;
            robot.dropped = true;
            Phase::Done
        }
    }
}

/// Drive the whole fleet to completion against an in-process server.
/// (Thin wrapper over [`run_fleet_on`]; multi-host fleets pass a
/// [`LocalCluster`] there instead.)
pub fn run_fleet(
    registry: &Arc<ModelRegistry>,
    server: &PolicyServer,
    cfg: &FleetConfig,
    obs_params: &ObsParams,
) -> Result<FleetReport, FleetError> {
    run_fleet_on(registry, server, cfg, obs_params)
}

/// Drive the whole fleet to completion against any [`FleetClient`].
pub fn run_fleet_on<C: FleetClient>(
    registry: &Arc<ModelRegistry>,
    client: &C,
    cfg: &FleetConfig,
    obs_params: &ObsParams,
) -> Result<FleetReport, FleetError> {
    if cfg.robots == 0 {
        return Err(FleetError::NoRobots);
    }
    if cfg.variants.is_empty() {
        return Err(FleetError::NoVariants);
    }
    if cfg.drills.contains(&Drill::HostLoss) && client.live_hosts() < 2 {
        return Err(FleetError::DrillNeedsHosts);
    }
    for v in &cfg.variants {
        if registry.get(v).is_none() {
            return Err(FleetError::UnknownVariant(v.clone()));
        }
    }
    let reference_model = registry
        .get(&cfg.reference)
        .ok_or_else(|| FleetError::UnknownVariant(cfg.reference.clone()))?;

    let t_start = Instant::now();

    // Build the fleet: round-robin variants over robots, tasks striped so
    // every variant sees (close to) the same task distribution.
    let tasks = fleet_task_pool();
    let mut robots: Vec<Robot> = Vec::with_capacity(cfg.robots);
    for i in 0..cfg.robots {
        let seed = robot_seed(cfg.seed, i);
        let variant = cfg.variants[i % cfg.variants.len()].clone();
        let task = tasks[(i / cfg.variants.len()) % tasks.len()].clone();
        let (ref_actions, ref_success) =
            reference_trajectory(&reference_model, &task, seed, cfg.horizon, obs_params);
        robots.push(Robot::new(i, variant, task, seed, cfg.horizon, ref_actions, ref_success));
    }

    // Progress-based drill triggers: responses delivered vs the
    // upper-bound expectation (every robot runs its full horizon).
    let chunk_len = reference_model.chunk_len().max(1);
    let expected_responses = (cfg.robots as u64) * (cfg.horizon as u64).div_ceil(chunk_len as u64);
    let mut scheduled = schedule(&cfg.drills);
    let mut drill_report = DrillReport::default();
    let mut gathering = false;

    // Per-robot control-period deadline (indexed by robot id): the
    // earliest instant the robot may START its next decode. All due at
    // t_start so the first decode is never delayed.
    let mut next_due: Vec<Instant> = vec![t_start; cfg.robots];

    let mut latency: HashMap<String, LatencyStats> = HashMap::new();
    let mut responses_total = 0u64;
    let mut done_count = 0usize;

    while done_count < robots.len() {
        let mut progress = false;
        let now = Instant::now();

        for robot in robots.iter_mut() {
            // Phase holds a ResponseHandle (not clonable), so the state
            // transition takes ownership and writes the successor back.
            let phase = std::mem::replace(&mut robot.phase, Phase::Done);
            robot.phase = match phase {
                Phase::Done => Phase::Done,
                Phase::Gathered => Phase::Gathered,
                Phase::Waiting(handle) => match handle.try_wait() {
                    None => Phase::Waiting(handle),
                    Some(Ok(rsp)) => {
                        progress = true;
                        responses_total += 1;
                        robot.serving_counters_mut().responses_ok += 1;
                        // Keyed by the variant that served the request
                        // (the submit-time target), so a mid-flight
                        // rehome never misattributes the sample.
                        latency
                            .entry(robot.serving_variant().to_string())
                            .or_default()
                            .record(rsp.latency());
                        robot.accept_chunk(rsp.actions);
                        Phase::Ready
                    }
                    Some(Err(e)) => {
                        progress = true;
                        match e {
                            ServeError::DeadlineExceeded { .. } => {
                                robot.serving_counters_mut().deadline_misses += 1;
                                retry_or_abort(robot, now, ERROR_BACKOFF_US, cfg.max_retries)
                            }
                            // The variant-kill drill deregistered this
                            // robot's variant mid-flight: no retry fixes
                            // it — drop loudly instead of burning the
                            // whole retry budget on typed failures.
                            ServeError::UnknownVariant(_) => {
                                robot.serving_counters_mut().errors += 1;
                                robot.dropped = true;
                                Phase::Done
                            }
                            // Overloaded only occurs at submit; anything
                            // else mid-flight is a transient worker-side
                            // failure.
                            _ => {
                                robot.serving_counters_mut().errors += 1;
                                retry_or_abort(robot, now, ERROR_BACKOFF_US, cfg.max_retries)
                            }
                        }
                    }
                },
                Phase::BackOff { until } => {
                    if now >= until {
                        progress = true;
                        if gathering {
                            Phase::Gathered
                        } else {
                            // Retries bypass the control pace: the decode
                            // already started its period when it first
                            // submitted, it is late, not early.
                            submit_decode(robot, client, cfg, now)
                        }
                    } else {
                        Phase::BackOff { until }
                    }
                }
                Phase::Paced { until } => {
                    if now >= until {
                        progress = true;
                        if gathering {
                            Phase::Gathered
                        } else {
                            if let Some(period) = cfg.control_period {
                                next_due[robot.id] = now + period;
                            }
                            submit_decode(robot, client, cfg, now)
                        }
                    } else {
                        Phase::Paced { until }
                    }
                }
                Phase::Ready => match robot.advance() {
                    CursorState::Done => {
                        progress = true;
                        Phase::Done
                    }
                    CursorState::NeedsDecode => {
                        progress = true;
                        robot.obs_for_decode(&reference_model, obs_params);
                        if gathering {
                            Phase::Gathered
                        } else {
                            match cfg.control_period {
                                Some(_) if now < next_due[robot.id] => {
                                    Phase::Paced { until: next_due[robot.id] }
                                }
                                Some(period) => {
                                    next_due[robot.id] = now + period;
                                    submit_decode(robot, client, cfg, now)
                                }
                                None => submit_decode(robot, client, cfg, now),
                            }
                        }
                    }
                },
            };
        }

        done_count = robots.iter().filter(|r| r.finished()).count();

        // Fire due drills.
        let done_frac = done_count as f64 / robots.len() as f64;
        let resp_frac = responses_total as f64 / expected_responses.max(1) as f64;
        let prog = done_frac.max(resp_frac);
        for s in &mut scheduled {
            if s.fired || prog < s.at_progress {
                continue;
            }
            s.fired = true;
            match s.drill {
                Drill::Overload => gathering = true,
                Drill::Hotspot => {
                    // The hot variant must not be the reference: the
                    // reference row is the fleet's zero-divergence
                    // anchor, and skewing extra traffic onto it would
                    // defeat the drill. Falls back to variants[0] only
                    // when the menu is reference-only (nothing to skew).
                    let hot = cfg
                        .variants
                        .iter()
                        .find(|v| **v != cfg.reference)
                        .unwrap_or(&cfg.variants[0])
                        .clone();
                    drill_report.hotspot_variant = Some(hot.clone());
                    // Every other still-live robot not already on the
                    // hot variant switches: half the off-hot fleet.
                    let mut switch = false;
                    for r in robots.iter_mut() {
                        if !r.finished() && r.variant != hot {
                            switch = !switch;
                            if switch {
                                r.rehome(hot.clone());
                                drill_report.hotspot_switched += 1;
                            }
                        }
                    }
                }
                Drill::WorkerLoss => {
                    let live = client.live_workers();
                    drill_report.workers_before_loss = live;
                    let target = (live / 2).max(1);
                    client.shrink_workers(target);
                    drill_report.workers_after_loss = target;
                }
                Drill::HostLoss => {
                    drill_report.hosts_before_loss = client.live_hosts();
                    drill_report.host_killed = client.kill_host();
                    drill_report.hosts_after_loss = client.live_hosts();
                }
                Drill::VariantKill => {
                    // Victim: the first non-reference variant — killing
                    // the divergence anchor would take the reference
                    // replay's variant out from under every row.
                    drill_report.variants_before_kill = registry.len();
                    let victim = cfg.variants.iter().find(|v| **v != cfg.reference).cloned();
                    if let Some(victim) = victim {
                        if registry.remove(&victim).is_ok() {
                            drill_report.variant_killed = Some(victim);
                        }
                    }
                    drill_report.variants_after_kill = registry.len();
                }
            }
        }

        // Release a gathered overload burst once enough robots parked
        // (or every still-active robot is in the pen).
        if gathering {
            let active = robots.len() - done_count;
            let parked: Vec<usize> = robots
                .iter()
                .enumerate()
                .filter(|(_, r)| matches!(r.phase, Phase::Gathered))
                .map(|(i, _)| i)
                .collect();
            let target = active.min(OVERLOAD_BURST_MAX).max(1);
            if !parked.is_empty() && parked.len() >= target {
                let release_now = Instant::now();
                for &idx in &parked {
                    let robot = &mut robots[idx];
                    // A burst release is itself a control tick: the next
                    // decode paces off it rather than submitting twice in
                    // one period.
                    if let Some(period) = cfg.control_period {
                        next_due[robot.id] = release_now + period;
                    }
                    robot.phase = submit_decode(robot, client, cfg, release_now);
                }
                drill_report.overload_bursts += 1;
                drill_report.max_burst_size = drill_report.max_burst_size.max(parked.len() as u64);
                gathering = false;
                progress = true;
                done_count = robots.iter().filter(|r| r.finished()).count();
            }
        }

        if !progress && done_count < robots.len() {
            std::thread::sleep(IDLE_SLEEP);
        }
    }

    // Aggregate: robot-level outcomes (membership, success, digest,
    // drops) group by FINAL assignment; traffic stats (counters,
    // divergence, latency) are attributed to the variant that actually
    // SERVED them. A robot the hotspot drill rehomed leaves its
    // pre-switch history on its old variant, so the reference row stays
    // the zero-divergence anchor no matter which drills ran.
    let mut row_order: Vec<String> = cfg.variants.clone();
    for r in &robots {
        if !row_order.contains(&r.variant) {
            row_order.push(r.variant.clone());
        }
        for (v, _) in r.served() {
            if !row_order.contains(v) {
                row_order.push(v.clone());
            }
        }
    }
    let rows: Vec<FleetVariantRow> = row_order
        .iter()
        .map(|name| {
            let members: Vec<&Robot> = robots.iter().filter(|r| &r.variant == name).collect();
            let mut traffic = RobotCounters::default();
            let mut divergence = DivergenceTracker::new(cfg.horizon);
            for r in &robots {
                if let Some(s) = r.served_stats(name) {
                    traffic.add(&s.counters);
                    divergence.merge(&s.divergence);
                }
            }
            FleetVariantRow::aggregate(name, &members, traffic, divergence, latency.get(name))
        })
        .collect();

    let (router_redials, router_failovers) = client.self_heal_counters();
    Ok(FleetReport {
        robots: cfg.robots,
        horizon: cfg.horizon,
        seed: cfg.seed,
        reference: cfg.reference.clone(),
        drills: cfg.drills.clone(),
        live_workers_at_end: client.live_workers(),
        total_responses: responses_total,
        wall_secs: t_start.elapsed().as_secs_f64(),
        router_redials,
        router_failovers,
        rows,
        drill_report,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_pool_is_heterogeneous() {
        let tasks = fleet_task_pool();
        assert!(tasks.len() >= 10);
        let suites: std::collections::HashSet<&str> =
            tasks.iter().map(|t| t.suite.as_str()).collect();
        assert!(suites.len() >= 3, "{suites:?}");
    }

    #[test]
    fn robot_seeds_decorrelate() {
        let a = robot_seed(1, 0);
        let b = robot_seed(1, 1);
        assert_ne!(a, b);
        assert_eq!(robot_seed(1, 7), robot_seed(1, 7));
    }

    #[test]
    fn backoff_jitter_is_deterministic_and_bounded() {
        for base in [BACKOFF_MIN_US, 500, BACKOFF_MAX_US] {
            for robot in 0..32usize {
                for attempt in 1..8u32 {
                    let j = backoff_jitter_us(robot, attempt, base);
                    assert_eq!(j, backoff_jitter_us(robot, attempt, base));
                    assert!(j <= base / 2, "jitter {j} exceeds half of base {base}");
                }
            }
        }
    }

    #[test]
    fn backoff_jitter_decorrelates_robots_and_attempts() {
        // The lockstep-storm fix: robots shed by the same burst must not
        // share a backoff. Distinct-value counts over a burst of 64.
        let burst: std::collections::HashSet<u64> =
            (0..64usize).map(|r| backoff_jitter_us(r, 1, BACKOFF_MAX_US)).collect();
        assert!(burst.len() >= 48, "only {} distinct jitters across 64 robots", burst.len());
        // And one robot's successive attempts spread too.
        let attempts: std::collections::HashSet<u64> =
            (1..9u32).map(|a| backoff_jitter_us(7, a, BACKOFF_MAX_US)).collect();
        assert!(attempts.len() >= 6, "attempts collapsed: {attempts:?}");
    }

    #[test]
    fn fleet_errors_render() {
        assert!(FleetError::NoRobots.to_string().contains("robot"));
        assert!(FleetError::UnknownVariant("x".into()).to_string().contains("'x'"));
        assert!(FleetError::DrillNeedsHosts.to_string().contains("--hosts"));
    }
}
