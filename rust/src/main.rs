//! `hbvla` — the command-line launcher for the HBVLA reproduction.
//!
//! Subcommands regenerate every table/figure of the paper (DESIGN.md §6):
//!
//! ```text
//! hbvla table1|table2|table3|table4|fig1|fig3|fig4   # one experiment
//! hbvla all                                          # everything
//! hbvla quantize --method hbvla                      # PTQ report
//! hbvla perf                                         # §Perf measurements
//! hbvla serve                                        # serving-router demo
//! hbvla serve --listen ADDR                          # one wire host (TCP)
//! hbvla route --hosts N                              # router over N host processes
//! hbvla fleet                                        # fleet replay harness
//! ```
//!
//! Budget flags: `--episodes N` (per task, default 50), `--demos N`
//! (default 256), `--seed S`, `--threads T`, `--md` (markdown tables),
//! `--smoke` (tiny budget for CI).
//!
//! `serve` flags: `--variant <name>` (dense | rtn-packed | hbvla-packed |
//! hbvla-exact | rtn-packed-a8 | hbvla-packed-a8), `--act-precision
//! f32|int8` (maps a packed variant to its W1A8 twin), `--act-scale
//! per-token|static` (static = calibrate per-layer W1A8 scales once and
//! skip the per-token max sweep on the hot path), `--act-clip max|p999`
//! (how the static calibration clips the observed range), `--attn-precision
//! f32|int8` (attention-core override; W1A8 twins default to INT8
//! attention), `--workers N`, `--shards N` (variant-affine dispatch
//! shards; 0 = one per worker),
//! `--max-batch N`, `--max-wait-us U`, `--requests N` — the demo registers
//! the dense checkpoint, both packed commits, the transform-domain exact
//! HBVLA commit (`hbvla-exact`: serves the committed Haar-domain bitplanes
//! with zero residual planes), and the INT8-activation twins
//! (quantize → register → serve) and routes every request to the chosen
//! one.
//!
//! `fleet` drives N simulated robots closed-loop against the policy
//! server (`--robots N`, `--horizon N`, `--variants a,b,c`, `--reference
//! NAME`, `--deadline-us U`, `--drill none|overload|hotspot|worker-loss|
//! host-loss|variant-kill|all` — `all` expands to every drill valid for
//! the deployment shape), tracking per-variant success retention,
//! divergence-vs-horizon and shed/miss/latency stats; `--json PATH`
//! merges the `fleet` section into the hbvla-bench-v1 report at PATH.
//! `--hosts N` routes all fleet traffic across N loopback wire hosts
//! behind the placement-hashed router (arming the `host-loss` drill);
//! `--replicas R` places each variant on R probe-order hosts with
//! transparent per-request failover; `--control-hz F` paces each robot
//! to F decode starts per second.
//!
//! `route` is the same front door over TRUE process isolation: it spawns
//! `--hosts N` children of this binary in `serve --listen` mode, connects
//! a router to all of them, and drives `--requests N` across hosts.

use hbvla::eval::tables::EvalBudget;
use hbvla::report::Table;
use hbvla::util::cli::Args;

fn budget_from(args: &Args) -> EvalBudget {
    let mut b = if args.flag("smoke") { EvalBudget::smoke() } else { EvalBudget::default() };
    b.episodes_per_task = args.usize_or("episodes", b.episodes_per_task);
    b.n_demos = args.usize_or("demos", b.n_demos);
    b.seed = args.u64_or("seed", b.seed);
    b.threads = args.usize_or("threads", b.threads);
    b
}

fn emit(tables: &[Table], md: bool) {
    for t in tables {
        if md {
            println!("{}", t.render_markdown());
        } else {
            println!("{}", t.render());
        }
    }
}

/// Register the standard serving-variant family on a registry: the dense
/// checkpoint, the rtn/hbvla packed commits with their W1A8 twins, and
/// the transform-exact HBVLA commit (`hbvla-exact`). Shared by `serve`
/// and `fleet` so both subcommands expose the same variant menu.
fn register_standard_variants(
    registry: &std::sync::Arc<hbvla::coordinator::ModelRegistry>,
    tb: &hbvla::eval::Testbed,
    threads: usize,
) {
    use std::sync::Arc;
    registry.register("dense", Arc::new(tb.model.clone())).expect("register dense");
    for (variant, method_name) in [("rtn-packed", "rtn"), ("hbvla-packed", "hbvla")] {
        let method = hbvla::methods::by_name(method_name).unwrap();
        let rep = hbvla::coordinator::quantize_into_registry(
            registry,
            variant,
            &tb.model,
            &tb.calib,
            method.as_ref(),
            &hbvla::eval::paper_components(),
            threads,
        )
        .expect("register variant");
        println!(
            "registered {variant:<13} {} packed layers, ×{:.1} smaller, \
             deploy rel err {:.4}",
            rep.packed_layers,
            rep.realized_compression(),
            rep.mean_deploy_rel_err
        );
        // W1A8 twin: same packed weights, Int8 activations.
        let a8 =
            hbvla::coordinator::register_a8_variant(registry, variant).expect("register a8 twin");
        println!("registered {a8:<16} (W1A8: int8 activations on the same packed weights)");
    }
    // Transform-domain exact twin: serve the committed Haar-domain
    // bitplanes directly (y = C·haar(Pᵀx)), zero residual planes.
    let method = hbvla::methods::by_name("hbvla").unwrap();
    let rep = hbvla::coordinator::quantize_exact_into_registry(
        registry,
        "hbvla-exact",
        &tb.model,
        &tb.calib,
        method.as_ref(),
        &hbvla::eval::paper_components(),
        threads,
    )
    .expect("register exact variant");
    println!(
        "registered {:<13} {} transform-exact layers, ×{:.1} smaller, \
         deploy rel err {:.4} (zero residual planes)",
        "hbvla-exact",
        rep.transform_layers,
        rep.realized_compression(),
        rep.mean_deploy_rel_err
    );
}

fn main() {
    let args = Args::from_env();
    let md = args.flag("md");
    let budget = budget_from(&args);
    match args.subcommand() {
        Some("table1") => emit(&hbvla::eval::tables::table1_simpler(&budget), md),
        Some("table2") => emit(&hbvla::eval::tables::table2_libero(&budget), md),
        Some("table3") => emit(&[hbvla::eval::ablation::table3_permutation(&budget)], md),
        Some("table4") => emit(&[hbvla::eval::ablation::table4_hessian(&budget)], md),
        Some("fig1") => {
            let s = hbvla::eval::figures::fig1_dual_dominance(&budget);
            println!("## Figure 1 — dual dominance statistics");
            println!("max |activation|      : {:.1} (paper highlights Val=106.5)", s.max_abs);
            println!("excess kurtosis       : {:.1}", s.kurtosis);
            println!("visual:instr tokens   : {:.0}:1", s.visual_token_ratio);
        }
        Some("fig3") => emit(&[hbvla::eval::figures::fig3_aloha(&budget)], md),
        Some("fig4") => emit(&[hbvla::eval::figures::fig4_sensitivity(&budget)], md),
        Some("quantize") => {
            let method_name = args.get_or("method", "hbvla");
            let method = hbvla::methods::by_name(method_name)
                .unwrap_or_else(|| panic!("unknown method {method_name}"));
            let tb = hbvla::eval::build_testbed(
                hbvla::model::HeadKind::Chunk,
                hbvla::sim::tasks::libero_suite("object"),
                budget.n_demos.min(64),
                budget.seed,
            );
            let (qm, rep) = hbvla::coordinator::scheduler::quantize_model(
                &tb.model,
                &tb.calib,
                method.as_ref(),
                &hbvla::eval::paper_components(),
                budget.threads,
            );
            println!("method            : {}", rep.method);
            println!("layers quantized  : {}", rep.layers.len());
            println!("mean rel frob err : {:.4}", rep.mean_rel_err);
            println!("deploy rel err    : {:.4}", rep.mean_deploy_rel_err);
            println!("bits per weight   : {:.3}", rep.bits_per_weight());
            println!("packed layers     : {}", rep.packed_layers);
            println!("realized memory   : ×{:.1} smaller", rep.realized_compression());
            println!("wall time         : {:.3}s", rep.wall_secs);
            for (name, err) in &rep.layers {
                println!("  {name:<14} rel_err={err:.4}");
            }
            println!("{}", hbvla::report::MemoryReport::from_store(&qm.store).render());
        }
        Some("perf") => {
            let rep =
                hbvla::eval::perf::run_perf_opts(budget.threads, budget.seed, args.flag("smoke"));
            println!("## §Perf\n{}", rep.render());
            // `--json PATH` additionally emits the machine-readable
            // baseline (schema hbvla-bench-v1) — the BENCH_*.json perf
            // trajectory CI validates and archives per PR.
            if let Some(path) = args.get("json") {
                std::fs::write(path, rep.to_json())
                    .unwrap_or_else(|e| panic!("write bench json {path}: {e}"));
                println!("wrote machine-readable bench baseline to {path}");
            }
        }
        Some("serve") => {
            use hbvla::coordinator::{ModelRegistry, PolicyServer, ServeConfig, ServeRequest};
            use std::sync::Arc;
            let tb = hbvla::eval::build_testbed(
                hbvla::model::HeadKind::Chunk,
                hbvla::sim::tasks::libero_suite("object"),
                budget.n_demos.min(64),
                budget.seed,
            );
            // quantize → register → serve: one registry holds the dense
            // checkpoint plus each PTQ commit; requests choose per-variant
            // (`--variant`, default hbvla-packed — the packed 1-bit path).
            let registry = Arc::new(ModelRegistry::new());
            register_standard_variants(&registry, &tb, budget.threads);
            let cfg = ServeConfig {
                workers: args.usize_or("workers", 2),
                // 0 = auto (one variant-affine dispatch shard per worker).
                shards: args.usize_or("shards", 0),
                max_batch: args.usize_or("max-batch", 8),
                max_wait: std::time::Duration::from_micros(args.u64_or("max-wait-us", 500)),
                ..Default::default()
            };
            // `--variant` picks the served variant; the pre-registry
            // `--method` spelling still works — preregistered methods map
            // to their variant, any other known method quantizes and
            // registers on demand.
            let variant = match (args.get("variant"), args.get("method")) {
                (Some(v), _) => v.to_string(),
                (None, Some(m)) => match m.to_ascii_lowercase().as_str() {
                    "rtn" | "rtn-1b" => "rtn-packed".to_string(),
                    "hbvla" => "hbvla-packed".to_string(),
                    "fp" | "full" | "fullprecision" => "dense".to_string(),
                    other => {
                        let method = hbvla::methods::by_name(other)
                            .unwrap_or_else(|| panic!("unknown method {other}"));
                        let name = format!("{other}-packed");
                        let rep = hbvla::coordinator::quantize_into_registry(
                            &registry,
                            &name,
                            &tb.model,
                            &tb.calib,
                            method.as_ref(),
                            &hbvla::eval::paper_components(),
                            budget.threads,
                        )
                        .expect("register variant");
                        println!(
                            "registered {name:<13} {} packed layers, ×{:.1} smaller",
                            rep.packed_layers,
                            rep.realized_compression()
                        );
                        name
                    }
                },
                (None, None) => "hbvla-packed".to_string(),
            };
            // `--act-precision int8` routes to the chosen variant's W1A8
            // twin (registering it on demand for method-registered
            // variants); `f32` (the default) leaves the choice as-is.
            let variant = match args.get("act-precision") {
                None => variant,
                Some(spec) => match hbvla::model::ActPrecision::parse(spec) {
                    Some(hbvla::model::ActPrecision::Int8) if !variant.ends_with("-a8") => {
                        // Register the twin on demand for method-registered
                        // variants that don't have one yet.
                        if registry.get(&format!("{variant}-a8")).is_none()
                            && registry.get(&variant).is_some()
                        {
                            hbvla::coordinator::register_a8_variant(&registry, &variant)
                                .expect("register a8 twin");
                        }
                        // Int8 only changes packed-layer execution: say so
                        // when the twin would execute identically to f32.
                        if let Some(m) = registry.get(&variant) {
                            if m.store.packed_layer_count() == 0 {
                                eprintln!(
                                    "note: variant '{variant}' has no packed layers — \
                                     '{variant}-a8' executes identical f32 kernels"
                                );
                            }
                        }
                        format!("{variant}-a8")
                    }
                    // `f32` on an `-a8` twin means the base variant: the
                    // flag always wins over the variant spelling.
                    Some(hbvla::model::ActPrecision::F32) if variant.ends_with("-a8") => {
                        variant.strip_suffix("-a8").unwrap().to_string()
                    }
                    Some(_) => variant,
                    None => {
                        eprintln!("--act-precision expects f32 or int8, got '{spec}'");
                        std::process::exit(2);
                    }
                },
            };
            if registry.get(&variant).is_none() {
                eprintln!(
                    "unknown variant '{variant}'; registered variants: {}",
                    registry.names().join(", ")
                );
                std::process::exit(2);
            }
            // `--act-clip` is a static-calibration policy: reject it
            // where it would be silently ignored.
            if args.get("act-clip").is_some()
                && args.get("act-scale").and_then(hbvla::model::ActScaleMode::parse)
                    != Some(hbvla::model::ActScaleMode::Static)
            {
                eprintln!("--act-clip only applies with --act-scale static");
                std::process::exit(2);
            }
            // `--act-scale static` registers the calibrated-static-scale
            // twin of the chosen variant (a one-sweep calibration over a
            // small demo stream pins per-layer W1A8 scales; the hot path
            // then skips the per-token max sweeps) and serves it.
            // `per-token` (the default) leaves the choice as-is.
            let variant = match args.get("act-scale") {
                None => variant,
                Some(spec) => match hbvla::model::ActScaleMode::parse(spec) {
                    Some(hbvla::model::ActScaleMode::PerToken) => variant,
                    Some(hbvla::model::ActScaleMode::Static) => {
                        // Static scales only exist for INT8 activations:
                        // the twin registration forces Int8, so an
                        // explicit f32 request cannot be honored — fail
                        // loudly instead of silently serving W1A8.
                        if args.get("act-precision").and_then(hbvla::model::ActPrecision::parse)
                            == Some(hbvla::model::ActPrecision::F32)
                        {
                            eprintln!(
                                "--act-scale static implies int8 activations and cannot be \
                                 combined with --act-precision f32"
                            );
                            std::process::exit(2);
                        }
                        // `--act-clip max|p999` picks how the calibrated
                        // scale clips the observed range (max covers
                        // everything; p999 clips the 0.1% outlier tail
                        // and saturates it at serve time).
                        let clip = match args.get("act-clip") {
                            None => hbvla::calib::ScaleClip::Max,
                            Some(spec) => hbvla::calib::ScaleClip::parse(spec).unwrap_or_else(|| {
                                eprintln!("--act-clip expects max or p999, got '{spec}'");
                                std::process::exit(2);
                            }),
                        };
                        // Same calibration recipe the perf baseline's
                        // act-scale rows measure (calib::scales keeps
                        // them from drifting apart).
                        let (eps, steps) =
                            hbvla::calib::scales::calib_recipe(args.flag("smoke"));
                        let demos = hbvla::calib::collect_demos(
                            &tb.model,
                            &tb.tasks,
                            eps,
                            budget.seed ^ hbvla::calib::scales::CALIB_SEED_STREAM,
                        );
                        let (name, layers) =
                            hbvla::coordinator::scheduler::register_static_scale_variant_clip(
                                &registry,
                                &variant,
                                &demos,
                                steps,
                                clip,
                            )
                            .expect("register static-scale twin");
                        println!(
                            "registered {name:<20} ({layers} layers with calibrated static \
                             activation scales [clip={}], W1A8, max sweep skipped on the hot \
                             path)",
                            clip.label()
                        );
                        // Mirror the --act-precision no-op note: a
                        // variant with nothing to calibrate (e.g. dense)
                        // serves unchanged kernels under the twin name.
                        if layers == 0 {
                            eprintln!(
                                "note: variant '{variant}' has no packed layers to \
                                 calibrate — '{name}' executes the same kernels"
                            );
                        }
                        name
                    }
                    None => {
                        eprintln!("--act-scale expects per-token or static, got '{spec}'");
                        std::process::exit(2);
                    }
                },
            };
            // `--attn-precision f32|int8` overrides the attention-core
            // precision of the chosen variant (W1A8 twins inherit INT8
            // attention by default; `f32` pins the f32 scores/context
            // back for A/B runs). The override re-registers the variant
            // under the SAME name — attention precision is a runtime
            // policy, not an interface property, so the serving name
            // stays stable.
            if let Some(spec) = args.get("attn-precision") {
                match hbvla::model::AttnPrecision::parse(spec) {
                    Some(p) => {
                        let m = registry.get(&variant).expect("variant vanished");
                        if m.store.attn_precision() != p {
                            let pinned = (*m).clone().with_attn_precision(p);
                            registry
                                .register(&variant, Arc::new(pinned))
                                .expect("re-register attn override");
                        }
                        println!("attention core pinned to {} on '{variant}'", p.label());
                    }
                    None => {
                        eprintln!("--attn-precision expects f32 or int8, got '{spec}'");
                        std::process::exit(2);
                    }
                }
            }
            // An explicit --threads pins the kernel fan-out budget on
            // every registered variant (matching `perf`); without the
            // flag, serving uses the machine default. The per-variant
            // clone is startup-only and sequential (one store at a
            // time), which is acceptable at demo scale; pinning at
            // registration would avoid it if variant counts grow.
            if args.get("threads").is_some() {
                for name in registry.names() {
                    if let Some(m) = registry.get(&name) {
                        let mut pinned = (*m).clone();
                        pinned.store.set_exec_threads(budget.threads);
                        registry.register(&name, Arc::new(pinned)).expect("re-register pinned");
                    }
                }
                println!(
                    "pinned kernel thread budget to {} on all registered variants",
                    budget.threads
                );
            }
            // `--listen ADDR` turns `serve` into a wire host: expose this
            // process's `PolicyServer` on a TCP socket speaking the
            // length-prefixed frame protocol and block until stdin closes
            // (the `route` front door spawns these as children, parses
            // the printed handshake line, and owns their lifetime).
            if let Some(listen) = args.get("listen") {
                let host =
                    hbvla::coordinator::WireHost::spawn(Arc::clone(&registry), cfg.clone(), listen)
                        .unwrap_or_else(|e| panic!("bind {listen}: {e}"));
                println!("hbvla-host listening on {}", host.addr());
                // Second line on purpose: `route` prefix-parses the
                // handshake line above, so identity goes after it.
                println!(
                    "hbvla-host identity {:#018x}, protocol v{}",
                    host.host_id(),
                    hbvla::coordinator::PROTOCOL_VERSION
                );
                let mut line = String::new();
                loop {
                    line.clear();
                    match std::io::BufRead::read_line(&mut std::io::stdin().lock(), &mut line) {
                        Ok(0) | Err(_) => break,
                        Ok(_) => {}
                    }
                }
                host.shutdown();
                return;
            }
            let server = PolicyServer::start(Arc::clone(&registry), cfg.clone());
            println!(
                "serving variant '{variant}' with {} workers, {} shards, max batch {}, max wait {:?}",
                cfg.workers,
                server.n_shards(),
                cfg.max_batch,
                cfg.max_wait
            );
            let mut rng = hbvla::util::rng::Rng::new(budget.seed);
            let task = &tb.tasks[0];
            let scene = task.instantiate(&mut rng);
            let obs = hbvla::sim::observe::observe(
                &scene,
                task.stages[0].instr(),
                100,
                &tb.model,
                &hbvla::sim::observe::ObsParams::clean(),
                &mut rng,
            );
            let n = args.usize_or("requests", if args.flag("smoke") { 64 } else { 1000 });
            // Async waves let the router coalesce real compute batches.
            let wave = 16usize;
            let t0 = std::time::Instant::now();
            let mut served = 0usize;
            while served < n {
                let k = wave.min(n - served);
                let handles: Vec<_> = (0..k)
                    .map(|_| {
                        server
                            .submit_async(ServeRequest::new(obs.clone()).with_variant(&variant))
                            .expect("submit")
                    })
                    .collect();
                for h in handles {
                    let rsp = h.wait().expect("serve request failed");
                    assert_eq!(rsp.variant_served, variant);
                }
                served += k;
            }
            let el = t0.elapsed().as_secs_f64();
            println!("served {n} requests in {el:.3}s ({:.0} req/s)", n as f64 / el);
            for (name, stats) in server.variant_stats() {
                println!("  {name:<13} {}", stats.summary());
            }
            println!("mean batch size: {:.2}", server.mean_batch_size());
            server.shutdown();
        }
        Some("route") => {
            // The multi-host front door over TRUE process isolation: N
            // `serve --listen` children of this same binary, one Router
            // connected to all of them, traffic spanning hosts. (The
            // loopback in-process equivalent is `fleet --hosts N`.)
            use hbvla::coordinator::metrics::LatencyStats;
            use hbvla::coordinator::{AdmissionControl, Router, RouterConfig, ServeRequest};
            use std::io::BufRead;
            let smoke = args.flag("smoke");
            let n_hosts = args.usize_or("hosts", 2).max(1);
            let exe = std::env::current_exe().expect("current_exe");
            let mut children = Vec::new();
            for i in 0..n_hosts {
                let mut cmd = std::process::Command::new(&exe);
                cmd.arg("serve")
                    .arg("--listen")
                    .arg("127.0.0.1:0")
                    .arg("--workers")
                    .arg(args.usize_or("workers", 2).to_string())
                    .arg("--shards")
                    .arg(args.usize_or("shards", 0).to_string())
                    .arg("--max-batch")
                    .arg(args.usize_or("max-batch", 8).to_string())
                    .arg("--max-wait-us")
                    .arg(args.u64_or("max-wait-us", 200).to_string())
                    .arg("--seed")
                    .arg(budget.seed.to_string())
                    .arg("--demos")
                    .arg(budget.n_demos.to_string())
                    .arg("--threads")
                    .arg(budget.threads.to_string());
                if smoke {
                    cmd.arg("--smoke");
                }
                cmd.stdin(std::process::Stdio::piped())
                    .stdout(std::process::Stdio::piped())
                    .stderr(std::process::Stdio::inherit());
                children.push(cmd.spawn().unwrap_or_else(|e| panic!("spawn host {i}: {e}")));
            }
            // Each child prints registration progress, then the parseable
            // `hbvla-host listening on ADDR` handshake. Keep draining
            // stdout afterwards so no child ever blocks on a full pipe.
            let mut addrs = Vec::new();
            let mut drains = Vec::new();
            for (i, child) in children.iter_mut().enumerate() {
                let stdout = child.stdout.take().expect("child stdout");
                let mut reader = std::io::BufReader::new(stdout);
                let mut line = String::new();
                let addr = loop {
                    line.clear();
                    match reader.read_line(&mut line) {
                        Ok(0) => panic!("host {i} exited before its listen handshake"),
                        Err(e) => panic!("host {i} stdout: {e}"),
                        Ok(_) => {}
                    }
                    if let Some(rest) = line.trim().strip_prefix("hbvla-host listening on ") {
                        break rest.to_string();
                    }
                };
                println!("host {i}: {addr}");
                addrs.push(addr);
                drains.push(std::thread::spawn(move || {
                    let mut sink = String::new();
                    while matches!(reader.read_line(&mut sink), Ok(n) if n > 0) {
                        sink.clear();
                    }
                }));
            }
            let deadline_us = args.u64_or("deadline-us", 0);
            let router_cfg = RouterConfig {
                admission: if deadline_us > 0 {
                    AdmissionControl::DeadlineAware { min_samples: 16 }
                } else {
                    AdmissionControl::Off
                },
                replicas: args.usize_or("replicas", 1).max(1),
            };
            let router = Router::connect(&addrs, router_cfg)
                .unwrap_or_else(|e| panic!("router connect: {e}"));
            // Local testbed only supplies observations + the variant
            // menu; every decode happens host-side across the wire.
            let tb = hbvla::eval::build_testbed(
                hbvla::model::HeadKind::Chunk,
                hbvla::sim::tasks::libero_suite("object"),
                budget.n_demos.min(64),
                budget.seed,
            );
            let variants = args.list_or("variants", "dense,hbvla-packed,hbvla-packed-a8");
            let mut rng = hbvla::util::rng::Rng::new(budget.seed);
            let task = &tb.tasks[0];
            let scene = task.instantiate(&mut rng);
            let obs = hbvla::sim::observe::observe(
                &scene,
                task.stages[0].instr(),
                100,
                &tb.model,
                &hbvla::sim::observe::ObsParams::clean(),
                &mut rng,
            );
            let n = args.usize_or("requests", if smoke { 96 } else { 512 });
            let wave = 16usize;
            let mut lat = LatencyStats::default();
            let (mut ok, mut sheds, mut errors, mut submitted) = (0u64, 0u64, 0u64, 0usize);
            let t0 = std::time::Instant::now();
            while submitted < n {
                let k = wave.min(n - submitted);
                let mut handles = Vec::with_capacity(k);
                for _ in 0..k {
                    let mut req = ServeRequest::new(obs.clone())
                        .with_variant(&variants[submitted % variants.len()]);
                    if deadline_us > 0 {
                        req = req.with_deadline(std::time::Duration::from_micros(deadline_us));
                    }
                    submitted += 1;
                    match router.submit_async(req) {
                        Ok(h) => handles.push(h),
                        Err(hbvla::coordinator::ServeError::Overloaded { .. }) => sheds += 1,
                        Err(_) => errors += 1,
                    }
                }
                for h in handles {
                    match h.wait() {
                        Ok(rsp) => {
                            ok += 1;
                            lat.record(rsp.latency());
                        }
                        Err(_) => errors += 1,
                    }
                }
            }
            let el = t0.elapsed().as_secs_f64();
            let pcts = lat.percentiles_us(&[0.50, 0.99]);
            println!(
                "routed {ok}/{n} requests over {} hosts in {el:.3}s ({:.0} req/s), \
                 shed {sheds}, errors {errors}, p50 {}us, p99 {}us, \
                 rejoins {}, failovers {}",
                router.live_hosts(),
                ok as f64 / el.max(1e-9),
                pcts[0],
                pcts[1],
                router.redials_total(),
                router.failovers_total()
            );
            for hc in router.host_counters() {
                let mark = |m: Option<u64>| {
                    m.map(|s| format!("seq {s}")).unwrap_or_else(|| "never".to_string())
                };
                println!(
                    "  host {}: {}, dials {}, redials {}, failovers {}, \
                     last death {}, last rejoin {}",
                    hc.addr,
                    if hc.alive { "live" } else { "dead" },
                    hc.dial_attempts,
                    hc.redials,
                    hc.failovers,
                    mark(hc.last_death_seq),
                    mark(hc.last_rejoin_seq)
                );
            }
            router.shutdown();
            for mut child in children {
                // Closing the piped stdin is the children's shutdown
                // signal; kill is the backstop if one ignores it.
                drop(child.stdin.take());
                let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
                loop {
                    match child.try_wait() {
                        Ok(Some(_)) => break,
                        Ok(None) if std::time::Instant::now() < deadline => {
                            std::thread::sleep(std::time::Duration::from_millis(20));
                        }
                        _ => {
                            let _ = child.kill();
                            let _ = child.wait();
                            break;
                        }
                    }
                }
            }
            for d in drains {
                let _ = d.join();
            }
        }
        Some("fleet") => {
            use hbvla::coordinator::router::LocalCluster;
            use hbvla::coordinator::{
                AdmissionControl, ModelRegistry, PolicyServer, RouterConfig, ServeConfig,
            };
            use hbvla::fleet::{merge_fleet_json, parse_drills, run_fleet_on, FleetConfig};
            use std::sync::Arc;
            let smoke = args.flag("smoke");
            let tb = hbvla::eval::build_testbed(
                hbvla::model::HeadKind::Chunk,
                hbvla::sim::tasks::libero_suite("object"),
                budget.n_demos.min(64),
                budget.seed,
            );
            let registry = Arc::new(ModelRegistry::new());
            register_standard_variants(&registry, &tb, budget.threads);
            // Drill validity depends on the deployment shape (`host-loss`
            // needs hosts), so the host count is parsed first and `all`
            // expands against it — rejections are typed, never silent.
            let n_hosts = args.usize_or("hosts", 1);
            let drills = parse_drills(args.get_or("drill", "none"), n_hosts.max(1))
                .unwrap_or_else(|e| {
                    eprintln!(
                        "--drill: {e} (expects none|overload|hotspot|worker-loss|host-loss|\
                         variant-kill|all or a comma list)"
                    );
                    std::process::exit(2);
                });
            let deadline_us = args.u64_or("deadline-us", 0);
            // `--control-hz F` paces each robot to at most F decode
            // starts per second; 0 (the default) is free-running.
            let control_hz = args.f64_or("control-hz", 0.0);
            if control_hz < 0.0 || !control_hz.is_finite() {
                eprintln!("--control-hz expects a finite rate >= 0, got {control_hz}");
                std::process::exit(2);
            }
            let fleet_cfg = FleetConfig {
                robots: args.usize_or("robots", if smoke { 16 } else { 200 }),
                horizon: args.usize_or("horizon", if smoke { 12 } else { 64 }),
                variants: args.list_or("variants", "dense,hbvla-packed,hbvla-packed-a8"),
                seed: budget.seed,
                deadline: if deadline_us > 0 {
                    Some(std::time::Duration::from_micros(deadline_us))
                } else {
                    None
                },
                drills,
                reference: args.get_or("reference", "dense").to_string(),
                control_period: if control_hz > 0.0 {
                    Some(std::time::Duration::from_secs_f64(1.0 / control_hz))
                } else {
                    None
                },
                ..Default::default()
            };
            let serve_cfg = ServeConfig {
                workers: args.usize_or("workers", 4),
                shards: args.usize_or("shards", 0),
                max_batch: args.usize_or("max-batch", 8),
                max_wait: std::time::Duration::from_micros(args.u64_or("max-wait-us", 200)),
                // Deadline budgets arm admission control: the fleet then
                // exercises the shed + retry_after_us path for real.
                admission: if deadline_us > 0 {
                    AdmissionControl::DeadlineAware { min_samples: 16 }
                } else {
                    AdmissionControl::Off
                },
            };
            println!(
                "fleet: {} robots, horizon {}, variants [{}], {} workers, {} host(s), drills [{}]",
                fleet_cfg.robots,
                fleet_cfg.horizon,
                fleet_cfg.variants.join(","),
                serve_cfg.workers,
                n_hosts.max(1),
                fleet_cfg.drills.iter().map(|d| d.label()).collect::<Vec<_>>().join(",")
            );
            let obs_params = hbvla::sim::observe::ObsParams::clean();
            // `--hosts N` (N >= 2) routes every fleet request across the
            // wire: N loopback hosts behind the placement-hashed router,
            // with the same admission policy router-side.
            let report = if n_hosts >= 2 {
                let router_cfg = RouterConfig {
                    admission: serve_cfg.admission,
                    replicas: args.usize_or("replicas", 1).max(1),
                };
                let cluster = LocalCluster::spawn(
                    Arc::clone(&registry),
                    serve_cfg,
                    n_hosts,
                    router_cfg,
                )
                .unwrap_or_else(|e| panic!("spawn {n_hosts}-host cluster: {e}"));
                let report = run_fleet_on(&registry, &cluster, &fleet_cfg, &obs_params);
                cluster.shutdown();
                report
            } else {
                let server = PolicyServer::start(Arc::clone(&registry), serve_cfg);
                let report = run_fleet_on(&registry, &server, &fleet_cfg, &obs_params);
                server.shutdown();
                report
            }
            .unwrap_or_else(|e| {
                eprintln!("fleet failed: {e}");
                std::process::exit(2);
            });
            println!("{}", report.render());
            // `--json PATH`: merge the fleet section into an existing
            // hbvla-bench-v1 report at PATH (the perf baseline), or write
            // a standalone wrapper if PATH doesn't hold one.
            if let Some(path) = args.get("json") {
                let fleet_obj = report.to_json();
                let merged = match std::fs::read_to_string(path) {
                    Ok(bench) if bench.contains("\"schema\": \"hbvla-bench-v1\"") => {
                        merge_fleet_json(&bench, &fleet_obj)
                    }
                    _ => format!("{{\n  \"fleet\": {fleet_obj}\n}}\n"),
                };
                std::fs::write(path, merged)
                    .unwrap_or_else(|e| panic!("write fleet json {path}: {e}"));
                println!("wrote fleet report into {path}");
            }
        }
        Some("all") => {
            emit(&hbvla::eval::tables::table1_simpler(&budget), md);
            emit(&hbvla::eval::tables::table2_libero(&budget), md);
            emit(&[hbvla::eval::ablation::table3_permutation(&budget)], md);
            emit(&[hbvla::eval::ablation::table4_hessian(&budget)], md);
            emit(&[hbvla::eval::figures::fig3_aloha(&budget)], md);
            emit(&[hbvla::eval::figures::fig4_sensitivity(&budget)], md);
        }
        _ => {
            eprintln!(
                "usage: hbvla <table1|table2|table3|table4|fig1|fig3|fig4|quantize|perf|serve|\
                 route|fleet|all> \
                 [--episodes N] [--demos N] [--seed S] [--threads T] [--method M] [--md] [--smoke]\n\
                 perf flags: [--json PATH] (machine-readable BENCH baseline)\n\
                 serve flags: [--variant dense|rtn-packed|hbvla-packed|hbvla-exact|\
                 rtn-packed-a8|hbvla-packed-a8] \
                 [--act-precision f32|int8] [--act-scale per-token|static] [--act-clip max|p999] \
                 [--attn-precision f32|int8] [--workers N] [--shards N] \
                 [--max-batch N] [--max-wait-us U] [--requests N] \
                 [--listen ADDR] (wire-host mode)\n\
                 route flags: [--hosts N] [--replicas R] [--requests N] [--variants a,b,c] \
                 [--deadline-us U] [--workers N] [--shards N] [--max-batch N] [--max-wait-us U]\n\
                 fleet flags: [--robots N] [--horizon N] [--variants a,b,c] [--reference NAME] \
                 [--deadline-us U] \
                 [--drill none|overload|hotspot|worker-loss|host-loss|variant-kill|all|LIST] \
                 [--hosts N] [--replicas R] [--control-hz F] \
                 [--workers N] [--shards N] [--max-batch N] [--max-wait-us U] [--json PATH]"
            );
            std::process::exit(2);
        }
    }
}
