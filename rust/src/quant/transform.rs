//! Transform-domain exact serving representation.
//!
//! [`TransformPacked`] is the execution form behind
//! [`crate::model::params::WeightRepr::TransformPacked`]: the Haar-domain
//! sign bitplane HBVLA actually commits (ONE plane — no residual chain),
//! together with the column permutation of Algorithm 1, the Haar level
//! metadata, and the salient-weight side-channel. Where
//! [`crate::model::params::WeightRepr::Packed`] re-packs the method's
//! *reconstruction* with residual bitplanes at a ≤0.5% energy tolerance
//! (approximate serving), this form serves the committed coefficients
//! exactly by moving the transform to the activation side:
//!
//! ```text
//!   Ŵ = Pᵀ-unpermute( C · B ) + salient      (offline reconstruction)
//!   Ŵ·x = C · B·(Pᵀx) + salient·x_sal        (what the kernels execute)
//! ```
//!
//! with C the packed Haar-domain coefficient plane and B the Haar
//! synthesis map ([`crate::haar::haar_act_fwd_vec`] applies it to
//! activations). The activation-side work is O(m): a permuted gather fused
//! with the pairwise sum/difference pass (and, under W1A8, with the
//! activation-scale sweep of [`PackedBits::quantize_act_with_scale`]),
//! followed by the unmodified packed GEMV/GEMM. Exactness is by
//! construction — the bitplane IS the commitment, so there is no
//! reconstruction error for residual planes to absorb, which is where the
//! resident-memory drop over the repacked form comes from.
//!
//! The salient side-channel carries the Hessian-selected columns
//! (k_sal ≤ 40) as an order-2 residual-binarized correction — the paper's
//! high-fidelity salient treatment, committed in packed form. Like the
//! main plane, it is exact by construction: the packed correction IS the
//! commitment, and the forward executes it directly (a k_sal-wide gather
//! + packed GEMV on full-precision activations).

use crate::haar::half_len;
use crate::quant::packed::{ActI8, PackedBits, DEPLOY_GROUP_SIZE};
use crate::quant::permute::unpermute_cols;
use crate::tensor::matrix::Matrix;

/// Pick the packed group size for a Haar-domain plane whose bands are
/// [0, half) and [half, 2·half): the largest divisor of `half` that is
/// ≤ [`DEPLOY_GROUP_SIZE`], so group boundaries land on the band seam and
/// no (α, μ) pair ever spans low- and high-pass coefficients (their
/// statistics differ by construction). Degenerate halves whose largest
/// admissible divisor is tiny (< 16, e.g. a large prime) fall back to
/// [`DEPLOY_GROUP_SIZE`] and accept one straddling group rather than
/// per-column metadata.
pub fn transform_group_size(half: usize) -> usize {
    if half == 0 {
        return 1;
    }
    if half <= DEPLOY_GROUP_SIZE {
        return half;
    }
    let mut best = 1;
    for d in 1..=DEPLOY_GROUP_SIZE {
        if half % d == 0 {
            best = d;
        }
    }
    if best >= 16 {
        best
    } else {
        DEPLOY_GROUP_SIZE
    }
}

/// The salient-weight side-channel: an order-≤2 residual-binarized
/// correction over the salient columns (rows × k_sal), indexed by their
/// original column positions, added on top of the non-salient transform
/// reconstruction (Eq. 18's Ŵ = Ŵ_nonsal + Ŵ_sal — the order-2 salient
/// path of Eqs. 15–17, committed packed and therefore served exactly).
#[derive(Clone, Debug)]
pub struct SalientCols {
    /// Sorted original column indices (u16-range in the paper's bit
    /// accounting; u32 here matches the store serialization width).
    pub idx: Vec<u32>,
    /// Packed correction, rows × idx.len(), order ≤ 2.
    pub bits: PackedBits,
}

impl SalientCols {
    /// Bytes held resident: indices + the packed correction planes.
    pub fn storage_bytes(&self) -> usize {
        self.idx.len() * 4 + self.bits.storage_bytes()
    }
}

/// Packed Haar-domain layer: permutation + one-level Haar metadata + the
/// committed coefficient bitplane + the salient side-channel.
#[derive(Clone, Debug)]
pub struct TransformPacked {
    /// Original input dim m (columns of the dense layer this replaces).
    pub cols_in: usize,
    /// Haar decomposition levels (currently always 1; carried so the
    /// store format doesn't change when multi-level lands).
    pub levels: u8,
    /// Column ordering π of Algorithm 1, length `cols_in`: the gather
    /// x_p[k] = x[perm[k]] is the runtime Pᵀ.
    pub perm: Vec<u32>,
    /// Haar-domain packed coefficients C: rows × 2·⌈cols_in/2⌉, order 1.
    pub bits: PackedBits,
    /// Salient correction columns, if the layer has salient weights.
    pub salient: Option<SalientCols>,
}

impl TransformPacked {
    /// Assemble and validate. Panics on inconsistent metadata — this is a
    /// commit-time constructor, not a deserialization path (which
    /// validates with errors instead).
    pub fn new(
        cols_in: usize,
        perm: Vec<u32>,
        bits: PackedBits,
        salient: Option<SalientCols>,
    ) -> Self {
        assert_eq!(perm.len(), cols_in, "perm length != cols_in");
        assert_eq!(bits.cols, 2 * half_len(cols_in), "bits cols != 2*half_len(cols_in)");
        assert_eq!(bits.order(), 1, "transform-exact serving commits exactly one bitplane");
        let mut seen = vec![false; cols_in];
        for &p in &perm {
            assert!((p as usize) < cols_in && !seen[p as usize], "perm is not a permutation");
            seen[p as usize] = true;
        }
        if let Some(s) = &salient {
            assert_eq!(s.bits.rows, bits.rows, "salient rows mismatch");
            assert_eq!(s.bits.cols, s.idx.len(), "salient idx/cols mismatch");
            assert!(s.bits.order() <= 2, "salient side-channel is order-2 at most");
            assert!(s.idx.windows(2).all(|w| w[0] < w[1]), "salient idx must be sorted unique");
            assert!(s.idx.iter().all(|&j| (j as usize) < cols_in), "salient idx out of range");
        }
        TransformPacked { cols_in, levels: 1, perm, bits, salient }
    }

    /// Output rows of the layer.
    pub fn rows(&self) -> usize {
        self.bits.rows
    }

    /// (rows, cols) of the dense layer this representation replaces.
    pub fn dims(&self) -> (usize, usize) {
        (self.bits.rows, self.cols_in)
    }

    /// Salient column count (the side-channel width).
    pub fn salient_count(&self) -> usize {
        self.salient.as_ref().map_or(0, |s| s.idx.len())
    }

    /// The activation-side transform z = B·(Pᵀx): permuted gather fused
    /// with the pairwise sum/difference pass of
    /// [`crate::haar::haar_act_fwd_into`] — one O(m) sweep, no scratch
    /// gather buffer and NO max tracking (this is the
    /// [`crate::quant::packed::ActScaleMode::Static`] hot path, where the
    /// calibrated scale makes the max sweep unnecessary; the W1A32 path
    /// uses it too). Same arithmetic per element as
    /// [`Self::transform_act_with_max`], so z is bit-identical.
    pub fn transform_act(&self, x: &[f32]) -> Vec<f32> {
        let mut z = Vec::new();
        self.transform_act_into(x, &mut z);
        z
    }

    /// [`Self::transform_act`] writing into a caller-owned buffer (resized
    /// to 2·⌈m/2⌉, NOT pre-cleared): the serving hot paths feed pooled
    /// buffers through here so the coalesced W1A8 forward allocates
    /// nothing per token. Every slot is written explicitly — including the
    /// odd-m copy slot and its zero padding slot — so a stale reused
    /// buffer can never leak a previous token's coefficients.
    pub fn transform_act_into(&self, x: &[f32], z: &mut Vec<f32>) {
        z.resize(2 * half_len(self.cols_in), 0.0);
        self.transform_act_slice(x, z);
    }

    /// Core sweep of [`Self::transform_act_into`] over an exact-size
    /// slice (lets the batched path target matrix rows directly).
    fn transform_act_slice(&self, x: &[f32], z: &mut [f32]) {
        assert_eq!(x.len(), self.cols_in, "transform_act dim mismatch");
        let m = self.cols_in;
        let j = half_len(m);
        debug_assert_eq!(z.len(), 2 * j);
        for k in 0..m / 2 {
            let a = x[self.perm[2 * k] as usize];
            let b = x[self.perm[2 * k + 1] as usize];
            z[k] = a + b;
            z[j + k] = a - b;
        }
        if m % 2 == 1 {
            z[j - 1] = x[self.perm[m - 1] as usize];
            // Explicit: the synthesis never reads z[2j−1], but a reused
            // buffer must not carry a stale value into the quantizer's
            // max sweep.
            z[2 * j - 1] = 0.0;
        }
    }

    /// [`Self::transform_act`] additionally returning max|z| tracked in
    /// the same sweep — the W1A8 path's activation-scale input
    /// (`scale = max|z|/127`), so INT8 serving pays the same number of
    /// activation passes as [`PackedBits::quantize_act`] does on a plain
    /// packed layer. The max over a fixed value set is order-independent
    /// in f32, so this equals `act_scale_i8(z)·127` bit-for-bit — the
    /// property the sequential/batched W1A8 parity rests on.
    pub fn transform_act_with_max(&self, x: &[f32]) -> (Vec<f32>, f32) {
        let mut z = Vec::new();
        let mx = self.transform_act_with_max_into(x, &mut z);
        (z, mx)
    }

    /// [`Self::transform_act_with_max`] into a caller-owned buffer (same
    /// write-every-slot discipline as [`Self::transform_act_into`], so
    /// pooled buffers are safe); returns max|z|.
    pub fn transform_act_with_max_into(&self, x: &[f32], z: &mut Vec<f32>) -> f32 {
        assert_eq!(x.len(), self.cols_in, "transform_act dim mismatch");
        let m = self.cols_in;
        let j = half_len(m);
        z.resize(2 * j, 0.0);
        let mut mx = 0.0f32;
        for k in 0..m / 2 {
            let a = x[self.perm[2 * k] as usize];
            let b = x[self.perm[2 * k + 1] as usize];
            let lo = a + b;
            let hi = a - b;
            z[k] = lo;
            z[j + k] = hi;
            mx = mx.max(lo.abs()).max(hi.abs());
        }
        if m % 2 == 1 {
            let v = x[self.perm[m - 1] as usize];
            z[j - 1] = v;
            // The synthesis never reads z[2j−1]; zero it anyway so a
            // stale pooled buffer can't leak into the quantizer sweep.
            z[2 * j - 1] = 0.0;
            mx = mx.max(v.abs());
        }
        mx
    }

    /// The ONE per-token transform→quantize sequence every W1A8 entry
    /// point shares (GEMV, GEMM, pooled or owned): `None` = per-token
    /// scale from the fused max sweep; `Some(s)` = calibrated static
    /// z-domain scale through the max-free transform (the scale
    /// `calib::scales` pins for transform-exact layers is max|z|/127,
    /// NOT max|x| — the kernel quantizes z; out-of-range coefficients
    /// saturate at ±127).
    fn quantize_transformed_scaled_into(&self, x: &[f32], scale: Option<f32>, act: &mut ActI8) {
        // The z buffer comes from the shared scratch pool: steady-state
        // coalesced serving quantizes transform-domain tokens straight
        // into the pooled ActI8 with zero per-token allocations.
        let mut z = crate::quant::packed::take_scratch_z();
        match scale {
            Some(s) => {
                self.transform_act_into(x, &mut z);
                self.bits.quantize_act_with_scale_into(&z, s, act);
            }
            None => {
                let mx = self.transform_act_with_max_into(x, &mut z);
                self.bits.quantize_act_with_scale_into(&z, mx / 127.0, act);
            }
        }
        crate::quant::packed::put_scratch_z(z);
    }

    /// Quantize one token for the W1A8 path: transform (with the fused
    /// max sweep) then the fused quantize+group-sum+bit-slice pass.
    pub fn quantize_transformed(&self, x: &[f32]) -> ActI8 {
        let mut act = ActI8::default();
        self.quantize_transformed_scaled_into(x, None, &mut act);
        act
    }

    /// [`Self::quantize_transformed`] with a calibrated static z-domain
    /// scale (see [`Self::quantize_transformed_scaled_into`]).
    pub fn quantize_transformed_with_scale(&self, x: &[f32], scale: f32) -> ActI8 {
        let mut act = ActI8::default();
        self.quantize_transformed_scaled_into(x, Some(scale), &mut act);
        act
    }

    /// Add the salient side-channel contribution for one token: gather the
    /// k_sal ORIGINAL (untransformed, f32) activations at the salient
    /// indices and run the packed correction GEMV over them — the
    /// side-channel serves at full activation precision under both W1A32
    /// and W1A8 (it is tiny; quantizing it would buy nothing). One shared
    /// helper so the sequential and batched paths accumulate in the
    /// identical order (bit-parity per request).
    fn salient_accumulate(&self, x: &[f32], y: &mut [f32]) {
        let Some(s) = &self.salient else { return };
        let x_sal: Vec<f32> = s.idx.iter().map(|&j| x[j as usize]).collect();
        let add = s.bits.matvec_owned(&x_sal);
        for (slot, v) in y.iter_mut().zip(&add) {
            *slot += *v;
        }
    }

    /// y = Ŵ·x executed in the transform domain (W1A32): fused gather+Haar
    /// on the activation, packed GEMV against the committed plane, salient
    /// side-channel accumulation. The form the
    /// [`crate::model::layers::linear_vec`] dispatch calls.
    pub fn matvec_owned(&self, x: &[f32]) -> Vec<f32> {
        self.matvec_owned_mt(x, crate::util::threadpool::default_threads())
    }

    /// [`Self::matvec_owned`] with an explicit thread budget (the
    /// `model::layers` dispatch form — a pinned `--threads` budget
    /// reaches the packed GEMV fan-out).
    pub fn matvec_owned_mt(&self, x: &[f32], threads: usize) -> Vec<f32> {
        let mut z = crate::quant::packed::take_scratch_z();
        self.transform_act_into(x, &mut z);
        let mut y = self.bits.matvec_owned_mt(&z, None, threads);
        self.salient_accumulate(x, &mut y);
        crate::quant::packed::put_scratch_z(z);
        y
    }

    /// W1A8 twin of [`Self::matvec_owned`]: the transformed activation is
    /// quantized to i8 (scale fused into the transform sweep) and the
    /// integer packed GEMV runs; the salient side-channel stays f32.
    pub fn matvec_i8_owned(&self, x: &[f32]) -> Vec<f32> {
        self.matvec_i8_owned_with_scale(x, None)
    }

    /// [`Self::matvec_i8_owned`] with an optional calibrated static
    /// z-domain scale ([`crate::quant::packed::ActScaleMode::Static`]).
    pub fn matvec_i8_owned_with_scale(&self, x: &[f32], scale: Option<f32>) -> Vec<f32> {
        self.matvec_i8_owned_mt(x, scale, crate::util::threadpool::default_threads())
    }

    /// [`Self::matvec_i8_owned_with_scale`] with an explicit thread
    /// budget (the dispatch form). Quantizes into a pooled [`ActI8`]
    /// (same buffers the GEMM entries reuse), static scales through the
    /// max-free transform — the per-token computation mirrors
    /// [`Self::quantize_transformed`] exactly.
    pub fn matvec_i8_owned_mt(&self, x: &[f32], scale: Option<f32>, threads: usize) -> Vec<f32> {
        let mut act = crate::quant::packed::take_scratch_act();
        self.quantize_transformed_scaled_into(x, scale, &mut act);
        let mut y = vec![0.0f32; self.bits.rows];
        self.bits.matvec_i8_mt(&act, &mut y, threads);
        self.salient_accumulate(x, &mut y);
        crate::quant::packed::put_scratch_act(act);
        y
    }

    /// Transform every token of a TOKEN-MAJOR activation matrix (`xt`:
    /// n × cols_in, one token per row) into the Haar domain: returns Zt
    /// TOKEN-MAJOR (n × 2·⌈m/2⌉) with row t = B·Pᵀ·xt[t], computed by the
    /// same per-token sweep as [`Self::transform_act`]. Token-major
    /// throughout so the batched entry points transpose X exactly once
    /// and feed the packed GEMM's token-major entry directly — the old
    /// path transposed Zt here only for the GEMM to transpose it back.
    fn transform_tokens_t(&self, xt: &Matrix) -> Matrix {
        let j2 = 2 * half_len(self.cols_in);
        let mut zt = Matrix::zeros(xt.rows, j2);
        for t in 0..xt.rows {
            // Max-free sweep straight into the output row: the f32 GEMM
            // never needs a scale, and no per-token z vector exists.
            self.transform_act_slice(xt.row(t), zt.row_mut(t));
        }
        zt
    }

    /// Batched Y = Ŵ·X (W1A32): per-token transform, then the multi-token
    /// packed GEMM (token-major entry — no intermediate transposes), then
    /// the per-token salient accumulation. Each output column is
    /// bit-identical to [`Self::matvec_owned`] on that column alone (the
    /// packed GEMM shares the GEMV's per-(row, token) accumulation order,
    /// and the transform and salient helpers are the same code per
    /// token).
    pub fn matmul(&self, x: &Matrix) -> Matrix {
        self.matmul_mt(x, crate::util::threadpool::default_threads())
    }

    /// [`Self::matmul`] with an explicit thread budget (the dispatch
    /// form).
    pub fn matmul_mt(&self, x: &Matrix, threads: usize) -> Matrix {
        assert_eq!(x.rows, self.cols_in, "transform matmul dim mismatch");
        let mut xt = crate::quant::packed::take_scratch_xt();
        x.transpose_into(&mut xt);
        let zt = self.transform_tokens_t(&xt);
        let mut out = self.bits.matmul_t(&zt, threads);
        self.salient_accumulate_tokens_t(&xt, &mut out);
        crate::quant::packed::put_scratch_xt(xt);
        out
    }

    /// W1A8 batched GEMM: each transformed token is quantized with its own
    /// symmetric scale inside the packed GEMM (identical to the fused
    /// sequential scale — max is sweep-order independent), salient
    /// side-channel in f32.
    pub fn matmul_i8(&self, x: &Matrix) -> Matrix {
        self.matmul_i8_with_scale(x, None)
    }

    /// [`Self::matmul_i8`] with an optional calibrated static z-domain
    /// scale applied to every token (the static-scale batched path).
    /// Each token is quantized straight out of the fused
    /// gather+Haar+max sweep — the max that sweep tracks IS the per-token
    /// scale, so z is never swept a second time (exactly the sequential
    /// [`Self::quantize_transformed`] computation, which keeps the
    /// GEMV/GEMM bit-parity by construction).
    pub fn matmul_i8_with_scale(&self, x: &Matrix, scale: Option<f32>) -> Matrix {
        self.matmul_i8_scaled_mt(x, scale, crate::util::threadpool::default_threads())
    }

    /// [`Self::matmul_i8_with_scale`] with an explicit thread budget
    /// (the dispatch form).
    pub fn matmul_i8_scaled_mt(&self, x: &Matrix, scale: Option<f32>, threads: usize) -> Matrix {
        assert_eq!(x.rows, self.cols_in, "transform matmul dim mismatch");
        let mut xt = crate::quant::packed::take_scratch_xt();
        x.transpose_into(&mut xt);
        // Tokens quantize straight out of the fused transform sweep into
        // the shared scratch pool (no re-sweep of z, no per-call ActI8
        // allocations): static scales use the max-free transform — the
        // calibrated scale is the whole point of skipping the sweep —
        // per-token scales come from the max the sweep tracks anyway
        // (both mirror the sequential GEMV paths, so GEMV/GEMM stay
        // bit-identical per token).
        let mut out = self.bits.matmul_i8_tokens_with(xt.rows, threads, |t, act| {
            self.quantize_transformed_scaled_into(xt.row(t), scale, act)
        });
        self.salient_accumulate_tokens_t(&xt, &mut out);
        crate::quant::packed::put_scratch_xt(xt);
        out
    }

    /// Per-token salient accumulation over a TOKEN-MAJOR batch, one row at
    /// a time through [`Self::salient_accumulate`] (bit-parity with the
    /// vec path; shares the caller's single transpose of X).
    fn salient_accumulate_tokens_t(&self, xt: &Matrix, out: &mut Matrix) {
        if self.salient.is_none() {
            return;
        }
        let rows = out.rows;
        let mut ycol = vec![0.0f32; rows];
        for t in 0..xt.rows {
            ycol.iter_mut().for_each(|v| *v = 0.0);
            self.salient_accumulate(xt.row(t), &mut ycol);
            for (r, v) in ycol.iter().enumerate() {
                *out.at_mut(r, t) += *v;
            }
        }
    }

    /// Offline dense reconstruction — the ground truth the transform
    /// forward is exact against (cold paths: export, diffing, tests):
    /// unpermute(haar_inv(dequantized plane)) + salient scatter.
    pub fn dequantize(&self) -> Matrix {
        let c = self.bits.dequantize();
        let rec = crate::haar::haar_rows_inv(&c, self.cols_in);
        let pi: Vec<usize> = self.perm.iter().map(|&p| p as usize).collect();
        let mut w = unpermute_cols(&rec, &pi);
        if let Some(s) = &self.salient {
            let corr = s.bits.dequantize();
            for (k, &jcol) in s.idx.iter().enumerate() {
                for r in 0..w.rows {
                    *w.at_mut(r, jcol as usize) += corr.at(r, k);
                }
            }
        }
        w
    }

    /// Bytes held resident: the single Haar-domain plane, the permutation
    /// (u32 per column), and the salient side-channel.
    pub fn storage_bytes(&self) -> usize {
        self.bits.storage_bytes()
            + self.perm.len() * 4
            + self.salient.as_ref().map_or(0, |s| s.storage_bytes())
    }

    /// Serialize (self-describing, little-endian): header (cols_in,
    /// levels, salient count), permutation, salient side-channel, then the
    /// bitplane via [`PackedBits::write_to`]. Bit-exact round-trip.
    pub fn write_to<W: std::io::Write>(&self, w: &mut W) -> std::io::Result<()> {
        w.write_all(&(self.cols_in as u32).to_le_bytes())?;
        w.write_all(&(self.levels as u32).to_le_bytes())?;
        let k = self.salient_count();
        w.write_all(&(k as u32).to_le_bytes())?;
        for &p in &self.perm {
            w.write_all(&p.to_le_bytes())?;
        }
        if let Some(s) = &self.salient {
            for &i in &s.idx {
                w.write_all(&i.to_le_bytes())?;
            }
            s.bits.write_to(w)?;
        }
        self.bits.write_to(w)
    }

    /// Inverse of [`Self::write_to`]; validates the metadata (permutation
    /// property, salient ranges, bitplane shape/order) instead of trusting
    /// the stream.
    pub fn read_from<R: std::io::Read>(r: &mut R) -> std::io::Result<Self> {
        fn bad(msg: &str) -> std::io::Error {
            std::io::Error::new(std::io::ErrorKind::InvalidData, msg)
        }
        fn read_u32<R: std::io::Read>(r: &mut R) -> std::io::Result<u32> {
            let mut b = [0u8; 4];
            r.read_exact(&mut b)?;
            Ok(u32::from_le_bytes(b))
        }
        let cols_in = read_u32(r)? as usize;
        let levels = read_u32(r)?;
        let k = read_u32(r)? as usize;
        const DIM_CAP: usize = 1 << 24;
        if cols_in == 0 || cols_in > DIM_CAP || levels != 1 || k > cols_in {
            return Err(bad("bad transform header"));
        }
        let mut perm = Vec::with_capacity(cols_in.min(DIM_CAP));
        let mut seen = vec![false; cols_in];
        for _ in 0..cols_in {
            let p = read_u32(r)? as usize;
            if p >= cols_in || seen[p] {
                return Err(bad("bad transform permutation"));
            }
            seen[p] = true;
            perm.push(p as u32);
        }
        let salient = if k > 0 {
            let mut idx = Vec::with_capacity(k);
            for _ in 0..k {
                let i = read_u32(r)?;
                if i as usize >= cols_in || idx.last().is_some_and(|&l| i <= l) {
                    return Err(bad("bad salient indices"));
                }
                idx.push(i);
            }
            let sbits = PackedBits::read_from(r)?;
            if sbits.cols != k || sbits.order() > 2 {
                return Err(bad("bad salient correction"));
            }
            Some(SalientCols { idx, bits: sbits })
        } else {
            None
        };
        let bits = PackedBits::read_from(r)?;
        if bits.cols != 2 * half_len(cols_in) || bits.order() != 1 {
            return Err(bad("bad transform bitplane"));
        }
        if let Some(s) = &salient {
            if s.bits.rows != bits.rows {
                return Err(bad("salient rows mismatch"));
            }
        }
        Ok(TransformPacked { cols_in, levels: 1, perm, bits, salient })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::permute::{pairing_and_chaining, permute_cols, NormKind};
    use crate::tensor::ops::matvec;
    use crate::util::rng::Rng;

    /// Build a TransformPacked by hand from the HBVLA pipeline pieces:
    /// permute → Haar → pack one plane, plus an optional salient
    /// side-channel correcting towards W.
    fn build(w: &Matrix, salient_cols: &[usize], rng: &mut Rng) -> TransformPacked {
        let _ = rng;
        let pi = pairing_and_chaining(w, None, NormKind::L2);
        let u = crate::haar::haar_rows(&permute_cols(w, &pi));
        let gs = transform_group_size(half_len(w.cols));
        let bits = PackedBits::pack(&u, gs);
        let perm: Vec<u32> = pi.iter().map(|&p| p as u32).collect();
        let salient = if salient_cols.is_empty() {
            None
        } else {
            // Side channel = order-2 packed residual of W at the salient
            // columns against the transform reconstruction (the commit
            // HBVLA makes; see methods::hbvla).
            let partial =
                TransformPacked::new(w.cols, perm.clone(), bits.clone(), None).dequantize();
            let resid = w.sub(&partial).select_cols(salient_cols);
            let idx: Vec<u32> = salient_cols.iter().map(|&j| j as u32).collect();
            Some(SalientCols { idx, bits: PackedBits::pack_residual(&resid, 64, 2, 0.0) })
        };
        TransformPacked::new(w.cols, perm, bits, salient)
    }

    #[test]
    fn forward_matches_offline_reconstruction() {
        let mut rng = Rng::new(201);
        for &(rows, cols) in &[(8usize, 64usize), (6, 70), (5, 33), (7, 128), (3, 9)] {
            let w = Matrix::gauss(rows, cols, 1.0, &mut rng);
            let t = build(&w, &[], &mut rng);
            assert_eq!(t.bits.order(), 1, "zero residual planes");
            let deq = t.dequantize();
            let x: Vec<f32> = (0..cols).map(|_| rng.gauss() as f32).collect();
            let y_ref = matvec(&deq, &x);
            let y = t.matvec_owned(&x);
            for r in 0..rows {
                assert!(
                    (y[r] - y_ref[r]).abs() < 1e-3 * (1.0 + y_ref[r].abs()),
                    "({rows},{cols}) row {r}: {} vs {}",
                    y[r],
                    y_ref[r]
                );
            }
        }
    }

    #[test]
    fn salient_side_channel_served_exactly() {
        let mut rng = Rng::new(202);
        let w = Matrix::gauss(9, 70, 1.0, &mut rng);
        let t = build(&w, &[3, 17, 64], &mut rng);
        let deq = t.dequantize();
        // The committed order-2 correction tightens the salient columns
        // towards W versus the transform-only reconstruction…
        let bare = build(&w, &[], &mut rng).dequantize();
        let col_err = |m: &Matrix, j: usize| -> f64 {
            (0..9).map(|r| ((m.at(r, j) - w.at(r, j)) as f64).powi(2)).sum()
        };
        for &j in &[3usize, 17, 64] {
            assert!(col_err(&deq, j) < col_err(&bare, j), "col {j} not improved");
        }
        // …and, like the main plane, it is served EXACTLY: the forward
        // matches the dense product of the full reconstruction.
        let x: Vec<f32> = (0..70).map(|_| rng.gauss() as f32).collect();
        let y = t.matvec_owned(&x);
        let y_ref = matvec(&deq, &x);
        for r in 0..9 {
            assert!((y[r] - y_ref[r]).abs() < 1e-3 * (1.0 + y_ref[r].abs()));
        }
    }

    #[test]
    fn batched_gemm_bit_identical_to_gemv_per_token() {
        let mut rng = Rng::new(203);
        let w = Matrix::gauss(10, 70, 1.0, &mut rng);
        let t = build(&w, &[5, 40], &mut rng);
        let x = Matrix::gauss(70, 6, 1.0, &mut rng);
        let xt = x.transpose();
        let y = t.matmul(&x);
        let y8 = t.matmul_i8(&x);
        for tok in 0..6 {
            let yv = t.matvec_owned(xt.row(tok));
            let yv8 = t.matvec_i8_owned(xt.row(tok));
            for r in 0..10 {
                assert_eq!(y.at(r, tok), yv[r], "f32 ({r},{tok})");
                assert_eq!(y8.at(r, tok), yv8[r], "i8 ({r},{tok})");
            }
        }
    }

    #[test]
    fn i8_path_within_activation_roundoff_of_f32() {
        let mut rng = Rng::new(204);
        let w = Matrix::gauss(8, 96, 1.0, &mut rng);
        let t = build(&w, &[2, 50], &mut rng);
        let deq = t.dequantize();
        let x: Vec<f32> = (0..96).map(|_| rng.gauss() as f32).collect();
        let y32 = t.matvec_owned(&x);
        let y8 = t.matvec_i8_owned(&x);
        // The i8 deviation is bounded by the transformed-activation
        // round-off pushed through the committed plane (salient is f32 in
        // both paths): |Δz| ≤ s/2 per coefficient, |y32−y8| ≤ s/2·Σ|C_r|.
        let (_, mx) = t.transform_act_with_max(&x);
        let s = mx / 127.0;
        let c = t.bits.dequantize();
        for r in 0..8 {
            let abs_row: f32 = c.row(r).iter().map(|v| v.abs()).sum();
            let bound = 0.5 * s * abs_row * 1.001 + 1e-4;
            assert!((y32[r] - y8[r]).abs() <= bound, "row {r}: {} vs {}", y32[r], y8[r]);
        }
        assert!(deq.is_finite());
    }

    #[test]
    fn fused_scale_equals_reference_scale() {
        let mut rng = Rng::new(205);
        for cols in [64usize, 65, 70, 33] {
            let w = Matrix::gauss(4, cols, 1.0, &mut rng);
            let t = build(&w, &[], &mut rng);
            let x: Vec<f32> = (0..cols).map(|_| 2.0 * rng.gauss() as f32).collect();
            let (z, mx) = t.transform_act_with_max(&x);
            assert_eq!(mx / 127.0, crate::tensor::ops::act_scale_i8(&z), "cols={cols}");
            let act = t.quantize_transformed(&x);
            let act_ref = t.bits.quantize_act(&z);
            assert_eq!(act.q, act_ref.q);
            assert_eq!(act.scale, act_ref.scale);
            assert_eq!(act.group_sums, act_ref.group_sums);
        }
    }

    #[test]
    fn static_z_scale_gemv_gemm_agree_and_match_per_token_at_own_scale() {
        let mut rng = Rng::new(208);
        let w = Matrix::gauss(8, 70, 1.0, &mut rng);
        let t = build(&w, &[4, 20], &mut rng);
        let x: Vec<f32> = (0..70).map(|_| rng.gauss() as f32).collect();
        // Static scale equal to the token's own fused scale reproduces
        // the per-token path bit-for-bit.
        let (_, mx) = t.transform_act_with_max(&x);
        let y_static = t.matvec_i8_owned_with_scale(&x, Some(mx / 127.0));
        let y_dyn = t.matvec_i8_owned(&x);
        assert_eq!(y_static, y_dyn);
        // GEMM and GEMV agree per token under a shared static z-scale.
        let xb = Matrix::gauss(70, 4, 1.0, &mut rng);
        let g = t.matmul_i8_with_scale(&xb, Some(0.03));
        let xbt = xb.transpose();
        for tok in 0..4 {
            let yv = t.matvec_i8_owned_with_scale(xbt.row(tok), Some(0.03));
            for r in 0..8 {
                assert_eq!(g.at(r, tok), yv[r], "({r},{tok})");
            }
        }
    }

    #[test]
    fn transform_into_reused_buffer_matches_fresh() {
        // The pooled-buffer contract: a reused (stale, wrong-sized) z
        // buffer must yield exactly the fresh-allocation transform —
        // including the odd-m copy slot and its zero padding slot, which
        // are the two slots a lazy rewrite would leave stale.
        let mut rng = Rng::new(209);
        for cols in [64usize, 33, 70, 9] {
            let w = Matrix::gauss(4, cols, 1.0, &mut rng);
            let t = build(&w, &[], &mut rng);
            let mut z = vec![f32::NAN; 5]; // wrong size AND poisoned
            let xa: Vec<f32> = (0..cols).map(|_| rng.gauss() as f32).collect();
            t.transform_act_into(&xa, &mut z);
            assert_eq!(z, t.transform_act(&xa), "cols={cols} first use");
            let xb: Vec<f32> = (0..cols).map(|_| 3.0 * rng.gauss() as f32).collect();
            let mx = t.transform_act_with_max_into(&xb, &mut z);
            let (zf, mxf) = t.transform_act_with_max(&xb);
            assert_eq!(z, zf, "cols={cols} reuse");
            assert_eq!(mx, mxf, "cols={cols} max");
        }
    }

    #[test]
    fn serialization_roundtrip_bit_exact() {
        let mut rng = Rng::new(206);
        let w = Matrix::gauss(7, 70, 1.0, &mut rng);
        let t = build(&w, &[1, 33, 69], &mut rng);
        let mut buf = Vec::new();
        t.write_to(&mut buf).unwrap();
        let u = TransformPacked::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(u.cols_in, 70);
        assert_eq!(u.perm, t.perm);
        assert_eq!(u.salient_count(), 3);
        assert_eq!(u.dequantize().data, t.dequantize().data, "round-trip must be bit-exact");
        assert_eq!(u.storage_bytes(), t.storage_bytes());
        // Corrupt permutation → typed io error, not a panic.
        let mut bad = buf.clone();
        bad[12] = 0xFF; // first perm entry out of range
        assert!(TransformPacked::read_from(&mut bad.as_slice()).is_err());
    }

    #[test]
    fn group_size_respects_band_seam() {
        assert_eq!(transform_group_size(32), 32);
        assert_eq!(transform_group_size(64), 64);
        assert_eq!(transform_group_size(35), 35);
        assert_eq!(transform_group_size(68), 34); // 68 = 2·34, 34 ≤ 64
        assert_eq!(transform_group_size(128), 64);
        // Large prime: no admissible divisor ≥ 16 → fall back, straddle.
        assert_eq!(transform_group_size(127), 64);
        assert_eq!(transform_group_size(0), 1);
    }

    #[test]
    fn storage_counts_plane_perm_and_side_channel() {
        let mut rng = Rng::new(207);
        let w = Matrix::gauss(4, 64, 1.0, &mut rng);
        let t = build(&w, &[7], &mut rng);
        let side = t.salient.as_ref().unwrap();
        let expect = t.bits.storage_bytes() + 64 * 4 + (4 + side.bits.storage_bytes());
        assert_eq!(t.storage_bytes(), expect);
        // One plane in the Haar domain is far below dense f32.
        assert!(t.storage_bytes() < 4 * 64 * 4);
    }
}
