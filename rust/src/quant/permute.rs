//! Sparse orthogonal transform: the column permutation that makes the Haar
//! transform adaptive to weight geometry (paper Algorithm 1).
//!
//! By the identity of Eq. 14, the one-level Haar high-pass energy of `W P`
//! equals ¼ Σ_k ‖w_{π(2k−1)} − w_{π(2k)}‖², so the optimal P is the
//! minimum-weight perfect matching + ordering — NP-hard in general, hence
//! the paper's two-phase greedy heuristic:
//!
//! 1. **Pairing** — repeatedly take the unmatched column with the largest
//!    norm and match it to its nearest unmatched neighbour (optionally
//!    restricted to a top-K candidate list);
//! 2. **Chaining** — order the pairs into one sequence, at each step
//!    appending the pair (oriented) whose closer endpoint is nearest to the
//!    current tail, which suppresses discontinuities at pair boundaries
//!    (these matter for the *shared-mean* grouping across a band).

use crate::tensor::matrix::Matrix;

/// Distance criterion between columns, used both by Algorithm 1 and by the
/// Table-3 ablation (column-norm criterion ℓ1 vs ℓ2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NormKind {
    L1,
    L2,
}

/// Squared ℓ2 distance matrix between all column pairs of W (m×m).
/// O(m²·d); layers in MiniVLA have m ≤ a few hundred so this is cheap,
/// and it is computed once per layer.
pub fn column_distances(w: &Matrix) -> Matrix {
    let m = w.cols;
    let mut d = Matrix::zeros(m, m);
    // ‖a−b‖² = ‖a‖² + ‖b‖² − 2a·b ; use the Gram of Wᵀ.
    let wt = w.transpose(); // m×d, rows are columns of W
    let norms: Vec<f32> = (0..m)
        .map(|i| wt.row(i).iter().map(|v| v * v).sum::<f32>())
        .collect();
    for i in 0..m {
        let ri = wt.row(i);
        for j in i + 1..m {
            let rj = wt.row(j);
            let mut dot = 0.0f32;
            for p in 0..wt.cols {
                dot += ri[p] * rj[p];
            }
            let dist = (norms[i] + norms[j] - 2.0 * dot).max(0.0);
            d.set(i, j, dist);
            d.set(j, i, dist);
        }
    }
    d
}

/// Algorithm 1: greedy pairing-and-chaining. Returns the ordering π over
/// the columns of `w` (a permutation of 0..m). `top_k = Some(K)` restricts
/// pairing candidates to the K nearest neighbours of the pivot.
/// `norm` selects the pivot-ordering criterion (Table 3 ablation; the
/// paper's default and winner is ℓ2).
pub fn pairing_and_chaining(w: &Matrix, top_k: Option<usize>, norm: NormKind) -> Vec<usize> {
    let m = w.cols;
    if m <= 2 {
        return (0..m).collect();
    }
    let d = column_distances(w);
    let col_norm: Vec<f32> = match norm {
        NormKind::L2 => w.col_norms(),
        NormKind::L1 => w.col_norms_l1(),
    };

    // Optional top-K neighbour lists.
    let neighbors: Option<Vec<Vec<usize>>> = top_k.map(|k| {
        (0..m)
            .map(|i| {
                let mut idx: Vec<usize> = (0..m).filter(|&j| j != i).collect();
                idx.sort_by(|&a, &b| d.at(i, a).partial_cmp(&d.at(i, b)).unwrap());
                idx.truncate(k);
                idx
            })
            .collect()
    });

    // ---- Pairing ----
    let mut unmatched: Vec<bool> = vec![true; m];
    let mut remaining = m;
    // Pivot order: descending column norm (paper line 7).
    let mut order: Vec<usize> = (0..m).collect();
    order.sort_by(|&a, &b| col_norm[b].partial_cmp(&col_norm[a]).unwrap());

    let mut pairs: Vec<(usize, usize)> = Vec::with_capacity(m / 2 + 1);
    for &i in &order {
        if !unmatched[i] || remaining < 2 {
            continue;
        }
        // Candidate set: top-K neighbours ∩ unmatched, else all unmatched.
        let mut best: Option<usize> = None;
        if let Some(nb) = &neighbors {
            for &t in &nb[i] {
                if unmatched[t] && t != i && best.map(|b| d.at(i, t) < d.at(i, b)).unwrap_or(true) {
                    best = Some(t);
                }
            }
        }
        if best.is_none() {
            for t in 0..m {
                if t != i && unmatched[t] && best.map(|b| d.at(i, t) < d.at(i, b)).unwrap_or(true) {
                    best = Some(t);
                }
            }
        }
        let j = best.expect("at least one unmatched candidate");
        unmatched[i] = false;
        unmatched[j] = false;
        remaining -= 2;
        pairs.push((i, j));
    }
    // Leftover (odd m): self-pair, placed last (paper line 16).
    let leftover: Option<usize> = unmatched.iter().position(|&u| u);

    // ---- Chaining ----
    // Seed with the first-formed pair (contains the max-norm column).
    let mut pi: Vec<usize> = Vec::with_capacity(m);
    let mut rest: Vec<(usize, usize)> = pairs;
    let (a, b) = rest.remove(0);
    pi.push(a);
    pi.push(b);
    let mut tail = b;
    while !rest.is_empty() {
        let mut best_idx = 0;
        let mut best_d = f32::INFINITY;
        for (k, &(x, y)) in rest.iter().enumerate() {
            let dd = d.at(tail, x).min(d.at(tail, y));
            if dd < best_d {
                best_d = dd;
                best_idx = k;
            }
        }
        let (mut u, mut v) = rest.remove(best_idx);
        if d.at(tail, u) > d.at(tail, v) {
            std::mem::swap(&mut u, &mut v);
        }
        pi.push(u);
        pi.push(v);
        tail = v;
    }
    if let Some(r) = leftover {
        pi.push(r);
    }
    debug_assert_eq!(pi.len(), m);
    pi
}

/// Apply the ordering: out(:,k) = w(:,π(k)) — i.e. W·P.
pub fn permute_cols(w: &Matrix, pi: &[usize]) -> Matrix {
    assert_eq!(pi.len(), w.cols);
    w.select_cols(pi)
}

/// Invert the ordering: returns W such that permute_cols(W, π) = input.
pub fn unpermute_cols(w: &Matrix, pi: &[usize]) -> Matrix {
    assert_eq!(pi.len(), w.cols);
    let mut inv = vec![0usize; pi.len()];
    for (k, &p) in pi.iter().enumerate() {
        inv[p] = k;
    }
    w.select_cols(&inv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::haar::pairwise_highpass_energy;
    use crate::util::rng::Rng;

    #[test]
    fn permutation_is_valid() {
        let mut rng = Rng::new(51);
        for m in [4usize, 5, 16, 33, 64] {
            let w = Matrix::gauss(8, m, 1.0, &mut rng);
            let pi = pairing_and_chaining(&w, None, NormKind::L2);
            let mut s = pi.clone();
            s.sort_unstable();
            assert_eq!(s, (0..m).collect::<Vec<_>>(), "m={m}");
        }
    }

    #[test]
    fn permute_unpermute_roundtrip() {
        let mut rng = Rng::new(52);
        let w = Matrix::gauss(6, 12, 1.0, &mut rng);
        let pi = pairing_and_chaining(&w, None, NormKind::L2);
        let p = permute_cols(&w, &pi);
        let back = unpermute_cols(&p, &pi);
        assert!(w.dist_sq(&back) < 1e-12);
    }

    #[test]
    fn reduces_highpass_energy_on_modality_interleaved_weights() {
        // Simulate the paper's motivating structure: columns of two
        // "modalities" with very different statistics, interleaved.
        let mut rng = Rng::new(53);
        let m = 64;
        let w = Matrix::from_fn(32, m, |_, j| {
            if j % 2 == 0 {
                (rng.gauss() * 0.1 + 3.0) as f32 // modality A: large mean
            } else {
                (rng.gauss() * 0.1 - 3.0) as f32 // modality B: negative mean
            }
        });
        let identity: Vec<usize> = (0..m).collect();
        let pi = pairing_and_chaining(&w, None, NormKind::L2);
        let e_id = pairwise_highpass_energy(&w, &identity);
        let e_pi = pairwise_highpass_energy(&w, &pi);
        assert!(
            e_pi < 0.05 * e_id,
            "permutation should collapse cross-modality jumps: {e_pi} vs {e_id}"
        );
    }

    #[test]
    fn top_k_close_to_full_search() {
        let mut rng = Rng::new(54);
        let w = Matrix::gauss(16, 48, 1.0, &mut rng);
        let full = pairing_and_chaining(&w, None, NormKind::L2);
        let topk = pairing_and_chaining(&w, Some(8), NormKind::L2);
        let e_full = pairwise_highpass_energy(&w, &full);
        let e_topk = pairwise_highpass_energy(&w, &topk);
        assert!(e_topk <= 1.5 * e_full, "topk {e_topk} vs full {e_full}");
    }

    #[test]
    fn odd_column_count_keeps_all() {
        let mut rng = Rng::new(55);
        let w = Matrix::gauss(4, 9, 1.0, &mut rng);
        let pi = pairing_and_chaining(&w, Some(3), NormKind::L1);
        assert_eq!(pi.len(), 9);
    }

    #[test]
    fn distance_matrix_symmetry_and_zero_diag() {
        let mut rng = Rng::new(56);
        let w = Matrix::gauss(5, 10, 1.0, &mut rng);
        let d = column_distances(&w);
        for i in 0..10 {
            assert_eq!(d.at(i, i), 0.0);
            for j in 0..10 {
                assert!((d.at(i, j) - d.at(j, i)).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn tiny_matrices_identity() {
        let w = Matrix::zeros(3, 2);
        assert_eq!(pairing_and_chaining(&w, None, NormKind::L2), vec![0, 1]);
        let w1 = Matrix::zeros(3, 1);
        assert_eq!(pairing_and_chaining(&w1, None, NormKind::L2), vec![0]);
    }
}
