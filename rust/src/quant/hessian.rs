//! Calibration Hessians.
//!
//! Standard proxy: H = X Xᵀ = Σₜ xₜxₜᵀ over calibration tokens (GPTQ/OBQ
//! convention). The paper's *policy-aware rectified* Hessian (Eq. 3)
//! replaces the uniform token sum with a token-importance-weighted one,
//! H̃ = X S Xᵀ = Σₜ sₜ xₜxₜᵀ, where S comes from the block gradient probe
//! ([`crate::quant::probe`]). This module provides streaming accumulation
//! of both forms.

use crate::tensor::matrix::Matrix;
use crate::tensor::ops::{gram, gram_weighted};

/// Streaming accumulator for H (d×d) over calibration activations.
/// Activations arrive as matrices with **rows = feature dims (d), cols =
/// tokens** — the xₜ-as-columns convention of the paper.
#[derive(Clone, Debug)]
pub struct HessianAccum {
    pub h: Matrix,
    pub tokens: usize,
    /// Sum of token weights seen (equals `tokens` for the uniform Hessian).
    pub weight_sum: f64,
}

impl HessianAccum {
    pub fn new(dim: usize) -> Self {
        HessianAccum { h: Matrix::zeros(dim, dim), tokens: 0, weight_sum: 0.0 }
    }

    /// Add a chunk with uniform token weights: H += X Xᵀ.
    pub fn add(&mut self, x: &Matrix) {
        assert_eq!(x.rows, self.h.rows, "feature dim mismatch");
        let g = gram(x);
        self.h.add_assign(&g);
        self.tokens += x.cols;
        self.weight_sum += x.cols as f64;
    }

    /// Add a chunk with per-token weights sₜ: H̃ += X S Xᵀ (Eq. 3).
    pub fn add_weighted(&mut self, x: &Matrix, s: &[f32]) {
        assert_eq!(x.rows, self.h.rows, "feature dim mismatch");
        assert_eq!(x.cols, s.len(), "token weight length mismatch");
        let g = gram_weighted(x, s);
        self.h.add_assign(&g);
        self.tokens += x.cols;
        self.weight_sum += s.iter().map(|&v| v as f64).sum::<f64>();
    }

    /// Finalized Hessian, normalized by total weight so that scales are
    /// comparable between the standard and rectified variants.
    pub fn finalize(&self) -> Matrix {
        let mut h = self.h.clone();
        if self.weight_sum > 0.0 {
            h.scale((1.0 / self.weight_sum) as f32);
        }
        h
    }

    pub fn diag(&self) -> Vec<f32> {
        self.finalize().diag()
    }
}

/// H-weighted reconstruction error ‖(W − Ŵ) X‖²_F = tr(Δ H Δᵀ) — the
/// proxy objective of Eq. 2 evaluated through the Hessian. This is the
/// metric Tables 3/4 report (as a relative %).
pub fn hessian_weighted_error(w: &Matrix, w_hat: &Matrix, h: &Matrix) -> f64 {
    assert_eq!(w.cols, h.rows);
    let delta = w.sub(w_hat);
    // tr(Δ H Δᵀ) = Σ_i  δᵢ H δᵢᵀ  over rows δᵢ.
    let mut total = 0.0f64;
    for i in 0..delta.rows {
        let d = delta.row(i);
        // v = H dᵀ ; total += d · v
        for r in 0..h.rows {
            if d[r] == 0.0 {
                continue;
            }
            let hrow = h.row(r);
            let mut acc = 0.0f32;
            for c in 0..h.cols {
                acc += hrow[c] * d[c];
            }
            total += (d[r] * acc) as f64;
        }
    }
    total.max(0.0)
}

/// Relative H-weighted error: err(Ŵ) / err(0) — i.e. normalized by the
/// full signal energy ‖W X‖². Returned as a fraction in [0, ~1].
pub fn relative_hessian_error(w: &Matrix, w_hat: &Matrix, h: &Matrix) -> f64 {
    let zero = Matrix::zeros(w.rows, w.cols);
    let sig = hessian_weighted_error(w, &zero, h);
    if sig <= 0.0 {
        return 0.0;
    }
    hessian_weighted_error(w, w_hat, h) / sig
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::matmul;
    use crate::util::rng::Rng;

    #[test]
    fn accum_matches_direct_gram() {
        let mut rng = Rng::new(61);
        let x1 = Matrix::gauss(8, 30, 1.0, &mut rng);
        let x2 = Matrix::gauss(8, 20, 1.0, &mut rng);
        let mut acc = HessianAccum::new(8);
        acc.add(&x1);
        acc.add(&x2);
        // Direct: concat and gram, then normalize by tokens.
        let mut xall = Matrix::zeros(8, 50);
        for i in 0..8 {
            for t in 0..30 {
                xall.set(i, t, x1.at(i, t));
            }
            for t in 0..20 {
                xall.set(i, 30 + t, x2.at(i, t));
            }
        }
        let mut expect = gram(&xall);
        expect.scale(1.0 / 50.0);
        assert!(acc.finalize().dist_sq(&expect) < 1e-6);
        assert_eq!(acc.tokens, 50);
    }

    #[test]
    fn weighted_with_unit_weights_equals_uniform() {
        let mut rng = Rng::new(62);
        let x = Matrix::gauss(6, 40, 1.0, &mut rng);
        let mut a = HessianAccum::new(6);
        a.add(&x);
        let mut b = HessianAccum::new(6);
        b.add_weighted(&x, &vec![1.0; 40]);
        assert!(a.finalize().dist_sq(&b.finalize()) < 1e-8);
    }

    #[test]
    fn weights_suppress_outlier_tokens() {
        let mut rng = Rng::new(63);
        // One token with huge magnitude dominates the uniform Hessian; a
        // small weight on it restores balance (the dual-dominance fix).
        let mut x = Matrix::gauss(4, 20, 1.0, &mut rng);
        for i in 0..4 {
            x.set(i, 0, 100.0);
        }
        let mut uni = HessianAccum::new(4);
        uni.add(&x);
        let mut w = vec![1.0f32; 20];
        w[0] = 1e-4;
        let mut rect = HessianAccum::new(4);
        rect.add_weighted(&x, &w);
        let h_uni = uni.finalize();
        let h_rect = rect.finalize();
        // Uniform Hessian diag is outlier-dominated (~100²/20 = 500).
        assert!(h_uni.at(0, 0) > 100.0);
        // Rectified diag is back at O(1).
        assert!(h_rect.at(0, 0) < 10.0, "h_rect diag {}", h_rect.at(0, 0));
    }

    #[test]
    fn hessian_error_matches_explicit_form() {
        let mut rng = Rng::new(64);
        let w = Matrix::gauss(5, 7, 1.0, &mut rng);
        let w_hat = Matrix::gauss(5, 7, 1.0, &mut rng);
        let x = Matrix::gauss(7, 60, 1.0, &mut rng);
        let h = gram(&x);
        // Explicit ‖(W−Ŵ)X‖²_F
        let d = w.sub(&w_hat);
        let dx = matmul(&d, &x);
        let direct = dx.frob_norm_sq();
        let via_h = hessian_weighted_error(&w, &w_hat, &h);
        assert!((direct - via_h).abs() < 1e-2 * (1.0 + direct), "{direct} vs {via_h}");
    }

    #[test]
    fn relative_error_is_zero_for_exact() {
        let mut rng = Rng::new(65);
        let w = Matrix::gauss(4, 6, 1.0, &mut rng);
        let x = Matrix::gauss(6, 30, 1.0, &mut rng);
        let h = gram(&x);
        assert_eq!(relative_hessian_error(&w, &w, &h), 0.0);
        let zero = Matrix::zeros(4, 6);
        let r = relative_hessian_error(&w, &zero, &h);
        assert!((r - 1.0).abs() < 1e-9);
    }
}
