//! Group-wise 1-bit quantization primitive (paper Eq. 11).
//!
//! `Q(u) = α_g · sign(u − μ_g)` with `μ_g`, `α_g` computed per group. Given
//! μ, the MSE-optimal scale is `α = mean(|u − μ|)` (we prove optimality in
//! tests). Dequantization adds μ back: `û = μ_g + α_g · sign(u − μ_g)` —
//! storing μ without using it in synthesis would waste the metadata the
//! paper explicitly budgets, so we follow the (standard) mean-restoring
//! convention.
//!
//! Two refinements from the paper are implemented here:
//! - **shared-mean** mode: one μ per (row × frequency-band) shared across
//!   groups, trading a little error for metadata (used for non-salient
//!   weights);
//! - **adaptive dense/sparse grouping**: within a band, coefficients are
//!   split by magnitude-about-the-mean into a "dense" (concentrated) and a
//!   "sparse" (tail) group, each with its own α; the split threshold is
//!   chosen by scanning quantiles for minimal MSE. Group membership costs
//!   one mask bit per weight, which the bit accounting charges.

use crate::tensor::matrix::Matrix;
use crate::tensor::stats::{mean, mean_abs_dev};

/// Configuration of the group quantizer.
#[derive(Clone, Debug)]
pub struct GroupSpec {
    /// Contiguous group length within a band (paper/BiLLM default: 128).
    pub group_size: usize,
    /// One shared μ per row×band instead of per group.
    pub shared_mean: bool,
    /// Split each band into dense/sparse magnitude groups (adds 1 mask
    /// bit/weight, but captures heavy-tailed coefficient distributions).
    pub adaptive_split: bool,
}

impl Default for GroupSpec {
    fn default() -> Self {
        GroupSpec { group_size: 128, shared_mean: true, adaptive_split: true }
    }
}

/// Storage accounting for the quantized representation, in *bits*.
#[derive(Clone, Copy, Debug, Default)]
pub struct QuantStats {
    /// 1 bit per weight sign.
    pub sign_bits: u64,
    /// Number of α scale parameters (16 bits each when packed).
    pub scale_params: u64,
    /// Number of μ mean parameters (16 bits each when packed).
    pub mean_params: u64,
    /// Extra per-weight mask bits (adaptive split membership).
    pub mask_bits: u64,
    /// Salient bookkeeping: column indices (16 bits each).
    pub index_params: u64,
    /// Total weights covered.
    pub weights: u64,
}

impl QuantStats {
    pub fn add(&mut self, other: &QuantStats) {
        self.sign_bits += other.sign_bits;
        self.scale_params += other.scale_params;
        self.mean_params += other.mean_params;
        self.mask_bits += other.mask_bits;
        self.index_params += other.index_params;
        self.weights += other.weights;
    }

    /// Average bits per weight, counting metadata at fp16 (the paper's
    /// "weight 1.08 bit" accounting convention).
    pub fn bits_per_weight(&self) -> f64 {
        if self.weights == 0 {
            return 0.0;
        }
        let total = self.sign_bits
            + self.mask_bits
            + 16 * (self.scale_params + self.mean_params + self.index_params);
        total as f64 / self.weights as f64
    }
}

/// Quantize one contiguous group in place (recon overwrites `u`), given a
/// fixed mean. Returns α.
fn quantize_group_with_mu(u: &mut [f32], mu: f32) -> f32 {
    let alpha = mean_abs_dev(u, mu);
    for v in u.iter_mut() {
        *v = mu + alpha * if *v >= mu { 1.0 } else { -1.0 };
    }
    alpha
}

/// MSE of binarizing `u` about mean `mu` with optimal α (without mutating).
fn group_mse(u: &[f32], mu: f32) -> f64 {
    let alpha = mean_abs_dev(u, mu);
    u.iter()
        .map(|&v| {
            let q = mu + alpha * if v >= mu { 1.0 } else { -1.0 };
            let d = (v - q) as f64;
            d * d
        })
        .sum()
}

/// Quantize a band (one row's coefficients within [start, end)) in place.
/// Returns the stats contribution.
pub fn quantize_band(band: &mut [f32], spec: &GroupSpec) -> QuantStats {
    let n = band.len();
    let mut stats = QuantStats { weights: n as u64, sign_bits: n as u64, ..Default::default() };
    if n == 0 {
        return QuantStats::default();
    }
    let shared_mu = mean(band);
    if spec.shared_mean {
        stats.mean_params += 1;
    }

    if spec.adaptive_split {
        // Dense/sparse split: choose a magnitude threshold (quantile of
        // |u − μ|) minimizing total MSE of binarizing each side separately.
        let mu0 = shared_mu;
        let mut dev: Vec<f32> = band.iter().map(|&v| (v - mu0).abs()).collect();
        dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut best: Option<(f64, f32)> = None;
        for q in [0.5f64, 0.7, 0.8, 0.9, 0.95] {
            let t = dev[((q * (n - 1) as f64) as usize).min(n - 1)];
            let dense: Vec<f32> = band.iter().cloned().filter(|&v| (v - mu0).abs() <= t).collect();
            let sparse: Vec<f32> = band.iter().cloned().filter(|&v| (v - mu0).abs() > t).collect();
            if dense.is_empty() || sparse.is_empty() {
                continue;
            }
            let mu_d = if spec.shared_mean { mu0 } else { mean(&dense) };
            let mu_s = if spec.shared_mean { mu0 } else { mean(&sparse) };
            let e = group_mse(&dense, mu_d) + group_mse(&sparse, mu_s);
            if best.map(|(b, _)| e < b).unwrap_or(true) {
                best = Some((e, t));
            }
        }
        if let Some((_, t)) = best {
            // Apply the winning split.
            let mut dense_idx = Vec::new();
            let mut sparse_idx = Vec::new();
            for (i, &v) in band.iter().enumerate() {
                if (v - mu0).abs() <= t {
                    dense_idx.push(i);
                } else {
                    sparse_idx.push(i);
                }
            }
            for part in [&dense_idx, &sparse_idx] {
                let mut vals: Vec<f32> = part.iter().map(|&i| band[i]).collect();
                let mu = if spec.shared_mean { mu0 } else { mean(&vals) };
                quantize_group_with_mu(&mut vals, mu);
                for (k, &i) in part.iter().enumerate() {
                    band[i] = vals[k];
                }
                stats.scale_params += 1;
                if !spec.shared_mean {
                    stats.mean_params += 1;
                }
            }
            stats.mask_bits += n as u64; // membership bit per weight
            return stats;
        }
        // Fall through to plain grouping if the split degenerated.
    }

    // Fixed-size contiguous groups.
    let gs = spec.group_size.max(1);
    let mut start = 0;
    while start < n {
        let end = (start + gs).min(n);
        let g = &mut band[start..end];
        let mu = if spec.shared_mean { shared_mu } else { mean(g) };
        quantize_group_with_mu(g, mu);
        stats.scale_params += 1;
        if !spec.shared_mean {
            stats.mean_params += 1;
        }
        start = end;
    }
    stats
}

/// Quantize every row of `m` treating `bands` as the per-row frequency-band
/// boundaries ([start, end) pairs — for a one-level Haar layout these are
/// the low and high subbands). Returns (reconstruction, stats).
pub fn quantize_matrix_banded(m: &Matrix, bands: &[(usize, usize)], spec: &GroupSpec) -> (Matrix, QuantStats) {
    let mut out = m.clone();
    let mut stats = QuantStats::default();
    for i in 0..out.rows {
        let row = out.row_mut(i);
        for &(s, e) in bands {
            let st = quantize_band(&mut row[s..e], spec);
            stats.add(&st);
        }
    }
    (out, stats)
}

/// Plain (non-banded) row-wise group binarization of a full matrix —
/// the RTN-1b baseline and the inner primitive for residual passes.
pub fn quantize_matrix(m: &Matrix, spec: &GroupSpec) -> (Matrix, QuantStats) {
    quantize_matrix_banded(m, &[(0, m.cols)], spec)
}

/// Order-2 residual binarization (BiLLM-style "high-fidelity residual
/// quantization" for salient weights): binarize, then binarize the residual
/// and add. Effective 2 bits/weight + two scale sets.
pub fn residual_binarize(m: &Matrix, spec: &GroupSpec) -> (Matrix, QuantStats) {
    let (q1, mut stats) = quantize_matrix(m, spec);
    let r = m.sub(&q1);
    let (q2, s2) = quantize_matrix(&r, spec);
    stats.add(&s2);
    (q1.add(&q2), stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn mse(a: &Matrix, b: &Matrix) -> f64 {
        a.dist_sq(b) / (a.rows * a.cols) as f64
    }

    #[test]
    fn binarization_error_bounded_for_gaussian() {
        // For N(0,1) data and α = E|u|, relative MSE = 1 − 2/π ≈ 0.363.
        let mut rng = Rng::new(41);
        let m = Matrix::gauss(64, 512, 1.0, &mut rng);
        let spec = GroupSpec { group_size: 128, shared_mean: false, adaptive_split: false };
        let (q, _) = quantize_matrix(&m, &spec);
        let rel = m.dist_sq(&q) / m.frob_norm_sq();
        assert!((rel - 0.363).abs() < 0.03, "rel={rel}");
    }

    #[test]
    fn residual_halves_error() {
        let mut rng = Rng::new(42);
        let m = Matrix::gauss(32, 256, 1.0, &mut rng);
        let spec = GroupSpec { group_size: 64, shared_mean: false, adaptive_split: false };
        let (q1, _) = quantize_matrix(&m, &spec);
        let (q2, _) = residual_binarize(&m, &spec);
        assert!(m.dist_sq(&q2) < 0.5 * m.dist_sq(&q1));
    }

    #[test]
    fn adaptive_split_beats_plain_on_heavy_tails() {
        let mut rng = Rng::new(43);
        // Laplace-ish heavy-tailed data: product of gaussians.
        let m = Matrix::from_fn(16, 256, |_, _| (rng.gauss() * rng.gauss()) as f32);
        let plain = GroupSpec { group_size: 256, shared_mean: true, adaptive_split: false };
        let split = GroupSpec { group_size: 256, shared_mean: true, adaptive_split: true };
        let (qp, _) = quantize_matrix(&m, &plain);
        let (qs, _) = quantize_matrix(&m, &split);
        assert!(mse(&m, &qs) < mse(&m, &qp), "split {} !< plain {}", mse(&m, &qs), mse(&m, &qp));
    }

    #[test]
    fn shared_mean_costs_little_on_centered_data() {
        let mut rng = Rng::new(44);
        let m = Matrix::gauss(16, 256, 1.0, &mut rng);
        let shared = GroupSpec { group_size: 64, shared_mean: true, adaptive_split: false };
        let per = GroupSpec { group_size: 64, shared_mean: false, adaptive_split: false };
        let (qs, ss) = quantize_matrix(&m, &shared);
        let (qp, sp) = quantize_matrix(&m, &per);
        // Error within 10%, metadata strictly smaller.
        assert!(mse(&m, &qs) < 1.1 * mse(&m, &qp));
        assert!(ss.mean_params < sp.mean_params);
    }

    #[test]
    fn bits_per_weight_near_one() {
        let mut rng = Rng::new(45);
        let m = Matrix::gauss(128, 1024, 1.0, &mut rng);
        let spec = GroupSpec { group_size: 128, shared_mean: true, adaptive_split: false };
        let (_, stats) = quantize_matrix(&m, &spec);
        let bpw = stats.bits_per_weight();
        assert!(bpw > 1.0 && bpw < 1.3, "bpw={bpw}");
    }

    #[test]
    fn signs_are_exactly_two_levels_per_group() {
        let mut rng = Rng::new(46);
        let m = Matrix::gauss(4, 64, 1.0, &mut rng);
        let spec = GroupSpec { group_size: 64, shared_mean: false, adaptive_split: false };
        let (q, _) = quantize_matrix(&m, &spec);
        for i in 0..4 {
            let mut levels: Vec<f32> = q.row(i).to_vec();
            levels.sort_by(|a, b| a.partial_cmp(b).unwrap());
            levels.dedup_by(|a, b| (*a - *b).abs() < 1e-7);
            assert!(levels.len() <= 2, "row {i} has {} levels", levels.len());
        }
    }

    #[test]
    fn empty_band_is_noop() {
        let m = Matrix::zeros(3, 8);
        let (q, stats) = quantize_matrix_banded(&m, &[(4, 4)], &GroupSpec::default());
        assert_eq!(q, m);
        assert_eq!(stats.weights, 0);
    }

    #[test]
    fn stats_accumulate() {
        let mut a = QuantStats { sign_bits: 10, weights: 10, ..Default::default() };
        let b = QuantStats { sign_bits: 5, weights: 5, scale_params: 2, ..Default::default() };
        a.add(&b);
        assert_eq!(a.sign_bits, 15);
        assert_eq!(a.weights, 15);
        assert_eq!(a.scale_params, 2);
    }
}
