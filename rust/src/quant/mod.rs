//! Quantization core: the building blocks of HBVLA and its baselines.
//!
//! - [`group`] — the group-wise 1-bit primitive Q(u) = α·sign(u − μ)
//!   (Eq. 11) with shared-mean and adaptive dense/sparse grouping;
//! - [`packed`] — true 1-bit bitplane storage + packed GEMV (deploy path);
//! - [`transform`] — the transform-domain exact serving form (permutation
//!   + Haar metadata + salient side-channel around the committed plane);
//! - [`permute`] — the sparse orthogonal transform of Algorithm 1;
//! - [`hessian`] — standard and policy-aware rectified Hessians (Eq. 3);
//! - [`probe`] — the block-wise gradient probe producing token-importance
//!   scores (Eqs. 4–9), with a hand-written MHSA backward;
//! - [`saliency`] — salient column partitioning (two-stage selection);
//! - [`obq`] — OBQ/GPTQ error compensation (Appendix Eq. 28).

pub mod group;
pub mod hessian;
pub mod obq;
pub mod packed;
pub mod permute;
pub mod probe;
pub mod saliency;
pub mod transform;
