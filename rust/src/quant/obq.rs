//! OBQ / GPTQ error compensation with an (optionally) importance-aware
//! Hessian — the closed-form update of the paper's Appendix (Eq. 28).
//!
//! Quantizing column q and re-minimizing ‖ΔW X‖² over the remaining
//! columns gives the classic OBS/GPTQ recursion
//!
//!   e_q = (w_q − ŵ_q) / (H⁻¹)_qq,   w_k ← w_k − e_q · (H⁻¹)_qk  (k > q)
//!
//! applied column-by-column in index order. With the importance-aware
//! Hessian H_e = X G Xᵀ (G diagonal token importance) the identical update
//! holds with H replaced by H_e — that substitution is the whole proof of
//! Eq. 28, and is what the `hessian` argument receives when the
//! policy-aware path is on.

use crate::tensor::linalg::spd_inverse;
use crate::tensor::matrix::Matrix;

/// Percent-damping used before inversion, as in GPTQ.
pub const PERCDAMP: f64 = 0.01;

/// Run the OBQ sweep over the columns of `w` (d_out × d_in).
///
/// `quantize_col(j, col) -> quantized col` supplies the per-column
/// quantizer (binarization, residual binarization, …). Columns are visited
/// in ascending index order; after each column is frozen, its error is
/// propagated into the not-yet-visited columns through H⁻¹.
///
/// Returns the quantized matrix Ŵ (the compensated weights are internal).
pub fn obq_sweep<F>(w: &Matrix, hessian: &Matrix, mut quantize_col: F) -> Matrix
where
    F: FnMut(usize, &[f32]) -> Vec<f32>,
{
    assert_eq!(w.cols, hessian.rows);
    assert_eq!(hessian.rows, hessian.cols);
    let n = w.cols;
    let d = w.rows;
    let hinv = spd_inverse(hessian, PERCDAMP).expect("Hessian not invertible even after damping");

    // Work on a mutable copy; q holds the frozen quantized columns.
    let mut work = w.clone();
    let mut q = Matrix::zeros(d, n);
    for j in 0..n {
        let col = work.col(j);
        let qcol = quantize_col(j, &col);
        assert_eq!(qcol.len(), d);
        q.set_col(j, &qcol);
        let hjj = hinv.at(j, j).max(1e-12);
        // Propagate error to later columns: w_k -= e * hinv[j,k]
        for i in 0..d {
            let e = (col[i] - qcol[i]) / hjj;
            if e == 0.0 {
                continue;
            }
            let row = work.row_mut(i);
            let hrow = hinv.row(j);
            for k in j + 1..n {
                row[k] -= e * hrow[k];
            }
        }
    }
    q
}

/// Convenience: OBQ sweep where each column is binarized about its mean
/// with optimal scale (1-bit per-column quantizer), the building block of
/// the BiLLM baseline's non-salient path.
pub fn binarize_col(col: &[f32]) -> Vec<f32> {
    let n = col.len() as f32;
    let mu = col.iter().sum::<f32>() / n;
    let alpha = col.iter().map(|&v| (v - mu).abs()).sum::<f32>() / n;
    col.iter().map(|&v| mu + alpha * if v >= mu { 1.0 } else { -1.0 }).collect()
}

/// Order-2 residual per-column binarizer (salient columns).
pub fn residual_binarize_col(col: &[f32]) -> Vec<f32> {
    let q1 = binarize_col(col);
    let resid: Vec<f32> = col.iter().zip(&q1).map(|(&v, &q)| v - q).collect();
    let q2 = binarize_col(&resid);
    q1.iter().zip(&q2).map(|(&a, &b)| a + b).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::hessian::hessian_weighted_error;
    use crate::tensor::ops::gram;
    use crate::util::rng::Rng;

    fn calib(rng: &mut Rng, d_in: usize, n: usize) -> Matrix {
        Matrix::gauss(d_in, n, 1.0, rng)
    }

    #[test]
    fn obq_reduces_hessian_weighted_error_vs_direct() {
        let mut rng = Rng::new(81);
        let w = Matrix::gauss(24, 32, 1.0, &mut rng);
        // Correlated activations (x = A z): off-diagonal Hessian structure
        // is where OBQ compensation has room to help.
        let mix = Matrix::gauss(32, 8, 1.0, &mut rng);
        let z = calib(&mut rng, 8, 128);
        let x = crate::tensor::ops::matmul(&mix, &z);
        let h = gram(&x);
        // Direct column binarization (no compensation).
        let mut direct = Matrix::zeros(24, 32);
        for j in 0..32 {
            direct.set_col(j, &binarize_col(&w.col(j)));
        }
        let q = obq_sweep(&w, &h, |_, col| binarize_col(col));
        let e_direct = hessian_weighted_error(&w, &direct, &h);
        let e_obq = hessian_weighted_error(&w, &q, &h);
        assert!(
            e_obq < 0.9 * e_direct,
            "OBQ should reduce the H-weighted error: {e_obq} vs {e_direct}"
        );
    }

    #[test]
    fn obq_with_lossless_quantizer_is_identity() {
        let mut rng = Rng::new(82);
        let w = Matrix::gauss(8, 10, 1.0, &mut rng);
        let x = calib(&mut rng, 10, 40);
        let h = gram(&x);
        let q = obq_sweep(&w, &h, |_, col| col.to_vec());
        assert!(q.dist_sq(&w) < 1e-10);
    }

    #[test]
    fn residual_col_better_than_single() {
        let mut rng = Rng::new(83);
        let col: Vec<f32> = (0..64).map(|_| rng.gauss() as f32).collect();
        let e1: f64 = col
            .iter()
            .zip(&binarize_col(&col))
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum();
        let e2: f64 = col
            .iter()
            .zip(&residual_binarize_col(&col))
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum();
        assert!(e2 < 0.6 * e1);
    }

    #[test]
    fn importance_aware_hessian_prioritizes_weighted_tokens() {
        // Quantize with H built from token-weighted calibration; the
        // resulting Ŵ should fit the heavily weighted token better than
        // the uniform-H solution does.
        let mut rng = Rng::new(84);
        let d_in = 16;
        let w = Matrix::gauss(8, d_in, 1.0, &mut rng);
        let x = calib(&mut rng, d_in, 64);
        // Token 0 is "policy critical": weight 50.
        let mut s = vec![1.0f32; 64];
        s[0] = 50.0;
        let h_uni = gram(&x);
        let h_imp = crate::tensor::ops::gram_weighted(&x, &s);
        let q_uni = obq_sweep(&w, &h_uni, |_, col| binarize_col(col));
        let q_imp = obq_sweep(&w, &h_imp, |_, col| binarize_col(col));
        // Error on the critical token x₀.
        let x0 = x.col(0);
        let err_on = |q: &Matrix| -> f64 {
            let mut e = 0.0f64;
            for i in 0..8 {
                let mut y = 0.0f32;
                let mut yq = 0.0f32;
                for j in 0..d_in {
                    y += w.at(i, j) * x0[j];
                    yq += q.at(i, j) * x0[j];
                }
                e += ((y - yq) as f64).powi(2);
            }
            e
        };
        assert!(
            err_on(&q_imp) < err_on(&q_uni),
            "importance-aware OBQ should fit the critical token better: {} vs {}",
            err_on(&q_imp),
            err_on(&q_uni)
        );
    }
}
