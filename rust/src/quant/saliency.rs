//! Salient-column selection (paper "Policy-Aware Weight Partitioning",
//! final paragraphs).
//!
//! Stage 1: element-wise importance sᵢⱼ = wᵢⱼ² · h̃ⱼⱼ (quantization loss of
//! element (i,j) weighted by the — possibly rectified — Hessian diagonal),
//! reduced to a per-column score by ℓ2 over rows; the top `max_candidates`
//! columns form the candidate set.
//!
//! Stage 2: the final salient count k is chosen by minimizing a local
//! reconstruction-error surrogate: salient columns pay the (small)
//! order-2-residual binarization error, non-salient columns the 1-bit
//! error, both Hessian-diagonal-weighted, plus a metadata penalty per
//! salient column. This mirrors "determine the final number of salient
//! columns by minimizing a local reconstruction error under our
//! binarization surrogate".

use crate::tensor::matrix::Matrix;
use crate::tensor::stats::{mean, mean_abs_dev, top_k};

/// Result of salient-column selection.
#[derive(Clone, Debug)]
pub struct Partition {
    /// Sorted salient column indices.
    pub salient: Vec<usize>,
    /// Sorted non-salient column indices.
    pub non_salient: Vec<usize>,
    /// Per-column saliency scores (diagnostics / reports).
    pub scores: Vec<f32>,
}

/// Per-column MSE of 1-bit binarization (about the column's own mean).
fn col_mse_1bit(w: &Matrix, j: usize) -> f64 {
    let col = w.col(j);
    let mu = mean(&col);
    let alpha = mean_abs_dev(&col, mu);
    col.iter()
        .map(|&v| {
            let q = mu + alpha * if v >= mu { 1.0 } else { -1.0 };
            let d = (v - q) as f64;
            d * d
        })
        .sum()
}

/// Per-column MSE of order-2 residual binarization.
fn col_mse_2bit(w: &Matrix, j: usize) -> f64 {
    let col = w.col(j);
    let mu = mean(&col);
    let alpha = mean_abs_dev(&col, mu);
    let resid: Vec<f32> = col
        .iter()
        .map(|&v| v - (mu + alpha * if v >= mu { 1.0 } else { -1.0 }))
        .collect();
    let mu2 = mean(&resid);
    let a2 = mean_abs_dev(&resid, mu2);
    resid
        .iter()
        .map(|&r| {
            let q = mu2 + a2 * if r >= mu2 { 1.0 } else { -1.0 };
            let d = (r - q) as f64;
            d * d
        })
        .sum()
}

/// Select salient columns of `w` given the Hessian diagonal `h_diag`
/// (standard or policy-aware rectified). `max_candidates` bounds the
/// search (HBLLM convention: 40); the returned salient count is the
/// surrogate-error argmin over 0..=max_candidates.
pub fn select_salient(w: &Matrix, h_diag: &[f32], max_candidates: usize) -> Partition {
    assert_eq!(h_diag.len(), w.cols, "hessian diag dim mismatch");
    let m = w.cols;

    // Stage 1: diag-normalized element scores → column ℓ2 reduction.
    let mut scores = vec![0.0f32; m];
    for j in 0..m {
        let hj = h_diag[j].max(0.0);
        let mut acc = 0.0f64;
        for i in 0..w.rows {
            let s = (w.at(i, j) * w.at(i, j)) as f64 * hj as f64;
            acc += s * s;
        }
        scores[j] = (acc.sqrt()) as f32;
    }
    let cand = top_k(&scores, max_candidates.min(m));

    // Stage 2: pick k minimizing the binarization surrogate.
    // Precompute per-column weighted errors for both fidelities.
    let e1: Vec<f64> = (0..m).map(|j| col_mse_1bit(w, j) * h_diag[j].max(1e-12) as f64).collect();
    let e2: Vec<f64> = (0..m).map(|j| col_mse_2bit(w, j) * h_diag[j].max(1e-12) as f64).collect();
    let base: f64 = e1.iter().sum();
    // Metadata penalty per salient column: an extra sign plane + scales ≈
    // one column of bits; expressed as a fraction of the mean 1-bit error
    // so the units match. Small but non-zero, so k doesn't always max out.
    let penalty = 0.02 * base / m.max(1) as f64;

    let mut best_k = 0usize;
    let mut best_err = base;
    let mut err = base;
    for (k, &j) in cand.iter().enumerate() {
        err += e2[j] - e1[j] + penalty;
        if err < best_err {
            best_err = err;
            best_k = k + 1;
        }
    }

    let mut salient: Vec<usize> = cand[..best_k].to_vec();
    salient.sort_unstable();
    let sal_set: Vec<bool> = {
        let mut s = vec![false; m];
        for &j in &salient {
            s[j] = true;
        }
        s
    };
    let non_salient: Vec<usize> = (0..m).filter(|&j| !sal_set[j]).collect();
    Partition { salient, non_salient, scores }
}

/// Fill salient columns with the average of their nearest non-salient
/// neighbours on each side (paper: "fill the missing values in salient
/// columns using adjacent averages"), producing W_filled for the
/// non-salient Haar pass.
pub fn fill_salient_adjacent(w: &Matrix, salient: &[usize]) -> Matrix {
    let mut filled = w.clone();
    if salient.is_empty() {
        return filled;
    }
    let m = w.cols;
    let is_sal = {
        let mut s = vec![false; m];
        for &j in salient {
            s[j] = true;
        }
        s
    };
    for &j in salient {
        // Nearest non-salient neighbours left/right.
        let left = (0..j).rev().find(|&t| !is_sal[t]);
        let right = (j + 1..m).find(|&t| !is_sal[t]);
        for i in 0..w.rows {
            let v = match (left, right) {
                (Some(l), Some(r)) => 0.5 * (w.at(i, l) + w.at(i, r)),
                (Some(l), None) => w.at(i, l),
                (None, Some(r)) => w.at(i, r),
                (None, None) => 0.0,
            };
            filled.set(i, j, v);
        }
    }
    filled
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn finds_planted_salient_columns() {
        let mut rng = Rng::new(71);
        let mut w = Matrix::gauss(32, 64, 0.1, &mut rng);
        // Plant large-magnitude columns at 5 and 40.
        for i in 0..32 {
            w.set(i, 5, (rng.gauss() * 4.0) as f32);
            w.set(i, 40, (rng.gauss() * 4.0) as f32);
        }
        let h = vec![1.0f32; 64];
        let p = select_salient(&w, &h, 8);
        assert!(p.salient.contains(&5), "salient={:?}", p.salient);
        assert!(p.salient.contains(&40), "salient={:?}", p.salient);
    }

    #[test]
    fn hessian_diag_steers_selection() {
        let mut rng = Rng::new(72);
        let w = Matrix::gauss(16, 32, 1.0, &mut rng);
        // Uniform weights but one column has huge activation energy.
        let mut h = vec![1.0f32; 32];
        h[17] = 500.0;
        let p = select_salient(&w, &h, 4);
        assert!(p.salient.contains(&17), "salient={:?}", p.salient);
    }

    #[test]
    fn partition_is_complete_and_disjoint() {
        let mut rng = Rng::new(73);
        let w = Matrix::gauss(8, 20, 1.0, &mut rng);
        let h = vec![1.0f32; 20];
        let p = select_salient(&w, &h, 6);
        let mut all: Vec<usize> = p.salient.iter().chain(p.non_salient.iter()).cloned().collect();
        all.sort_unstable();
        assert_eq!(all, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn salient_count_bounded_by_candidates() {
        let mut rng = Rng::new(74);
        let w = Matrix::gauss(8, 50, 1.0, &mut rng);
        let h = vec![1.0f32; 50];
        let p = select_salient(&w, &h, 5);
        assert!(p.salient.len() <= 5);
    }

    #[test]
    fn fill_adjacent_averages() {
        let w = Matrix::from_vec(1, 5, vec![1.0, 100.0, 3.0, 100.0, 5.0]);
        let filled = fill_salient_adjacent(&w, &[1, 3]);
        assert_eq!(filled.at(0, 1), 2.0); // avg(1, 3)
        assert_eq!(filled.at(0, 3), 4.0); // avg(3, 5)
        assert_eq!(filled.at(0, 0), 1.0); // untouched
    }

    #[test]
    fn fill_edge_salient_uses_single_neighbor() {
        let w = Matrix::from_vec(1, 3, vec![100.0, 2.0, 100.0]);
        let filled = fill_salient_adjacent(&w, &[0, 2]);
        assert_eq!(filled.at(0, 0), 2.0);
        assert_eq!(filled.at(0, 2), 2.0);
    }

    #[test]
    fn no_salient_noop() {
        let mut rng = Rng::new(75);
        let w = Matrix::gauss(4, 8, 1.0, &mut rng);
        let filled = fill_salient_adjacent(&w, &[]);
        assert_eq!(filled, w);
    }
}
