//! Block-wise gradient probe (paper Eqs. 4–9): the source of the token
//! importance scores that rectify the Hessian.
//!
//! The block is the residual attention module on the action pathway,
//! Φ(X) = X + MHSA(X), together with its quantized counterpart Φ̂ under a
//! provisional binarization. A single local backward pass on
//! L_blk = ‖Φ(X) − Φ̂(X)‖²_F yields the cached gradients
//! G⁽ᵖ⁾ = ∂L/∂Y⁽ᵖ⁾ at the four projection outputs p ∈ {Q, K, V, O}; the
//! per-token column norms aₜ⁽ᵖ⁾ = ‖G⁽ᵖ⁾₍:,ₜ₎‖₂ / d_p become the diagonal
//! importance matrix S⁽ᵖ⁾ that reweights the Hessian (Eq. 3/9).
//!
//! The MHSA forward/backward here is hand-derived and verified against
//! finite differences in the tests — there is no autograd in this stack.

use crate::tensor::matrix::Matrix;
use crate::tensor::ops::{matmul, softmax_rows};

/// Weights of one residual attention block. Convention: tokens are
/// **columns** (X is d × N), projections act from the left: Y⁽ᵖ⁾ = W⁽ᵖ⁾ X.
#[derive(Clone, Debug)]
pub struct AttnBlock {
    pub wq: Matrix,
    pub wk: Matrix,
    pub wv: Matrix,
    pub wo: Matrix,
    pub heads: usize,
}

/// Intermediate state cached by the forward pass, needed for backward.
pub struct AttnTrace {
    /// Projection outputs Q, K, V (d × N).
    pub q: Matrix,
    pub k: Matrix,
    pub v: Matrix,
    /// Per-head softmax attention matrices (N × N, rows = query tokens).
    pub probs: Vec<Matrix>,
    /// Concatenated attention output before W_O (d × N).
    pub ctx: Matrix,
    /// Block output Z = X + W_O · ctx (d × N).
    pub z: Matrix,
}

/// Gradients at the four projection outputs (each d × N).
pub struct ProbeGrads {
    pub gq: Matrix,
    pub gk: Matrix,
    pub gv: Matrix,
    pub go: Matrix,
}

impl AttnBlock {
    pub fn head_dim(&self) -> usize {
        self.wq.rows / self.heads
    }

    /// Forward pass Φ(X) = X + MHSA(X), caching everything backward needs.
    pub fn forward(&self, x: &Matrix) -> AttnTrace {
        let d = self.wq.rows;
        let n = x.cols;
        assert_eq!(x.rows, self.wq.cols, "input dim mismatch");
        assert_eq!(d % self.heads, 0, "heads must divide model dim");
        let dh = d / self.heads;
        let q = matmul(&self.wq, x);
        let k = matmul(&self.wk, x);
        let v = matmul(&self.wv, x);
        let scale = 1.0 / (dh as f32).sqrt();
        let mut ctx = Matrix::zeros(d, n);
        let mut probs = Vec::with_capacity(self.heads);
        for h in 0..self.heads {
            let r0 = h * dh;
            let r1 = r0 + dh;
            let qh = q.slice_rows(r0, r1);
            let kh = k.slice_rows(r0, r1);
            let vh = v.slice_rows(r0, r1);
            // S = Qᵀ K / √dh  (N×N, rows = query tokens)
            let mut s = matmul(&qh.transpose(), &kh);
            s.scale(scale);
            softmax_rows(&mut s);
            // ctx_h = V_h · Pᵀ
            let ch = matmul(&vh, &s.transpose());
            for i in 0..dh {
                for t in 0..n {
                    ctx.set(r0 + i, t, ch.at(i, t));
                }
            }
            probs.push(s);
        }
        let yo = matmul(&self.wo, &ctx);
        let z = x.add(&yo);
        AttnTrace { q, k, v, probs, ctx, z }
    }

    /// Backward pass: given ∂L/∂Z, return gradients at the projection
    /// outputs Y⁽Q,K,V,O⁾. (Input gradients are not needed by the probe.)
    pub fn backward(&self, x: &Matrix, trace: &AttnTrace, gz: &Matrix) -> ProbeGrads {
        let d = self.wq.rows;
        let n = x.cols;
        let dh = d / self.heads;
        let scale = 1.0 / (dh as f32).sqrt();
        // Z = X + W_O·ctx ⇒ ∂L/∂Y_O = ∂L/∂Z.
        let go = gz.clone();
        // ∂L/∂ctx = W_Oᵀ · G_O
        let gctx = matmul(&self.wo.transpose(), &go);
        let mut gq = Matrix::zeros(d, n);
        let mut gk = Matrix::zeros(d, n);
        let mut gv = Matrix::zeros(d, n);
        for h in 0..self.heads {
            let r0 = h * dh;
            let r1 = r0 + dh;
            let gch = gctx.slice_rows(r0, r1); // dh × N
            let qh = trace.q.slice_rows(r0, r1);
            let kh = trace.k.slice_rows(r0, r1);
            let vh = trace.v.slice_rows(r0, r1);
            let p = &trace.probs[h]; // N × N
            // ctx_h = V_h Pᵀ  ⇒ G_V = G_ctx · P ; G_P = G_ctxᵀ · V_h
            let gvh = matmul(&gch, p);
            // G_P[t,s] = Σ_i gch[i,t]·vh[i,s]  →  (N×dh)·(dh×N) = N×N
            let gp = matmul(&gch.transpose(), &vh);
            // Softmax backward, row-wise: gS[t,s] = P[t,s]·(gP[t,s] − Σ_u gP[t,u]P[t,u])
            let mut gs = Matrix::zeros(n, n);
            for t in 0..n {
                let prow = p.row(t);
                let gprow = gp.row(t);
                let dot: f32 = prow.iter().zip(gprow.iter()).map(|(&a, &b)| a * b).sum();
                let gsrow = gs.row_mut(t);
                for s in 0..n {
                    gsrow[s] = prow[s] * (gprow[s] - dot);
                }
            }
            gs.scale(scale);
            // S = Qᵀ K  ⇒ G_Q = K · G_Sᵀ ; G_K = Q · G_S
            let gqh = matmul(&kh, &gs.transpose());
            let gkh = matmul(&qh, &gs);
            for i in 0..dh {
                for t in 0..n {
                    gq.set(r0 + i, t, gqh.at(i, t));
                    gk.set(r0 + i, t, gkh.at(i, t));
                    gv.set(r0 + i, t, gvh.at(i, t));
                }
            }
        }
        ProbeGrads { gq, gk, gv, go }
    }
}

/// Result of the probe: per-projection token-importance vectors (length N),
/// plus their mean (used for layers outside the attention projections,
/// e.g. MLP matrices — documented design choice).
#[derive(Clone, Debug)]
pub struct TokenImportance {
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub o: Vec<f32>,
    pub mean: Vec<f32>,
}

impl TokenImportance {
    pub fn for_proj(&self, p: char) -> &[f32] {
        match p {
            'q' | 'Q' => &self.q,
            'k' | 'K' => &self.k,
            'v' | 'V' => &self.v,
            'o' | 'O' => &self.o,
            _ => &self.mean,
        }
    }
}

/// Run the probe: forward FP block and quantized block on the same X,
/// backprop L = ‖Z − Ẑ‖² through the quantized block, aggregate per-token
/// column norms (Eq. 7), normalize to mean 1 so the rectified Hessian
/// keeps the standard Hessian's scale.
///
/// `focus` restricts the block loss to one output column — the *action
/// pathway* (readout/instruction token). This is what makes the probe
/// immune to the dual-dominance problem: measured over all columns, the
/// loss (and hence the gradients) would be dominated by the very
/// high-magnitude background tokens the rectification is meant to
/// suppress.
pub fn probe_token_importance_focused(
    fp: &AttnBlock,
    quant: &AttnBlock,
    x: &Matrix,
    focus: Option<usize>,
) -> TokenImportance {
    let z = fp.forward(x).z;
    let tr_q = quant.forward(x);
    // G_Z = 2 (Ẑ − Z), optionally restricted to the action column.
    let mut gz = tr_q.z.sub(&z);
    gz.scale(2.0);
    if let Some(c) = focus {
        for i in 0..gz.rows {
            for t in 0..gz.cols {
                if t != c {
                    gz.set(i, t, 0.0);
                }
            }
        }
    }
    let grads = quant.backward(x, &tr_q, &gz);
    let n = x.cols;
    let colnorm = |g: &Matrix| -> Vec<f32> {
        let dp = g.rows as f32;
        (0..n)
            .map(|t| {
                let mut acc = 0.0f32;
                for i in 0..g.rows {
                    let v = g.at(i, t);
                    acc += v * v;
                }
                acc.sqrt() / dp
            })
            .collect()
    };
    let mut q = colnorm(&grads.gq);
    let mut k = colnorm(&grads.gk);
    let mut v = colnorm(&grads.gv);
    let mut o = colnorm(&grads.go);
    // Normalize each score vector to mean 1 (keeps H̃ on H's scale; an
    // all-equal importance then reduces exactly to the standard Hessian).
    for s in [&mut q, &mut k, &mut v, &mut o] {
        let m: f32 = s.iter().sum::<f32>() / n as f32;
        if m > 1e-20 {
            for x in s.iter_mut() {
                *x /= m;
            }
        } else {
            for x in s.iter_mut() {
                *x = 1.0;
            }
        }
    }
    let mean: Vec<f32> = (0..n).map(|t| 0.25 * (q[t] + k[t] + v[t] + o[t])).collect();
    TokenImportance { q, k, v, o, mean }
}

/// Unfocused probe (loss over all output tokens) — kept for the ablation
/// benches; the calibration pipeline uses the focused variant.
pub fn probe_token_importance(fp: &AttnBlock, quant: &AttnBlock, x: &Matrix) -> TokenImportance {
    probe_token_importance_focused(fp, quant, x, None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_block(d: usize, heads: usize, rng: &mut Rng) -> AttnBlock {
        let s = 1.0 / (d as f32).sqrt();
        AttnBlock {
            wq: Matrix::gauss(d, d, s, rng),
            wk: Matrix::gauss(d, d, s, rng),
            wv: Matrix::gauss(d, d, s, rng),
            wo: Matrix::gauss(d, d, s, rng),
            heads,
        }
    }

    fn block_loss(fp: &AttnBlock, q: &AttnBlock, x: &Matrix) -> f64 {
        let z = fp.forward(x).z;
        let zq = q.forward(x).z;
        z.dist_sq(&zq)
    }

    /// dL/dW⁽ᵖ⁾ = G⁽ᵖ⁾ Xᵀ for Y = W X; finite differences on W entries
    /// validate the whole manual backward chain.
    #[test]
    fn backward_matches_finite_differences() {
        let mut rng = Rng::new(101);
        let d = 8;
        let n = 6;
        let fp = random_block(d, 2, &mut rng);
        let mut qb = random_block(d, 2, &mut rng);
        let x = Matrix::gauss(d, n, 1.0, &mut rng);

        let z = fp.forward(&x).z;
        let tr = qb.forward(&x);
        let mut gz = tr.z.sub(&z);
        gz.scale(2.0);
        let grads = qb.backward(&x, &tr, &gz);

        let xt = x.transpose();
        let analytic = [
            ("wq", matmul(&grads.gq, &xt)),
            ("wk", matmul(&grads.gk, &xt)),
            ("wv", matmul(&grads.gv, &xt)),
            ("wo", matmul(&grads.go, &tr.ctx.transpose())),
        ];
        let eps = 1e-3f32;
        for (name, ga) in &analytic {
            for &(i, j) in &[(0usize, 0usize), (1, 3), (d - 1, d - 1), (2, 5)] {
                let orig = match *name {
                    "wq" => qb.wq.at(i, j),
                    "wk" => qb.wk.at(i, j),
                    "wv" => qb.wv.at(i, j),
                    _ => qb.wo.at(i, j),
                };
                let set = |qb: &mut AttnBlock, v: f32| match *name {
                    "wq" => qb.wq.set(i, j, v),
                    "wk" => qb.wk.set(i, j, v),
                    "wv" => qb.wv.set(i, j, v),
                    _ => qb.wo.set(i, j, v),
                };
                set(&mut qb, orig + eps);
                let lp = block_loss(&fp, &qb, &x);
                set(&mut qb, orig - eps);
                let lm = block_loss(&fp, &qb, &x);
                set(&mut qb, orig);
                let fd = ((lp - lm) / (2.0 * eps as f64)) as f32;
                let an = ga.at(i, j);
                assert!(
                    (fd - an).abs() < 2e-2 * (1.0 + fd.abs().max(an.abs())),
                    "{name}[{i},{j}]: fd={fd} analytic={an}"
                );
            }
        }
    }

    #[test]
    fn identical_blocks_give_zero_loss_and_uniform_importance() {
        let mut rng = Rng::new(102);
        let b = random_block(8, 2, &mut rng);
        let x = Matrix::gauss(8, 10, 1.0, &mut rng);
        assert!(block_loss(&b, &b, &x) < 1e-12);
        let imp = probe_token_importance(&b, &b, &x);
        // Zero gradients → normalized to all-ones fallback.
        for t in 0..10 {
            assert!((imp.mean[t] - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn importance_mean_is_one() {
        let mut rng = Rng::new(103);
        let fp = random_block(8, 2, &mut rng);
        let qb = random_block(8, 2, &mut rng);
        let x = Matrix::gauss(8, 12, 1.0, &mut rng);
        let imp = probe_token_importance(&fp, &qb, &x);
        for s in [&imp.q, &imp.k, &imp.v, &imp.o] {
            let m: f32 = s.iter().sum::<f32>() / 12.0;
            assert!((m - 1.0).abs() < 1e-4);
            assert!(s.iter().all(|&v| v >= 0.0));
        }
    }

    #[test]
    fn forward_residual_path() {
        // With zero attention output (W_O = 0), Φ(X) = X.
        let mut rng = Rng::new(104);
        let mut b = random_block(8, 2, &mut rng);
        b.wo = Matrix::zeros(8, 8);
        let x = Matrix::gauss(8, 5, 1.0, &mut rng);
        let z = b.forward(&x).z;
        assert!(z.dist_sq(&x) < 1e-12);
    }

    #[test]
    fn probs_are_row_stochastic() {
        let mut rng = Rng::new(105);
        let b = random_block(16, 4, &mut rng);
        let x = Matrix::gauss(16, 9, 1.0, &mut rng);
        let tr = b.forward(&x);
        assert_eq!(tr.probs.len(), 4);
        for p in &tr.probs {
            for t in 0..9 {
                let s: f32 = p.row(t).iter().sum();
                assert!((s - 1.0).abs() < 1e-5);
            }
        }
    }
}
