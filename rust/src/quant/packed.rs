//! True 1-bit weight storage and the deploy-path kernels.
//!
//! The evaluation pipeline works with dequantized reconstructions (for
//! closed-loop parity with the PJRT path), but a deployable system must
//! actually *store* binarized layers packed: sign bitplanes in `u64` words
//! plus per-group (α, μ) in f32 (fp16-equivalent accounting). This module
//! provides the packed container, pack/dequant round-trips, and a packed
//! GEMV whose inner loop flips activation signs through the IEEE-754 sign
//! bit (branch-free), which is what the Pallas L1 kernel mirrors on TPU
//! (see `python/compile/kernels/binary_matmul.py` and DESIGN.md
//! §Hardware-Adaptation).

use crate::tensor::matrix::Matrix;

/// A packed 1-bit matrix: for each row, `cols` sign bits in u64 words and
/// one (α, μ) pair per group of `group_size` consecutive columns.
#[derive(Clone, Debug)]
pub struct PackedBits {
    pub rows: usize,
    pub cols: usize,
    pub group_size: usize,
    words_per_row: usize,
    groups_per_row: usize,
    /// Row-major sign words; bit j of word (r, j/64) set ⇒ sign +1.
    signs: Vec<u64>,
    /// Row-major per-group scales α.
    alpha: Vec<f32>,
    /// Row-major per-group means μ.
    mu: Vec<f32>,
}

impl PackedBits {
    /// Pack a dense matrix: each group of `group_size` columns in each row
    /// is binarized as μ + α·sign(w − μ) and the signs stored packed.
    pub fn pack(w: &Matrix, group_size: usize) -> Self {
        let group_size = group_size.max(1);
        let words_per_row = w.cols.div_ceil(64);
        let groups_per_row = w.cols.div_ceil(group_size);
        let mut signs = vec![0u64; w.rows * words_per_row];
        let mut alpha = vec![0f32; w.rows * groups_per_row];
        let mut mu = vec![0f32; w.rows * groups_per_row];
        for r in 0..w.rows {
            let row = w.row(r);
            for g in 0..groups_per_row {
                let s = g * group_size;
                let e = (s + group_size).min(w.cols);
                let seg = &row[s..e];
                let m = seg.iter().sum::<f32>() / seg.len() as f32;
                let a = seg.iter().map(|&v| (v - m).abs()).sum::<f32>() / seg.len() as f32;
                mu[r * groups_per_row + g] = m;
                alpha[r * groups_per_row + g] = a;
                for (k, &v) in seg.iter().enumerate() {
                    if v >= m {
                        let j = s + k;
                        signs[r * words_per_row + j / 64] |= 1u64 << (j % 64);
                    }
                }
            }
        }
        PackedBits { rows: w.rows, cols: w.cols, group_size, words_per_row, groups_per_row, signs, alpha, mu }
    }

    /// Dequantize to a dense matrix (the reconstruction the quantizer's
    /// dense path produces, bit-for-bit).
    pub fn dequantize(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let row = out.row_mut(r);
            for j in 0..self.cols {
                let g = j / self.group_size;
                let a = self.alpha[r * self.groups_per_row + g];
                let m = self.mu[r * self.groups_per_row + g];
                let bit = (self.signs[r * self.words_per_row + j / 64] >> (j % 64)) & 1;
                row[j] = m + if bit == 1 { a } else { -a };
            }
        }
        out
    }

    /// Packed GEMV: y = Ŵ x without materializing Ŵ.
    ///
    /// Per row r and group g:  Σ_{j∈g} (μ_g + α_g s_j) x_j
    ///   = μ_g Σ_{j∈g} x_j + α_g Σ_{j∈g} s_j x_j,
    /// and the sign-weighted sum flips x_j's IEEE sign bit by XOR — no
    /// branches, no multiply by ±1.
    pub fn matvec(&self, x: &[f32], group_sums: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        assert_eq!(group_sums.len(), self.groups_per_row);
        for r in 0..self.rows {
            let mut acc = 0.0f32;
            let wbase = r * self.words_per_row;
            let gbase = r * self.groups_per_row;
            for g in 0..self.groups_per_row {
                let s = g * self.group_size;
                let e = (s + self.group_size).min(self.cols);
                let mut signed_sum = 0.0f32;
                let mut j = s;
                while j < e {
                    let word = self.signs[wbase + j / 64];
                    let upto = e.min((j / 64 + 1) * 64);
                    let mut bitpos = j % 64;
                    while j < upto {
                        // +x if bit set, −x otherwise, via sign-bit XOR.
                        let neg_mask = (!(word >> bitpos) & 1) as u32;
                        let flipped = f32::from_bits(x[j].to_bits() ^ (neg_mask << 31));
                        signed_sum += flipped;
                        j += 1;
                        bitpos += 1;
                    }
                }
                acc += self.mu[gbase + g] * group_sums[g] + self.alpha[gbase + g] * signed_sum;
            }
            y[r] = acc;
        }
    }

    /// Precompute per-group sums of an activation vector (shared across all
    /// rows — the μ-term of the packed GEMV).
    pub fn group_sums(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.cols);
        let mut sums = vec![0.0f32; self.groups_per_row];
        for (g, sum) in sums.iter_mut().enumerate() {
            let s = g * self.group_size;
            let e = (s + self.group_size).min(self.cols);
            *sum = x[s..e].iter().sum();
        }
        sums
    }

    /// Bytes of storage for the packed form (signs + fp16 metadata).
    pub fn storage_bytes(&self) -> usize {
        self.signs.len() * 8 + (self.alpha.len() + self.mu.len()) * 2
    }

    /// Bytes the dense f32 form would take.
    pub fn dense_bytes(&self) -> usize {
        self.rows * self.cols * 4
    }

    /// Compression ratio dense/packed.
    pub fn compression_ratio(&self) -> f64 {
        self.dense_bytes() as f64 / self.storage_bytes() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::matvec;
    use crate::util::rng::Rng;

    #[test]
    fn pack_dequant_is_group_binarization() {
        let mut rng = Rng::new(91);
        let w = Matrix::gauss(16, 200, 1.0, &mut rng);
        let p = PackedBits::pack(&w, 64);
        let d = p.dequantize();
        // Reconstruction must equal the dense group binarizer output.
        let spec = crate::quant::group::GroupSpec { group_size: 64, shared_mean: false, adaptive_split: false };
        let (q, _) = crate::quant::group::quantize_matrix(&w, &spec);
        assert!(d.dist_sq(&q) < 1e-9, "dist={}", d.dist_sq(&q));
    }

    #[test]
    fn packed_matvec_matches_dense() {
        let mut rng = Rng::new(92);
        for &(rows, cols, gs) in &[(8usize, 64usize, 32usize), (5, 130, 64), (3, 64, 64), (7, 100, 128)] {
            let w = Matrix::gauss(rows, cols, 1.0, &mut rng);
            let x: Vec<f32> = (0..cols).map(|_| rng.gauss() as f32).collect();
            let p = PackedBits::pack(&w, gs);
            let dense = p.dequantize();
            let y_dense = matvec(&dense, &x);
            let mut y_packed = vec![0.0f32; rows];
            let gsums = p.group_sums(&x);
            p.matvec(&x, &gsums, &mut y_packed);
            for i in 0..rows {
                assert!(
                    (y_dense[i] - y_packed[i]).abs() < 1e-3 * (1.0 + y_dense[i].abs()),
                    "({rows},{cols},{gs}) row {i}: {} vs {}",
                    y_dense[i],
                    y_packed[i]
                );
            }
        }
    }

    #[test]
    fn compression_ratio_near_32x_for_large_groups() {
        let mut rng = Rng::new(93);
        let w = Matrix::gauss(256, 1024, 1.0, &mut rng);
        let p = PackedBits::pack(&w, 128);
        let r = p.compression_ratio();
        assert!(r > 20.0, "ratio={r}");
    }

    #[test]
    fn storage_accounting_sane() {
        let w = Matrix::zeros(4, 64);
        let p = PackedBits::pack(&w, 64);
        // 4 rows × 1 word × 8B signs + 4×(α+μ)×2B = 32 + 16 = 48.
        assert_eq!(p.storage_bytes(), 48);
        assert_eq!(p.dense_bytes(), 4 * 64 * 4);
    }

    #[test]
    fn non_multiple_group_sizes() {
        let mut rng = Rng::new(94);
        let w = Matrix::gauss(3, 70, 1.0, &mut rng); // 70 = 64 + 6 tail
        let p = PackedBits::pack(&w, 32);
        let d = p.dequantize();
        assert_eq!(d.cols, 70);
        assert!(d.is_finite());
    }
}
